//! ISSUE 9: fused resident-x scan equivalence and fanout thread-count
//! bit-identity, in the **default build** (no features) so tier-1 proves
//! the perf paths never change served bits.
//!
//! Engine level: [`NativeDenoise::run_scan_resident`] must match
//! [`NativeDenoise::run_batched_into`] bit for bit while beating the
//! liveness callback once per (row, step). Serving level: a
//! `resident = true` session must produce bit-identical images to the
//! chunked rotating-slab loop and to the per-request path, in exactly
//! one dispatch per batch.

use std::sync::atomic::{AtomicUsize, Ordering};

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{DenoiseRequest, DenoiseResult, DiffusionServer};
use sf_mmcn::runtime::{ArtifactStore, BatchDispatch, NativeDenoise, TensorBuf};

// ---------------------------------------------------------------- engine

fn params() -> Vec<TensorBuf> {
    vec![
        TensorBuf::new(vec![3], vec![0.1, -0.2, 0.3]).unwrap(),
        TensorBuf::new(vec![2, 2], vec![0.05, 0.0, -0.1, 0.2]).unwrap(),
    ]
}

/// A (B=4, C=steps) dispatch over 1×4×4 images with descending-t rows.
struct Fixture {
    x: TensorBuf,
    t_embs: TensorBuf,
    coeffs: TensorBuf,
    noises: TensorBuf,
    b: usize,
    steps: usize,
}

impl Fixture {
    fn new(b: usize, steps: usize) -> Self {
        let n = 16;
        let x: Vec<f32> = (0..b * n).map(|i| (i as f32) * 0.017 - 0.3).collect();
        let t_embs: Vec<f32> = (0..steps * 8).map(|i| (i as f32) * 0.04 - 0.1).collect();
        let mut coeffs = Vec::new();
        for r in 0..steps {
            coeffs.extend([1.004, 0.05, if r + 1 < steps { 0.07 } else { 0.0 }]);
        }
        let noises: Vec<f32> = (0..b * steps * n)
            .map(|i| ((i % 101) as f32) * 0.0009 - 0.04)
            .collect();
        Fixture {
            x: TensorBuf::new(vec![b, 1, 4, 4], x).unwrap(),
            t_embs: TensorBuf::new(vec![steps, 8], t_embs).unwrap(),
            coeffs: TensorBuf::new(vec![steps, 3], coeffs).unwrap(),
            noises: TensorBuf::new(vec![b, steps, 1, 4, 4], noises).unwrap(),
            b,
            steps,
        }
    }

    fn dispatch(&self) -> BatchDispatch {
        BatchDispatch {
            batch: self.b,
            steps: self.steps,
            x: &self.x,
            t_embs: &self.t_embs,
            coeffs: &self.coeffs,
            noises: &self.noises,
        }
    }
}

#[test]
fn resident_scan_bit_identical_with_per_step_beats() {
    let e = NativeDenoise::new(vec![1, 4, 4], 8);
    let p = params();
    let f = Fixture::new(4, 5);
    let d = f.dispatch();
    let mut chunked = vec![0.0f32; f.b * 16];
    e.run_batched_into(&d, &p, &mut chunked).unwrap();
    let beats = AtomicUsize::new(0);
    let mut resident = vec![0.0f32; f.b * 16];
    e.run_scan_resident(&d, &p, &mut resident, &|| {
        beats.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(resident, chunked, "resident scan changed the math");
    // liveness contract: one beat per (row, step) — at least as frequent
    // as the chunked loop's per-chunk pulse
    assert_eq!(beats.load(Ordering::Relaxed), f.b * f.steps);
    // wrong-sized slab rejected
    let mut short = vec![0.0f32; f.b * 16 - 1];
    assert!(e.run_scan_resident(&d, &p, &mut short, &|| {}).is_err());
}

#[test]
fn resident_scan_matches_manual_chunked_loop() {
    // Re-create the serving layer's chunked dispatch by hand (per-chunk
    // t_emb/coeff rows, per-request noise re-gather, image ping-pong)
    // and pin the resident scan to it bit for bit — the exact cross-
    // chunk-boundary equivalence the serving path relies on.
    let e = NativeDenoise::new(vec![1, 4, 4], 8);
    let p = params();
    let (b, steps, n, chunk) = (3usize, 5usize, 16usize, 2usize);
    let f = Fixture::new(b, steps);
    let beats = AtomicUsize::new(0);
    let mut resident = vec![0.0f32; b * n];
    e.run_scan_resident(&f.dispatch(), &p, &mut resident, &|| {
        beats.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(beats.load(Ordering::Relaxed), b * steps);

    let mut cur = f.x.clone();
    let mut done = 0;
    while done < steps {
        let c = chunk.min(steps - done);
        let t_embs =
            TensorBuf::new(vec![c, 8], f.t_embs.data[done * 8..(done + c) * 8].to_vec()).unwrap();
        let coeffs =
            TensorBuf::new(vec![c, 3], f.coeffs.data[done * 3..(done + c) * 3].to_vec()).unwrap();
        let mut nz = Vec::with_capacity(b * c * n);
        for i in 0..b {
            nz.extend_from_slice(
                &f.noises.data[(i * steps + done) * n..(i * steps + done + c) * n],
            );
        }
        let noises = TensorBuf::new(vec![b, c, 1, 4, 4], nz).unwrap();
        let d = BatchDispatch {
            batch: b,
            steps: c,
            x: &cur,
            t_embs: &t_embs,
            coeffs: &coeffs,
            noises: &noises,
        };
        let mut out = vec![0.0f32; b * n];
        e.run_batched_into(&d, &p, &mut out).unwrap();
        cur = TensorBuf::new(cur.shape.clone(), out).unwrap();
        done += c;
    }
    assert_eq!(resident, cur.data, "resident scan diverged across chunk boundaries");
}

#[test]
fn fanout_bit_identical_at_forced_thread_counts() {
    // ISSUE 9 property: `SF_MMCN_FANOUT_THREADS` forces the row fanout
    // to an exact thread count; rows are independent, so 1, 2, 3
    // (non-dividing) and 8 threads must reproduce the same bits. All
    // env mutation happens serially inside this one test.
    let e = NativeDenoise::new(vec![1, 16, 16], 8);
    let p = params();
    let n = 256;
    let (b, steps) = (8usize, 4usize);
    let x: Vec<f32> = (0..b * n).map(|i| ((i % 89) as f32) * 0.012 - 0.5).collect();
    let t_embs: Vec<f32> = (0..steps * 8).map(|i| (i as f32) * 0.03 - 0.09).collect();
    let mut coeffs = Vec::new();
    for r in 0..steps {
        coeffs.extend([1.002, 0.04, if r + 1 < steps { 0.05 } else { 0.0 }]);
    }
    let noises: Vec<f32> = (0..b * steps * n)
        .map(|i| ((i % 97) as f32) * 0.0011 - 0.05)
        .collect();
    let x_t = TensorBuf::new(vec![b, 1, 16, 16], x).unwrap();
    let te_t = TensorBuf::new(vec![steps, 8], t_embs).unwrap();
    let co_t = TensorBuf::new(vec![steps, 3], coeffs).unwrap();
    let no_t = TensorBuf::new(vec![b, steps, 1, 16, 16], noises).unwrap();
    let d = BatchDispatch {
        batch: b,
        steps,
        x: &x_t,
        t_embs: &te_t,
        coeffs: &co_t,
        noises: &no_t,
    };
    let run_with = |threads: &str| {
        std::env::set_var("SF_MMCN_FANOUT_THREADS", threads);
        let mut out = vec![0.0f32; b * n];
        let r = e.run_batched_into(&d, &p, &mut out);
        std::env::remove_var("SF_MMCN_FANOUT_THREADS");
        r.unwrap();
        out
    };
    let baseline = run_with("1");
    for t in ["2", "3", "8"] {
        assert_eq!(
            run_with(t),
            baseline,
            "fanout at {t} threads diverged from single-threaded"
        );
    }
    // the resident scan fans out through the same row kernel
    std::env::set_var("SF_MMCN_FANOUT_THREADS", "3");
    let beats = AtomicUsize::new(0);
    let mut resident = vec![0.0f32; b * n];
    let res = e.run_scan_resident(&d, &p, &mut resident, &|| {
        beats.fetch_add(1, Ordering::Relaxed);
    });
    std::env::remove_var("SF_MMCN_FANOUT_THREADS");
    res.unwrap();
    assert_eq!(resident, baseline, "resident fanout at 3 threads diverged");
    assert_eq!(beats.load(Ordering::Relaxed), b * steps, "beats from all shards");
}

// ---------------------------------------------------------------- serving

fn native_cfg(steps: usize, resident: bool, chunk: usize) -> ServeConfig {
    ServeConfig {
        steps,
        workers: 1,
        max_batch: 4,
        batched: true,
        requests: 0,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        pipeline: true,
        chunk,
        pooled: true,
        resident,
        ..ServeConfig::default()
    }
}

fn native_server(cfg: ServeConfig) -> DiffusionServer {
    let store = ArtifactStore::new("artifacts");
    DiffusionServer::new(cfg, &store).expect("native backend needs no artifacts")
}

fn reqs(n: u64, steps: usize) -> Vec<DenoiseRequest> {
    (0..n)
        .map(|i| DenoiseRequest::new(i, 500 + i, steps))
        .collect()
}

fn by_id(mut results: Vec<DenoiseResult>) -> Vec<DenoiseResult> {
    results.sort_by_key(|r| r.id);
    results
}

#[test]
fn resident_serve_bit_identical_in_one_dispatch_per_batch() {
    // 4 requests, one worker, max_batch 4 → exactly one batch. The
    // chunked session dispatches ceil(5/2) = 3 times; the resident
    // session must produce the same bits in a single engine call.
    let (r_chunk, m_chunk) = native_server(native_cfg(5, false, 2)).serve(reqs(4, 5)).unwrap();
    let (r_res, m_res) = native_server(native_cfg(5, true, 2)).serve(reqs(4, 5)).unwrap();
    let (r_seq, _) = {
        let mut cfg = native_cfg(5, false, 0);
        cfg.batched = false;
        cfg.max_batch = 1;
        native_server(cfg).serve(reqs(4, 5)).unwrap()
    };
    let (r_chunk, r_res, r_seq) = (by_id(r_chunk), by_id(r_res), by_id(r_seq));
    for ((c, r), s) in r_chunk.iter().zip(&r_res).zip(&r_seq) {
        assert_eq!(c.id, r.id);
        assert_eq!(
            c.image.data, r.image.data,
            "request {} diverged between chunked and resident serving",
            c.id
        );
        assert_eq!(
            s.image.data, r.image.data,
            "request {} diverged between per-request and resident serving",
            s.id
        );
    }
    assert_eq!(m_res.requests_done, 4);
    assert_eq!(m_res.steps_done, 20, "metrics cadence unchanged");
    assert_eq!(m_res.dispatches, 1, "resident batch is one engine call");
    assert_eq!(m_res.batch_items, 4);
    assert!(
        m_chunk.dispatches > m_res.dispatches,
        "chunked loop must dispatch more often ({} vs {})",
        m_chunk.dispatches,
        m_res.dispatches
    );
    // the resident flag must not leak into the batcher invariants
    assert_eq!(m_res.cross_model_batches, 0);
    assert_eq!(m_res.cross_shape_batches, 0);
}

#[test]
fn resident_serve_handles_mixed_step_counts() {
    // Mixed per-request steps form separate (model, steps, shape)
    // batches; each resident batch is still a single dispatch and still
    // bit-identical to its chunked counterpart.
    let mixed = |resident: bool| {
        let mut all = reqs(3, 6);
        all.extend((3..6).map(|i| DenoiseRequest::new(i, 500 + i, 2)));
        let (results, m) = native_server(native_cfg(6, resident, 2)).serve(all).unwrap();
        (by_id(results), m)
    };
    let (r_res, m_res) = mixed(true);
    let (r_chunk, m_chunk) = mixed(false);
    for (r, c) in r_res.iter().zip(&r_chunk) {
        assert_eq!(r.id, c.id);
        assert_eq!(r.steps, c.steps);
        assert_eq!(
            r.image.data, c.image.data,
            "request {} diverged under mixed step counts",
            r.id
        );
    }
    assert_eq!(m_res.requests_done, 6);
    assert!(
        m_res.dispatches < m_chunk.dispatches,
        "resident sessions collapse per-chunk dispatches ({} vs {})",
        m_res.dispatches,
        m_chunk.dispatches
    );
}

#[test]
fn resident_serve_under_load_with_deadlines_intact() {
    // A larger run through the admission queue: resident serving must
    // preserve the exactly-once resolution contract and drain cleanly.
    let mut cfg = native_cfg(4, true, 0);
    cfg.workers = 2;
    let s = native_server(cfg);
    let (results, m) = s.serve(reqs(12, 4)).unwrap();
    assert_eq!(results.len(), 12);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (0..12).collect::<Vec<_>>());
    assert_eq!(m.requests_done, 12);
    assert_eq!(m.steps_done, 48);
    assert_eq!(m.batch_items, 12, "each request in exactly one dispatch");
    assert_eq!(m.admission.admitted, 12);
    assert_eq!(m.admission.queue_depth, 0, "drained at shutdown");
    // every batch was a single resident dispatch
    assert!(m.dispatches <= 12 && m.dispatches >= 3);
}
