// integration smoke: load sf_block artifact, run, compare vs jnp values
//
// Skips (rather than fails) when the AOT artifacts are absent or the
// binary was built without the `pjrt` feature — CI builds have neither
// `make artifacts` outputs nor the vendored xla runtime.
use sf_mmcn::runtime::{ArtifactStore, Executor, TensorBuf};

#[test]
fn sf_block_artifact_loads_and_runs() {
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve("sf_block_16") else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut exe = Executor::new().unwrap();
    if let Err(e) = exe.load_hlo_text("sf_block", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return;
    }
    let x = TensorBuf::new(vec![8, 16, 16], vec![0.5; 8 * 16 * 16]).unwrap();
    let w = TensorBuf::new(vec![8, 8, 3, 3], vec![0.1; 8 * 8 * 3 * 3]).unwrap();
    let b = TensorBuf::new(vec![8], vec![0.0; 8]).unwrap();
    let skip = TensorBuf::new(vec![8, 16, 16], vec![1.0; 8 * 16 * 16]).unwrap();
    let out = exe.run("sf_block", &[x, w, b, skip]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![8, 16, 16]);
    // interior pixel: 9 taps * 8 ch * 0.5 * 0.1 + 1.0 = 4.6
    let v = out[0].data[16 * 16 / 2 + 8]; // row 8, col 8 of channel 0
    assert!((v - 4.6).abs() < 1e-4, "interior value {v}");
}
