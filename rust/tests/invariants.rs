//! Property-based invariants of the compiler/simulator stack, over
//! randomized graphs and accelerator configurations.

use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::models::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};
use sf_mmcn::models::{resnet18, unet, vgg16, UnetConfig};
use sf_mmcn::sim::array::AcceleratorConfig;
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::proptest_lite::{Gen, Prop};

/// Random small CNN: a chain of convs (some residual) + optional pool +
/// optional dense head.
fn random_graph(g: &mut Gen) -> ModelGraph {
    let c0 = g.usize_in(1, 8);
    let mut hw = *g.choose(&[8usize, 12, 16]);
    let mut b = GraphBuilder::new("rand", TensorShape::new(c0, hw, hw));
    let mut c = c0;
    let layers = g.usize_in(1, 5);
    let mut last_conv: Option<(usize, usize)> = None; // (node, channels)
    for _ in 0..layers {
        let c_out = g.usize_in(1, 12);
        let residual = match last_conv {
            Some((node, ch)) if ch == c_out && g.bool() => {
                Residual::Identity { from: node }
            }
            Some((node, _)) if g.bool() => Residual::Conv { from: node, stride: 1 },
            _ => Residual::None,
        };
        let td = if g.bool() { Some(g.usize_in(1, 16)) } else { None };
        let (residual, time_dense) = if matches!(residual, Residual::None) {
            (residual, td)
        } else {
            (residual, None) // PE_9 can host only one branch
        };
        let node = b
            .add(Layer::Conv {
                c_in: c,
                c_out,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual,
                time_dense,
            })
            .unwrap();
        last_conv = Some((node, c_out));
        c = c_out;
    }
    if hw >= 4 && g.bool() {
        b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
        hw /= 2;
        last_conv = None;
    }
    if g.bool() {
        let _ = last_conv;
        b.add(Layer::Dense {
            in_f: c * hw * hw,
            out_f: g.usize_in(1, 20),
            act: Act::None,
        })
        .unwrap();
    }
    b.build()
}

#[test]
fn utilization_bounded_and_positive() {
    Prop::new("0 < U_PE <= 1 on random graphs", 60).check(|g| {
        let graph = random_graph(g);
        let units = *g.choose(&[1usize, 2, 4, 8, 16]);
        let a = analyze_graph(&AcceleratorConfig::with_units(units), &graph, 0.0);
        for l in &a.layers {
            // pool/reshape nodes run on the peripheral units (zero PE use)
            if l.label.starts_with("conv") || l.label.starts_with("dense") {
                assert!(l.u_pe > 0.0, "{}: zero utilization", l.label);
            }
            assert!(l.u_pe <= 1.0 + 1e-12, "{}: U_PE {} > 1", l.label, l.u_pe);
        }
        let total_u = a.totals.u_pe();
        assert!(total_u > 0.0 && total_u <= 1.0 + 1e-12);
    });
}

#[test]
fn hardware_does_exactly_the_models_work() {
    Prop::new("worker MAC slots == model conv+dense MACs", 60).check(|g| {
        let graph = random_graph(g);
        let a = analyze_graph(&AcceleratorConfig::default(), &graph, 0.0);
        // Worker slots + PE_9 residual-conv/dense MACs together must equal
        // the model's MAC count: nothing dropped, nothing invented.
        let hw_slots = a.totals.pe.mac_slots();
        let model = graph.total_macs();
        assert_eq!(
            hw_slots, model,
            "hardware slots {hw_slots} != model MACs {model}"
        );
    });
}

#[test]
fn reuse_never_increases_reads() {
    Prop::new("buffer_reads <= buffer_reads_no_reuse", 60).check(|g| {
        let graph = random_graph(g);
        let a = analyze_graph(&AcceleratorConfig::default(), &graph, 0.0);
        assert!(a.totals.unit.buffer_reads <= a.totals.unit.buffer_reads_no_reuse);
        // and disabling reuse makes them equal for conv layers (dense
        // layers keep their structural input-broadcast sharing)
        let cfg = AcceleratorConfig {
            data_reuse: false,
            ..AcceleratorConfig::default()
        };
        let b = analyze_graph(&cfg, &graph, 0.0);
        for l in b.layers.iter().filter(|l| l.label.starts_with("conv")) {
            assert_eq!(
                l.counts.unit.buffer_reads, l.counts.unit.buffer_reads_no_reuse,
                "{}: reuse disabled must read every tap",
                l.label
            );
        }
    });
}

#[test]
fn cycles_monotone_in_units() {
    Prop::new("more units never slower", 30).check(|g| {
        let graph = random_graph(g);
        let c1 = analyze_graph(&AcceleratorConfig::with_units(1), &graph, 0.0)
            .total_cycles();
        let c4 = analyze_graph(&AcceleratorConfig::with_units(4), &graph, 0.0)
            .total_cycles();
        let c16 = analyze_graph(&AcceleratorConfig::with_units(16), &graph, 0.0)
            .total_cycles();
        assert!(c4 <= c1, "4 units ({c4}) slower than 1 ({c1})");
        assert!(c16 <= c4, "16 units ({c16}) slower than 4 ({c4})");
    });
}

#[test]
fn sparsity_only_moves_energy_not_time() {
    Prop::new("gating: same cycles, less energy", 30).check(|g| {
        let graph = random_graph(g);
        let cfg = AcceleratorConfig::default();
        let dense = analyze_graph(&cfg, &graph, 0.0);
        let sparse = analyze_graph(&cfg, &graph, 0.7);
        assert_eq!(dense.total_cycles(), sparse.total_cycles());
        assert_eq!(dense.totals.pe.mac_slots(), sparse.totals.pe.mac_slots());
        let ed = CAL_40NM.core_energy_pj(&dense.totals);
        let es = CAL_40NM.core_energy_pj(&sparse.totals);
        assert!(es <= ed, "sparsity must not increase energy");
    });
}

#[test]
fn residual_fusion_is_free_in_cycles() {
    Prop::new("identity-skip conv == plain conv cycles", 40).check(|g| {
        let c = g.usize_in(1, 10);
        let hw = g.usize_in(3, 14);
        let mk = |residual| {
            let mut b = GraphBuilder::new("t", TensorShape::new(c, hw, hw));
            b.add(Layer::Conv {
                c_in: c,
                c_out: c,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual: Residual::None,
                time_dense: None,
            })
            .unwrap();
            b.add(Layer::Conv {
                c_in: c,
                c_out: c,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual,
                time_dense: None,
            })
            .unwrap();
            b.build()
        };
        let plain = analyze_graph(
            &AcceleratorConfig::default(),
            &mk(Residual::None),
            0.0,
        );
        let fused = analyze_graph(
            &AcceleratorConfig::default(),
            &mk(Residual::Identity { from: 0 }),
            0.0,
        );
        assert_eq!(plain.total_cycles(), fused.total_cycles());
        // ...and fused does strictly more arithmetic in that time
        assert!(
            fused.totals.pe.residual_adds > 0,
            "fusion must perform the adds"
        );
    });
}

#[test]
fn full_models_satisfy_energy_sanity() {
    for (name, graph) in [
        ("vgg16", vgg16(32, 10)),
        ("resnet18", resnet18(32, 10)),
        ("unet", unet(UnetConfig::default())),
    ] {
        let a = analyze_graph(&AcceleratorConfig::default(), &graph, 0.45);
        let rep = CAL_40NM.report(&a.totals, 8);
        assert!(
            rep.core_power_w > 1e-3 && rep.core_power_w < 0.1,
            "{name}: core power {} W out of band",
            rep.core_power_w
        );
        assert!(rep.gops > 1.0, "{name}: {} GOPs", rep.gops);
        assert!(rep.nu.is_finite() && rep.nu > 0.0);
        assert!(
            rep.core_energy_j < rep.core_energy_j + rep.dram_energy_j,
            "{name}: dram energy must be accounted"
        );
    }
}
