//! Property test: the closed-form schedule model (`compiler::schedule`)
//! must produce *exactly* the same cycle and event counts as the
//! cycle-accurate micro simulator (`sim::array`) — this equivalence is
//! what licenses using the analytic model for full-size VGG/ResNet/U-net
//! sweeps in the paper benches.
//!
//! Inputs/weights are generated in [0.25, 1.0] so nothing quantizes to
//! Q8.8 zero: gating is then driven by padding alone, which both sides
//! count deterministically.

use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::models::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, NodeWeights, WeightStore};
use sf_mmcn::util::proptest_lite::{Gen, Prop};
use sf_mmcn::util::Tensor;

/// Weights with all values safely inside Q8.8 (no quantized zeros).
fn safe_weights(g: &ModelGraph, gen: &mut Gen) -> WeightStore {
    let mut ws = WeightStore::random(g, 1);
    for (i, n) in g.nodes.iter().enumerate() {
        let nw = match &n.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                residual,
                time_dense,
                ..
            } => {
                let w = Tensor::from_fn(&[*c_out, *c_in, *k, *k], |_| gen.f32_in(0.25, 1.0));
                let bias = (0..*c_out).map(|_| 0.0).collect();
                let w_res = match residual {
                    Residual::Conv { from, .. } => {
                        let cs = g.nodes[*from].out_shape.c;
                        Some(Tensor::from_fn(&[*c_out, cs], |_| gen.f32_in(0.25, 1.0)))
                    }
                    _ => None,
                };
                let w_time = time_dense.map(|td| {
                    Tensor::from_fn(&[*c_out, td], |_| gen.f32_in(0.25, 1.0))
                });
                Some(NodeWeights {
                    w,
                    bias,
                    w_res,
                    w_time,
                })
            }
            Layer::Dense { in_f, out_f, .. } => {
                let w = Tensor::from_fn(&[*out_f, *in_f], |_| gen.f32_in(0.25, 1.0));
                Some(NodeWeights {
                    w,
                    bias: vec![0.0; *out_f],
                    w_res: None,
                    w_time: None,
                })
            }
            _ => None,
        };
        ws.per_node[i] = nw;
    }
    // weights were replaced in place: drop any cached quantized taps
    ws.invalidate_quant();
    ws
}

fn assert_counts_equal(g: &ModelGraph, cfg: AcceleratorConfig, gen: &mut Gen, time_dim: Option<usize>) {
    let ws = safe_weights(g, gen);
    // positive inputs: conv chains stay positive, nothing quantizes to zero
    let x = Tensor::from_fn(
        &[g.input.c, g.input.h, g.input.w],
        |_| gen.f32_in(0.25, 1.0),
    );
    let emb: Option<Vec<f32>> = time_dim.map(|td| (0..td).map(|_| gen.f32_in(0.25, 1.0)).collect());
    let mut acc = Accelerator::new(cfg);
    let run = acc
        .run_graph(g, &x, &ws, emb.as_deref())
        .expect("micro sim runs");
    let ana = analyze_graph(&cfg, g, 0.0);

    assert_eq!(run.layers.len(), ana.layers.len());
    for (lr, la) in run.layers.iter().zip(&ana.layers) {
        let ctx = format!("layer {} ({})", lr.node_idx, la.label);
        assert_eq!(lr.cycles, la.cycles, "{ctx}: cycles");
        assert_eq!(lr.counts.pe.macs, la.counts.pe.macs, "{ctx}: macs");
        assert_eq!(
            lr.counts.pe.gated_macs, la.counts.pe.gated_macs,
            "{ctx}: gated"
        );
        assert_eq!(
            lr.counts.pe.active_cycles, la.counts.pe.active_cycles,
            "{ctx}: active"
        );
        assert_eq!(
            lr.counts.pe.idle_cycles, la.counts.pe.idle_cycles,
            "{ctx}: idle"
        );
        assert_eq!(
            lr.counts.pe.writebacks, la.counts.pe.writebacks,
            "{ctx}: writebacks"
        );
        assert_eq!(
            lr.counts.pe.residual_adds, la.counts.pe.residual_adds,
            "{ctx}: residual adds"
        );
        assert_eq!(
            lr.counts.unit.cycles, la.counts.unit.cycles,
            "{ctx}: unit cycles"
        );
        assert_eq!(
            lr.counts.unit.buffer_reads, la.counts.unit.buffer_reads,
            "{ctx}: buffer reads"
        );
        assert_eq!(
            lr.counts.unit.buffer_reads_no_reuse, la.counts.unit.buffer_reads_no_reuse,
            "{ctx}: buffer reads (no reuse)"
        );
        assert_eq!(
            lr.counts.unit.weight_reads, la.counts.unit.weight_reads,
            "{ctx}: weight reads"
        );
        assert_eq!(
            lr.counts.unit.served_values, la.counts.unit.served_values,
            "{ctx}: served"
        );
        assert_eq!(
            lr.counts.mem.dram_reads, la.counts.mem.dram_reads,
            "{ctx}: dram reads"
        );
        assert_eq!(
            lr.counts.mem.output_buf_reads, la.counts.mem.output_buf_reads,
            "{ctx}: skip reads"
        );
        assert_eq!(
            lr.counts.mem.input_buf_writes, la.counts.mem.input_buf_writes,
            "{ctx}: ifm writes"
        );
    }
    assert_eq!(run.total_cycles(), ana.total_cycles(), "total cycles");
}

#[test]
fn series_conv_equivalence() {
    Prop::new("series conv: schedule == sim", 30).check(|g| {
        let c_in = g.usize_in(1, 12);
        let c_out = g.usize_in(1, 12);
        let hw = g.usize_in(3, 14);
        let k = *g.choose(&[1usize, 3, 5]);
        if hw < k {
            return;
        }
        let pad = g.usize_in(0, k / 2);
        let stride = *g.choose(&[1usize, 2]);
        if hw + 2 * pad < k {
            return;
        }
        let mut b = GraphBuilder::new("t", TensorShape::new(c_in, hw, hw));
        b.add(Layer::Conv {
            c_in,
            c_out,
            k,
            stride,
            pad,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        let graph = b.build();
        let units = *g.choose(&[2usize, 4, 8]);
        assert_counts_equal(&graph, AcceleratorConfig::with_units(units), g, None);
    });
}

#[test]
fn residual_identity_equivalence() {
    Prop::new("residual identity: schedule == sim", 20).check(|g| {
        let c = g.usize_in(1, 10);
        let hw = g.usize_in(3, 12);
        let mut b = GraphBuilder::new("t", TensorShape::new(c, hw, hw));
        b.add(Layer::Conv {
            c_in: c,
            c_out: c,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.add(Layer::Conv {
            c_in: c,
            c_out: c,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::Identity { from: 0 },
            time_dense: None,
        })
        .unwrap();
        let graph = b.build();
        assert_counts_equal(&graph, AcceleratorConfig::default(), g, None);
    });
}

#[test]
fn residual_conv_equivalence() {
    Prop::new("residual conv: schedule == sim", 20).check(|g| {
        let c = g.usize_in(2, 8);
        let hw = g.usize_in(4, 12);
        let hw = hw & !1; // even for stride-2
        let mut b = GraphBuilder::new("t", TensorShape::new(c, hw, hw));
        b.add(Layer::Conv {
            c_in: c,
            c_out: c,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        // downsample block: stride-2 conv with 1x1/2 residual conv
        b.add(Layer::Conv {
            c_in: c,
            c_out: c * 2,
            k: 3,
            stride: 2,
            pad: 1,
            act: Act::None,
            residual: Residual::Conv { from: 0, stride: 2 },
            time_dense: None,
        })
        .unwrap();
        let graph = b.build();
        assert_counts_equal(&graph, AcceleratorConfig::default(), g, None);
    });
}

#[test]
fn time_dense_equivalence() {
    Prop::new("time dense: schedule == sim", 20).check(|g| {
        let c = g.usize_in(1, 8);
        let c_out = g.usize_in(1, 8);
        let hw = g.usize_in(3, 10);
        // include overhang cases: time_dim can exceed k*k*c_in
        let td = g.usize_in(1, 12 * 9);
        let mut b = GraphBuilder::new("t", TensorShape::new(c, hw, hw));
        b.add(Layer::Conv {
            c_in: c,
            c_out,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: Some(td),
        })
        .unwrap();
        let graph = b.build();
        assert_counts_equal(&graph, AcceleratorConfig::default(), g, Some(td));
    });
}

#[test]
fn dense_pool_gap_equivalence() {
    Prop::new("dense/pool/gap: schedule == sim", 20).check(|g| {
        let c = g.usize_in(1, 6);
        let hw = *g.choose(&[4usize, 6, 8]);
        let out_f = g.usize_in(1, 40);
        let mut b = GraphBuilder::new("t", TensorShape::new(c, hw, hw));
        b.add(Layer::Conv {
            c_in: c,
            c_out: c,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
        let s = hw / 2;
        b.add(Layer::Dense {
            in_f: c * s * s,
            out_f,
            act: Act::None,
        })
        .unwrap();
        let graph = b.build();
        assert_counts_equal(&graph, AcceleratorConfig::default(), g, None);
    });
}

#[test]
fn small_input_split_equivalence() {
    // Tiny maps (<= 4 outputs) engage the split PE array (Figs 11-12):
    // the analytic mirror must match in every SF mode.
    Prop::new("split mode: schedule == sim", 25).check(|g| {
        let c = g.usize_in(1, 8);
        let c_out = g.usize_in(2, 9);
        let hw = *g.choose(&[1usize, 2]); // 1x1 or 2x2 maps
        let mode = g.usize_in(0, 3);
        let mut b = GraphBuilder::new("t", TensorShape::new(c, hw * 2, hw * 2));
        // producer conv to give skips a source (also possibly split-sized)
        b.add(Layer::Conv {
            c_in: c,
            c_out: c,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
        let (residual, td) = match mode {
            0 => (Residual::None, None),
            1 => (Residual::None, Some(g.usize_in(1, 30))),
            2 if c == c_out => (Residual::Identity { from: 1 }, None),
            _ => (Residual::Conv { from: 1, stride: 1 }, None),
        };
        b.add(Layer::Conv {
            c_in: c,
            c_out,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual,
            time_dense: td,
        })
        .unwrap();
        let graph = b.build();
        assert_counts_equal(&graph, AcceleratorConfig::default(), g, td);
    });
}

#[test]
fn unet_like_composite_equivalence() {
    // fixed small composite exercising upsample/concat too
    let mut gen = Gen::new(0xC0FFEE);
    let mut b = GraphBuilder::new("t", TensorShape::new(2, 8, 8));
    b.add(Layer::Conv {
        c_in: 2,
        c_out: 4,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::None,
        time_dense: Some(6),
    })
    .unwrap();
    b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
    b.add(Layer::Upsample2x).unwrap();
    b.add(Layer::ConcatSkip { from: 0 }).unwrap();
    b.add(Layer::Conv {
        c_in: 8,
        c_out: 4,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::Conv { from: 3, stride: 1 },
        time_dense: None,
    })
    .unwrap();
    let graph = b.build();
    assert_counts_equal(
        &graph,
        AcceleratorConfig::default(),
        &mut gen,
        Some(6),
    );
}
