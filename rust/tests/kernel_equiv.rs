//! ISSUE 9 property suite: exactness contracts between the `--features
//! simd` kernels and the always-compiled scalar references, at the
//! awkward lengths where lane math goes wrong (empty, sub-width, one
//! past a width boundary, the 31-entry table period, and page-scale
//! slabs straddling the 8-wide main/tail split).
//!
//! The contracts under test (see docs/ARCHITECTURE.md exactness tiers):
//!
//! * step kernel — **bounded-ULP**: the polynomial tanh is the only
//!   divergence from libm, so outputs agree within a small absolute
//!   bound, and agree *bit for bit* when the tanh term is multiplied
//!   out (`c2 = 0`), pinning every non-transcendental op to the same
//!   IEEE expression tree.
//! * classify kernel — **bit-identical**: vectorized products, scalar
//!   accumulation order.
//! * widening Q8.8 dot — **bit-exact**: integer addition is associative.
//! * dispatch vs portable — the runtime-dispatched entry points must
//!   match their portable bodies bit for bit on every host (on AVX2
//!   machines this pins the intrinsics path; elsewhere it is trivially
//!   the same code).
//!
//! The companion fanout thread-count suite lives in
//! `tests/resident_e2e.rs` so it also runs in default (non-simd) builds.

#![cfg(feature = "simd")]

use sf_mmcn::quant::Fixed;
use sf_mmcn::runtime::{
    classify_row_scalar, classify_row_simd, step_kernel_scalar, step_kernel_simd,
};
use sf_mmcn::util::simd;

/// Lengths that stress every lane-handling edge: empty, scalar tail
/// only, exactly one 8-wide chunk, chunk+1, the 31-entry table period,
/// and large slabs around the 8-wide boundary (4096 = 512 chunks).
const LENS: &[usize] = &[0, 1, 7, 8, 9, 31, 4095, 4096, 4097];

/// Deterministic pseudo-image covering both signs and magnitudes O(1).
fn image(n: usize, seed: f32) -> Vec<f32> {
    (0..n)
        .map(|i| seed + ((i as f32) * 0.0137).sin() * 1.7)
        .collect()
}

fn noise(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i as f32) * 0.0071).cos() * 0.4).collect()
}

fn t_emb() -> Vec<f32> {
    (0..8).map(|i| (i as f32) * 0.1 - 0.25).collect()
}

/// Monotone integer ordering of f32s (negative values map below
/// positives, ±0 coincide) so ULP distance is a subtraction.
fn ord(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7fff_ffff) as i64)
    } else {
        b as i64
    }
}

fn ulps(a: f32, b: f32) -> i64 {
    (ord(a) - ord(b)).abs()
}

#[test]
fn tanh_poly_within_8_ulp_of_libm() {
    // Dense sweep across the full useful range (the approximation clamps
    // near ±8, where f32 tanh is within a few ULP of ±1 anyway), plus
    // the branch-boundary specials.
    let mut worst = 0i64;
    for i in -16000..=16000i32 {
        let x = i as f32 * 0.00125;
        let d = ulps(simd::tanh_poly(x), x.tanh());
        worst = worst.max(d);
        assert!(d <= 8, "tanh_poly({x}) off by {d} ULP");
    }
    for &x in &[
        0.0f32,
        -0.0,
        1e-8,
        -1e-8,
        3e-4,
        -3e-4,
        5e-4,
        7.99,
        -7.99,
        8.0,
        -8.0,
        20.0,
        -20.0,
        f32::MIN_POSITIVE,
    ] {
        let d = ulps(simd::tanh_poly(x), x.tanh());
        assert!(d <= 8, "tanh_poly({x}) off by {d} ULP");
    }
    // the approximation is actually good, not just barely passing
    assert!(worst <= 8, "worst-case drift {worst} ULP");
}

#[test]
fn step_kernel_scalar_vs_simd_bounded_at_awkward_lengths() {
    let emb = t_emb();
    let c = (1.01f32, 0.4, 0.1);
    let g = (0.9f32, 0.3);
    for &n in LENS {
        let nz = noise(n);
        let mut a = image(n, 0.2);
        let mut b = a.clone();
        step_kernel_scalar(&mut a, &emb, c, &nz, g);
        step_kernel_simd(&mut b, &emb, c, &nz, g);
        assert_eq!(a.len(), b.len());
        for (i, (&va, &vb)) in a.iter().zip(&b).enumerate() {
            assert!(va.is_finite() && vb.is_finite(), "n={n} elem {i} not finite");
            // the only divergence is the polynomial tanh (≤ 8 ULP of a
            // value in [-1, 1]), scaled by c1*c2 — comfortably under
            // 1e-5 in absolute terms for O(1) coefficients
            assert!(
                (va - vb).abs() <= 1e-5,
                "n={n} elem {i}: scalar {va} vs simd {vb}"
            );
        }
    }
}

#[test]
fn step_kernel_bit_identical_when_tanh_term_vanishes() {
    // With c2 = 0 the tanh output is multiplied away and every remaining
    // op (g0*x + bias + pos, c1*(x - 0) + sigma*noise) must follow the
    // exact same IEEE expression tree in both builds — any reassociation
    // or FMA contraction in the SIMD path shows up here as a bit flip.
    let emb = t_emb();
    let c = (1.01f32, 0.0, 0.1);
    let g = (0.9f32, 0.3);
    for &n in LENS {
        let nz = noise(n);
        let mut a = image(n, -0.3);
        let mut b = a.clone();
        step_kernel_scalar(&mut a, &emb, c, &nz, g);
        step_kernel_simd(&mut b, &emb, c, &nz, g);
        assert_eq!(a, b, "n={n}: non-tanh ops diverged between builds");
    }
}

#[test]
fn step_dispatch_matches_portable_bitwise() {
    // The runtime-dispatched step_kernel (AVX2 where available) must be
    // bit-identical to its portable body — "same build, different host"
    // never changes served bits.
    let pos = {
        let mut p = [0.0f32; 31];
        for (k, v) in p.iter_mut().enumerate() {
            *v = (k as f32) * 0.021 - 0.31;
        }
        p
    };
    for &n in LENS {
        let nz = noise(n);
        let mut a = image(n, 0.45);
        let mut b = a.clone();
        simd::step_kernel(&mut a, &nz, &pos, 0.9, 0.12, 1.01, 0.4, 0.1);
        simd::step_kernel_portable(&mut b, &nz, &pos, 0.9, 0.12, 1.01, 0.4, 0.1);
        assert_eq!(a, b, "n={n}: dispatch and portable step paths diverged");
    }
}

#[test]
fn classify_scalar_vs_simd_bit_identical_at_awkward_lengths() {
    let g = (0.9f32, 0.3);
    for &n in LENS {
        let x = image(n, 0.1);
        for &passes in &[1usize, 3] {
            let mut la = vec![0.0f32; 10];
            let mut lb = vec![0.0f32; 10];
            classify_row_scalar(&x, g, passes, 10, &mut la);
            classify_row_simd(&x, g, passes, 10, &mut lb);
            assert_eq!(la, lb, "n={n} passes={passes}: classify diverged");
        }
    }
}

#[test]
fn classify_dispatch_matches_portable_bitwise() {
    let wtab = {
        let mut w = [0.0f32; 31];
        for (k, v) in w.iter_mut().enumerate() {
            *v = (k as f32) * 0.017 - 0.26;
        }
        w
    };
    for &n in LENS {
        let x = image(n, -0.2);
        let mut acc_a = vec![0.0f64; 10];
        let mut acc_b = vec![0.0f64; 10];
        simd::classify_accumulate(&x, &wtab, 3, 10, &mut acc_a);
        simd::classify_accumulate_portable(&x, &wtab, 3, 10, &mut acc_b);
        assert_eq!(acc_a, acc_b, "n={n}: classify accumulate paths diverged");
    }
}

/// Deterministic i16 vector touching the overflow-critical extremes: an
/// all-`i16::MIN` pair per 8-wide chunk would overflow a pairwise-i32
/// reduction (`_mm256_madd_epi16`), so keeping extremes in the data
/// pins the widening accumulation.
fn ivec(n: usize, salt: i32) -> Vec<i16> {
    (0..n)
        .map(|i| match i % 11 {
            0 => i16::MIN,
            1 => i16::MAX,
            _ => ((i as i32)
                .wrapping_mul(2654435761u32 as i32)
                .wrapping_add(salt)
                % 30000) as i16,
        })
        .collect()
}

#[test]
fn dot_wide_exact_at_awkward_lengths() {
    for &n in LENS {
        let a = ivec(n, 17);
        let b = ivec(n, -5);
        // ground truth: plain widening scalar accumulation
        let want: i64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| (x as i32 * y as i32) as i64)
            .sum();
        assert_eq!(simd::dot_wide_portable(&a, &b), want, "n={n} portable");
        assert_eq!(simd::dot_wide_i16(&a, &b), want, "n={n} dispatch");
        let fa: Vec<Fixed> = a.iter().map(|&v| Fixed(v)).collect();
        let fb: Vec<Fixed> = b.iter().map(|&v| Fixed(v)).collect();
        assert_eq!(simd::dot_wide_fixed(&fa, &fb), want, "n={n} fixed");
    }
    // extreme square at every lane: (i16::MIN)^2 * 8 per chunk must not
    // saturate anything on the way to i64
    let worst = vec![i16::MIN; 4096];
    let want = (i16::MIN as i32 * i16::MIN as i32) as i64 * 4096;
    assert_eq!(simd::dot_wide_i16(&worst, &worst), want);
}
