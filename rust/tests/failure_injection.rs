//! Failure injection: every user-facing loading path must fail *cleanly*
//! (typed errors with actionable messages), never panic or UB.

use std::io::Write;
use std::path::PathBuf;

use sf_mmcn::config::{RunConfig, ServeConfig};
use sf_mmcn::coordinator::UnetParams;
use sf_mmcn::runtime::{ArtifactStore, Executor};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sfmmcn_fi_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn malformed_hlo_text_is_an_error_not_a_crash() {
    let d = tmpdir("badhlo");
    let p = d.join("bad.hlo.txt");
    let mut f = std::fs::File::create(&p).unwrap();
    writeln!(f, "HloModule this_is_not_valid {{ garbage").unwrap();
    let mut exe = Executor::new().unwrap();
    let err = exe.load_hlo_text("bad", &p);
    assert!(err.is_err(), "parser must reject garbage");
}

#[test]
fn truncated_hlo_text_is_an_error() {
    // take a valid artifact and truncate it mid-instruction
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve("sf_block_16") else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let text = std::fs::read_to_string(&spec.path).unwrap();
    let d = tmpdir("trunc");
    let p = d.join("trunc.hlo.txt");
    std::fs::write(&p, &text[..text.len() / 3]).unwrap();
    let mut exe = Executor::new().unwrap();
    assert!(exe.load_hlo_text("trunc", &p).is_err());
}

#[test]
fn wrong_arity_execution_fails_cleanly() {
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve("sf_block_16") else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut exe = Executor::new().unwrap();
    if let Err(e) = exe.load_hlo_text("sf_block", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return;
    }
    // artifact wants 4 inputs; pass 1
    let x = sf_mmcn::runtime::TensorBuf::zeros(&[8, 16, 16]);
    assert!(exe.run("sf_block", &[x]).is_err());
}

#[test]
fn unknown_artifact_name_is_an_error() {
    let exe = Executor::new().unwrap();
    let x = sf_mmcn::runtime::TensorBuf::zeros(&[1]);
    let err = exe.run("never-loaded", &[x]).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
}

#[test]
fn params_manifest_dimension_garbage() {
    let d = tmpdir("manifest");
    std::fs::write(d.join("p.manifest"), "a 2 x\n").unwrap();
    std::fs::write(d.join("p.bin"), [0u8; 8]).unwrap();
    let err = UnetParams::load(&d, "p").unwrap_err().to_string();
    assert!(err.contains("bad dims"), "{err}");
}

#[test]
fn config_parse_errors_are_actionable() {
    let err = RunConfig::from_toml("[run\nmodel=\"vgg16\"").unwrap_err().to_string();
    assert!(err.contains("line 1"), "{err}");
    let err = ServeConfig::from_toml("[serve]\nworkers = 0").unwrap_err().to_string();
    assert!(err.contains("workers"), "{err}");
}

#[test]
fn missing_config_file_is_an_error() {
    assert!(RunConfig::from_file(std::path::Path::new("/nonexistent/cfg.toml")).is_err());
}

#[test]
fn serve_with_missing_artifact_fails_at_construction() {
    let cfg = ServeConfig {
        artifact: "no_such_artifact".into(),
        ..ServeConfig::default()
    };
    let store = ArtifactStore::new("artifacts");
    let msg = match sf_mmcn::coordinator::DiffusionServer::new(cfg, &store) {
        Ok(_) => panic!("missing artifact must fail at construction"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn tensor_shape_mismatches_rejected_at_input_edge() {
    use sf_mmcn::runtime::TensorBuf;
    assert!(TensorBuf::new(vec![2, 3], vec![0.0; 5]).is_err());
    assert!(TensorBuf::new(vec![2, 3], vec![0.0; 6]).is_ok());
}
