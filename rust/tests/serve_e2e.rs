//! End-to-end serving integration tests: the full coordinator path
//! (bounded admission queue → fair batcher → worker lanes → DDPM loop)
//! on small workloads.
//!
//! Two tiers:
//!
//! * **Native tests** run unconditionally — the serving stack executes on
//!   the host-CPU surrogate runtime with synthetic parameters, so tier-1
//!   exercises admission control, batching, pipelining, fairness, and
//!   determinism offline.
//! * **PJRT tests** additionally require `make artifacts` *and* a
//!   PJRT-enabled build (`--features pjrt` against the real xla crate);
//!   each skips cleanly when either is missing.

use std::sync::Arc;
use std::time::Duration;

use sf_mmcn::config::{ModelChoice, ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    workload, AdmissionError, ClassifyRequest, DenoiseRequest, DenoiseResult, DiffusionServer,
    FaultSpec,
};
use sf_mmcn::runtime::{ArtifactStore, Executor};
use sf_mmcn::sim::energy::CAL_40NM;

// ---------------------------------------------------------------- native

/// Offline server on the native surrogate backend (no artifacts needed).
fn native_server(cfg: ServeConfig) -> DiffusionServer {
    let store = ArtifactStore::new("artifacts");
    DiffusionServer::new(cfg, &store).expect("native backend needs no artifacts")
}

fn native_cfg(steps: usize, workers: usize, max_batch: usize, batched: bool) -> ServeConfig {
    ServeConfig {
        steps,
        workers,
        max_batch,
        batched,
        requests: 0,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        pipeline: true,
        chunk: 0,
        pooled: true,
        ..ServeConfig::default()
    }
}

fn reqs(n: u64, steps: usize) -> Vec<DenoiseRequest> {
    (0..n)
        .map(|i| DenoiseRequest::new(i, 500 + i, steps))
        .collect()
}

#[test]
fn native_serves_all_requests_exactly_once() {
    let s = native_server(native_cfg(4, 2, 4, true));
    let (results, metrics) = s.serve(reqs(5, 4)).unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(metrics.requests_done, 5);
    assert_eq!(metrics.steps_done, 20);
    assert_eq!(metrics.request_latency.count(), 5);
    assert_eq!(metrics.step_latency.count(), 20);
    assert!(metrics.dispatches >= 1);
    assert_eq!(metrics.batch_items, 5, "each request in exactly one dispatch");
    // the serve() wrapper goes through the admission queue now
    assert_eq!(metrics.admission.offered, 5);
    assert_eq!(metrics.admission.admitted, 5);
    assert_eq!(metrics.admission.rejected_total(), 0);
    assert_eq!(metrics.admission.queue_depth, 0, "drained at shutdown");
    assert_eq!(metrics.e2e_latency.count(), 5);
}

#[test]
fn native_batched_bit_identical_to_per_request_path() {
    // The ISSUE 3 determinism contract: for the same seeds, the batched
    // pipelined path must produce bit-identical images to the
    // step-at-a-time per-request path.
    let s_seq = native_server(native_cfg(5, 1, 1, false));
    let (mut r_seq, _) = s_seq.serve(reqs(6, 5)).unwrap();
    let s_bat = native_server(native_cfg(5, 2, 4, true));
    let (mut r_bat, m) = s_bat.serve(reqs(6, 5)).unwrap();
    r_seq.sort_by_key(|r| r.id);
    r_bat.sort_by_key(|r| r.id);
    for (a, b) in r_seq.iter().zip(&r_bat) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.image.data, b.image.data,
            "request {} diverged between batched and per-request paths",
            a.id
        );
        assert_eq!(a.steps, 5);
        assert_eq!(b.steps, 5);
    }
    assert!(
        m.batch_occupancy() > 1.0,
        "batched mode must actually batch (occupancy {})",
        m.batch_occupancy()
    );
}

#[test]
fn native_chunked_dispatch_bit_identical() {
    // Chunked timestep dispatch (several [B, ...] executions per request)
    // must not change the math, only the dispatch count.
    let whole = native_server(native_cfg(5, 1, 4, true));
    let (mut r_whole, m_whole) = whole.serve(reqs(4, 5)).unwrap();
    let mut cfg = native_cfg(5, 1, 4, true);
    cfg.chunk = 2;
    let chunked = native_server(cfg);
    let (mut r_chunk, m_chunk) = chunked.serve(reqs(4, 5)).unwrap();
    r_whole.sort_by_key(|r| r.id);
    r_chunk.sort_by_key(|r| r.id);
    for (a, b) in r_whole.iter().zip(&r_chunk) {
        assert_eq!(a.image.data, b.image.data, "request {} diverged", a.id);
    }
    assert!(
        m_chunk.dispatches > m_whole.dispatches,
        "chunk=2 over 5 steps must dispatch more often ({} vs {})",
        m_chunk.dispatches,
        m_whole.dispatches
    );
}

// ------------------------------------------------- pooled hot path (ISSUE 4)

/// Sort-by-id helper for output comparisons.
fn by_id(mut results: Vec<DenoiseResult>) -> Vec<DenoiseResult> {
    results.sort_by_key(|r| r.id);
    results
}

#[test]
fn pooled_bit_identical_to_allocating_batched_and_per_request() {
    // ISSUE 4 acceptance: the pooled zero-allocation path must be
    // bit-identical to the PR 2 allocating batched path AND to the
    // step-at-a-time per-request path, for the same seeds.
    let pooled = native_server(native_cfg(5, 2, 4, true));
    let (r_pool, m_pool) = pooled.serve(reqs(6, 5)).unwrap();
    let r_pool = by_id(r_pool);
    let mut cfg = native_cfg(5, 2, 4, true);
    cfg.pooled = false;
    let unpooled = native_server(cfg);
    let (r_alloc, m_alloc) = unpooled.serve(reqs(6, 5)).unwrap();
    let r_alloc = by_id(r_alloc);
    let seq = native_server(native_cfg(5, 1, 1, false));
    let (r_seq, _) = seq.serve(reqs(6, 5)).unwrap();
    let r_seq = by_id(r_seq);
    for ((p, a), s) in r_pool.iter().zip(&r_alloc).zip(&r_seq) {
        assert_eq!(p.id, a.id);
        assert_eq!(p.id, s.id);
        assert_eq!(
            p.image.data, a.image.data,
            "request {} diverged between pooled and allocating batched paths",
            p.id
        );
        assert_eq!(
            p.image.data, s.image.data,
            "request {} diverged between pooled and per-request paths",
            p.id
        );
    }
    // the pooled session recycles; the disabled pool never hits
    assert!(m_pool.pool_hits > 0, "pooled run must reuse slabs");
    assert_eq!(m_alloc.pool_hits, 0, "disabled pool must never hit");
    assert!(m_alloc.pool_misses > 0, "disabled pool allocates every lease");
    assert!(
        m_pool.pool_bytes_leased > 0 && m_alloc.pool_bytes_leased > 0,
        "both modes account leased bytes"
    );
}

#[test]
fn pooled_chunked_bit_identical_to_allocating() {
    // Chunked dispatch exercises the partial-chunk scratch leases
    // (t_emb/coeff/noise gathers) on top of the rotating image slabs.
    let mut pooled_cfg = native_cfg(5, 1, 4, true);
    pooled_cfg.chunk = 2;
    let pooled = native_server(pooled_cfg);
    let (r_pool, _) = pooled.serve(reqs(4, 5)).unwrap();
    let r_pool = by_id(r_pool);
    let mut alloc_cfg = native_cfg(5, 1, 4, true);
    alloc_cfg.chunk = 2;
    alloc_cfg.pooled = false;
    let alloc = native_server(alloc_cfg);
    let (r_alloc, _) = alloc.serve(reqs(4, 5)).unwrap();
    let r_alloc = by_id(r_alloc);
    // and the whole-request pooled path for the same workload
    let whole = native_server(native_cfg(5, 1, 4, true));
    let (r_whole, _) = whole.serve(reqs(4, 5)).unwrap();
    let r_whole = by_id(r_whole);
    for ((p, a), w) in r_pool.iter().zip(&r_alloc).zip(&r_whole) {
        assert_eq!(p.image.data, a.image.data, "request {} diverged (chunked)", p.id);
        assert_eq!(p.image.data, w.image.data, "request {} diverged (vs whole)", p.id);
    }
}

#[test]
fn pooled_mixed_step_counts_bit_identical_to_allocating() {
    // Mixed per-request steps mean differently-sized slabs per batch —
    // the best-fit free list must still hand back correct (zeroed)
    // storage for every size.
    let mixed = |pooled: bool| {
        let mut all = reqs(3, 6);
        all.extend((3..6).map(|i| DenoiseRequest::new(i, 500 + i, 2)));
        let mut cfg = native_cfg(6, 2, 4, true);
        cfg.pooled = pooled;
        let s = native_server(cfg);
        let (results, m) = s.serve(all).unwrap();
        (by_id(results), m)
    };
    let (r_pool, _) = mixed(true);
    let (r_alloc, _) = mixed(false);
    for (p, a) in r_pool.iter().zip(&r_alloc) {
        assert_eq!(p.id, a.id);
        assert_eq!(p.steps, a.steps);
        assert_eq!(
            p.image.data, a.image.data,
            "request {} diverged between pooled and allocating mixed-step paths",
            p.id
        );
    }
}

#[test]
fn pool_misses_stay_flat_after_warmup() {
    // Steady-state zero-allocation contract: on a single worker serving
    // many same-shape batches, only the warmup working set allocates —
    // a miss count that grows with the batch count means slabs are not
    // recycling. 16 requests in batches of 2 = 8 batches; each batch
    // leases 5 slabs (4 prep + 1 rotating image slab in whole-request
    // mode), so a non-recycling pool would miss ~40 times.
    let s = native_server(native_cfg(3, 1, 2, true));
    let (_, m) = s.serve(reqs(16, 3)).unwrap();
    assert!(
        m.pool_misses <= 16,
        "pool misses must be bounded by the warmup working set, got {} \
         (hits {})",
        m.pool_misses,
        m.pool_hits
    );
    assert!(
        m.pool_hits > m.pool_misses,
        "steady state must be dominated by free-list hits ({} hits / {} misses)",
        m.pool_hits,
        m.pool_misses
    );
}

#[test]
fn native_deterministic_per_seed() {
    let s = native_server(native_cfg(3, 1, 2, true));
    let req = |seed| vec![DenoiseRequest::new(0, seed, 3)];
    let (r1, _) = s.serve(req(42)).unwrap();
    let (r2, _) = s.serve(req(42)).unwrap();
    let (r3, _) = s.serve(req(43)).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same image");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
}

#[test]
fn native_fair_batcher_spreads_work_across_workers() {
    // Starvation regression test: with max_batch >= the whole queue, the
    // old greedy batcher let one worker swallow all 8 requests. The fair
    // batcher divides by worker count (first grab <= ceil(8/2) = 4), and
    // the start barrier plus the serve() standing-start gate keep any
    // lane from draining before all exist.
    let s = native_server(native_cfg(6, 2, 8, true));
    let (results, m) = s.serve(reqs(8, 6)).unwrap();
    assert_eq!(results.len(), 8);
    assert_eq!(m.per_worker_requests.len(), 2);
    assert_eq!(m.per_worker_requests.iter().sum::<usize>(), 8);
    assert!(
        m.per_worker_requests.iter().all(|&c| c >= 1),
        "a worker starved: {:?}",
        m.per_worker_requests
    );
    assert!(
        m.per_worker_requests.iter().all(|&c| c <= 7),
        "a worker swallowed the queue: {:?}",
        m.per_worker_requests
    );
}

#[test]
fn native_mixed_step_counts_honored_per_request() {
    // ISSUE 3 satellite: per-request steps must be honored (the fused
    // path used to ignore them). Mixed-step workloads batch in same-step
    // groups and every result reports its own step count.
    let mut all = reqs(3, 6);
    all.extend((3..6).map(|i| DenoiseRequest::new(i, 500 + i, 2)));
    let s = native_server(native_cfg(6, 2, 4, true));
    let (mut results, m) = s.serve(all).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 6);
    for r in &results[..3] {
        assert_eq!(r.steps, 6, "request {}", r.id);
    }
    for r in &results[3..] {
        assert_eq!(r.steps, 2, "request {}", r.id);
    }
    assert_eq!(m.steps_done, 3 * 6 + 3 * 2);

    // and a 2-step request batched here must equal the same request run
    // solo through the per-request path (same 6-step schedule)
    let s2 = native_server(native_cfg(6, 1, 1, false));
    let (r2, _) = s2.serve(vec![DenoiseRequest::new(3, 503, 2)]).unwrap();
    let mixed = results.iter().find(|r| r.id == 3).unwrap();
    assert_eq!(mixed.image.data, r2[0].image.data);
}

#[test]
fn native_rejects_out_of_range_steps() {
    let s = native_server(native_cfg(4, 1, 2, false));
    let bad = vec![DenoiseRequest::new(9, 1, 99)];
    let err = s.serve(bad).unwrap_err().to_string();
    assert!(err.contains("steps 99"), "{err}");
    assert!(err.contains("out of range"), "{err}");
}

#[test]
fn native_fused_honors_per_request_steps() {
    // fused mode on the native backend runs the request's own step count
    let mut cfg = native_cfg(6, 1, 1, false);
    cfg.fused = true;
    let s = native_server(cfg);
    let (r, m) = s.serve(vec![DenoiseRequest::new(0, 77, 4)]).unwrap();
    assert_eq!(r[0].steps, 4);
    assert_eq!(m.steps_done, 4);
    // and matches the step-at-a-time result bit for bit
    let s_step = native_server(native_cfg(6, 1, 1, false));
    let (r_step, _) = s_step.serve(vec![DenoiseRequest::new(0, 77, 4)]).unwrap();
    assert_eq!(r[0].image.data, r_step[0].image.data);
}

#[test]
fn native_pipeline_off_is_equivalent() {
    let mut cfg = native_cfg(4, 2, 4, true);
    cfg.pipeline = false;
    let s_inline = native_server(cfg);
    let (mut r_inline, m_inline) = s_inline.serve(reqs(6, 4)).unwrap();
    let s_pipe = native_server(native_cfg(4, 2, 4, true));
    let (mut r_pipe, _) = s_pipe.serve(reqs(6, 4)).unwrap();
    r_inline.sort_by_key(|r| r.id);
    r_pipe.sort_by_key(|r| r.id);
    for (a, b) in r_inline.iter().zip(&r_pipe) {
        assert_eq!(a.image.data, b.image.data);
    }
    assert_eq!(m_inline.pipeline_stalls, 0, "no pipeline, no stalls");
}

#[test]
fn native_cosim_uses_micro_sim_for_batched_traffic() {
    let mut cfg = native_cfg(2, 1, 2, true);
    cfg.cosim = true;
    let s = native_server(cfg);
    let (_, metrics) = s.serve(reqs(2, 2)).unwrap();
    let rep = metrics.sim_report(&CAL_40NM, 8).expect("cosim enabled");
    assert!(rep.cycles > 0);
    assert!(rep.u_pe > 0.0 && rep.u_pe <= 1.0);
    // 2 requests x 2 steps: counts are per-step multiples
    let counts = metrics.sim_counts.unwrap();
    assert_eq!(counts.cycles % 4, 0, "4 identical steps merged");
}

#[test]
fn native_outputs_bounded() {
    let cfg = native_cfg(8, 2, 4, true);
    let s = native_server(cfg.clone());
    let (results, _) = s.serve(workload(&cfg, cfg.seed, 0..3)).unwrap();
    for r in &results {
        let max = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(
            max < 20.0,
            "request {} diverged (max |px| = {max})",
            r.id
        );
    }
}

// ---------------------------------------------- multi-mode (ISSUE 7)

/// Native config carrying a balanced three-model mix.
fn mixed_cfg(steps: usize, workers: usize, max_batch: usize, batched: bool) -> ServeConfig {
    let mut cfg = native_cfg(steps, workers, max_batch, batched);
    cfg.model_mix = "unet:1,resnet18:1,vgg16:1".into();
    cfg
}

#[test]
fn mixed_workload_batched_bit_identical_to_per_request() {
    // ISSUE 7 acceptance: a mixed U-net + ResNet-18 + VGG-16 workload
    // through the batched path must be bit-identical to the same
    // requests through the per-request path.
    let cfg_b = mixed_cfg(4, 2, 4, true);
    let reqs_b = workload(&cfg_b, cfg_b.seed, 0..9);
    let (r_bat, m) = native_server(cfg_b).serve(reqs_b).unwrap();
    let r_bat = by_id(r_bat);
    let cfg_s = mixed_cfg(4, 1, 1, false);
    let reqs_s = workload(&cfg_s, cfg_s.seed, 0..9);
    let (r_seq, _) = native_server(cfg_s).serve(reqs_s).unwrap();
    let r_seq = by_id(r_seq);
    assert_eq!(r_bat.len(), 9);
    for (a, b) in r_bat.iter().zip(&r_seq) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.model, b.model);
        assert_eq!(
            a.image.data, b.image.data,
            "request {} ({}) diverged between batched and per-request paths",
            a.id,
            a.model.name()
        );
    }
    // per-mode result shapes: U-net images vs classification logits
    for r in &r_bat {
        match r.model {
            ModelChoice::Unet => {
                assert_eq!(r.steps, 4);
                assert_eq!(r.image.shape.len(), 3);
            }
            _ => {
                assert_eq!(r.steps, 1, "classification is one logical step");
                assert_eq!(r.image.shape, vec![10], "logits over 10 classes");
            }
        }
    }
    // the batcher invariant and the per-model accounting
    assert_eq!(m.cross_model_batches, 0, "a batch never mixes models");
    let pm = &m.per_model;
    assert_eq!(pm[ModelChoice::Unet.index()].requests_done, 3);
    assert_eq!(pm[ModelChoice::Unet.index()].steps_done, 12);
    assert_eq!(pm[ModelChoice::Resnet18.index()].requests_done, 3);
    assert_eq!(pm[ModelChoice::Resnet18.index()].steps_done, 3);
    assert_eq!(pm[ModelChoice::Vgg16.index()].requests_done, 3);
    assert_eq!(pm[ModelChoice::Vgg16.index()].steps_done, 3);
    for row in pm {
        assert_eq!(row.e2e_latency.count(), 3, "{}", row.model.name());
        assert_eq!(row.requests_failed, 0);
    }
    assert_eq!(m.requests_done, 9);
    assert_eq!(m.steps_done, 12 + 3 + 3);
    assert!(m.is_multi_mode());
    assert!(m.render().contains("per-model:"), "{}", m.render());
}

#[test]
fn mixed_classification_deterministic_per_seed() {
    let s = native_server(mixed_cfg(2, 1, 2, true));
    let req = |seed| vec![ClassifyRequest::new(0, seed, ModelChoice::Resnet18)];
    let (r1, _) = s.serve(req(42)).unwrap();
    let (r2, _) = s.serve(req(42)).unwrap();
    let (r3, _) = s.serve(req(43)).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same logits");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
    assert!(
        r1[0].image.data.iter().all(|v| v.is_finite()),
        "logits stay finite"
    );
}

#[test]
fn mixed_cosim_reports_per_mode_counts() {
    // Per-mode co-simulation: each mode's accelerator counts land on its
    // own row, the rows partition the aggregate, and each row prices to
    // a positive area-efficiency FoM (GOPs/mm²). The per-request path
    // keeps the fast analytic model, so this stays cheap in debug.
    let mut cfg = mixed_cfg(2, 1, 1, false);
    cfg.cosim = true;
    let reqs = workload(&cfg, cfg.seed, 0..6);
    let (_, m) = native_server(cfg).serve(reqs).unwrap();
    let totals = m.sim_counts.expect("cosim enabled");
    assert!(totals.cycles > 0);
    let mut cycle_sum = 0u64;
    for row in &m.per_model {
        let c = row.sim_counts.expect("every mode saw traffic");
        assert!(c.cycles > 0, "{}", row.model.name());
        cycle_sum += c.cycles;
        let rep = row.sim_report(&CAL_40NM, 8).unwrap();
        assert!(
            rep.gops_per_mm2 > 0.0,
            "{} prices a positive FoM",
            row.model.name()
        );
    }
    assert_eq!(cycle_sum, totals.cycles, "per-mode counts partition the total");
}

#[test]
fn classify_without_provisioning_errors_with_guidance() {
    // A classification request on a server whose model_mix never named
    // the model must resolve its ticket with an error that points at the
    // provisioning knob — on the batched and per-request paths alike.
    for batched in [true, false] {
        let handle = native_server(native_cfg(3, 1, 2, batched)).start();
        let t = handle
            .submit(ClassifyRequest::new(0, 1, ModelChoice::Vgg16))
            .unwrap();
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("not provisioned"), "batched={batched}: {err}");
        assert!(err.contains("model_mix"), "batched={batched}: {err}");
        let m = handle.shutdown().unwrap();
        assert_eq!(m.requests_failed, 1);
        assert_eq!(
            m.per_model[ModelChoice::Vgg16.index()].requests_failed,
            1,
            "the failure lands on the model's own row"
        );
    }
}

// ------------------------------------------- streaming session (ISSUE 5)

#[test]
fn session_submit_wait_matches_serve() {
    // The session API must produce the same bits as the serve() wrapper
    // (which itself matches the historical drain).
    let cfg = native_cfg(4, 2, 4, true);
    let (r_serve, _) = native_server(cfg.clone()).serve(reqs(6, 4)).unwrap();
    let r_serve = by_id(r_serve);
    let handle = native_server(cfg).start();
    let tickets: Vec<_> = reqs(6, 4)
        .into_iter()
        .map(|r| handle.submit(r).expect("queue has room"))
        .collect();
    let mut r_sess: Vec<DenoiseResult> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    r_sess.sort_by_key(|r| r.id);
    let metrics = handle.shutdown().unwrap();
    assert_eq!(r_sess.len(), 6);
    for (a, b) in r_sess.iter().zip(&r_serve) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.image.data, b.image.data,
            "request {} diverged between session and serve() paths",
            a.id
        );
    }
    assert_eq!(metrics.requests_done, 6);
    assert_eq!(metrics.admission.admitted, 6);
    assert_eq!(metrics.e2e_latency.count(), 6);
}

#[test]
fn session_try_submit_sheds_load_when_queue_full() {
    // Bounded admission: with a depth-1 queue and one worker chewing
    // through multi-step requests, a rapid burst of try_submit calls
    // must bounce off QueueFull instead of growing the queue. (The
    // worker cannot finish a 16-step request between two back-to-back
    // submissions, so at least one rejection is guaranteed.)
    let mut cfg = native_cfg(16, 1, 1, true);
    cfg.queue_depth = 1;
    // no prefetching prep stage: the lane absorbs exactly one executing
    // request beyond the queue, so the rejection count is deterministic
    cfg.pipeline = false;
    let handle = native_server(cfg).start();
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for r in reqs(6, 16) {
        match handle.try_submit(r) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(rejected >= 1, "a depth-1 queue must shed a 6-request burst");
    let snapshot = handle.metrics_snapshot();
    assert_eq!(snapshot.admission.rejected_queue_full, rejected as u64);
    assert_eq!(snapshot.admission.offered, 6);
    // every admitted ticket still resolves
    let n_admitted = tickets.len();
    for t in tickets {
        t.wait().unwrap();
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, n_admitted);
    assert_eq!(metrics.admission.admitted, n_admitted as u64);
}

#[test]
fn session_rejects_expired_deadline_at_admission() {
    let handle = native_server(native_cfg(3, 1, 2, true)).start();
    let mut r = DenoiseRequest::new(0, 1, 3);
    r.deadline = Some(Duration::ZERO);
    assert_eq!(
        handle.try_submit(r).unwrap_err(),
        AdmissionError::Deadline
    );
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.admission.rejected_deadline, 1);
    assert_eq!(metrics.admission.admitted, 0);
}

#[test]
fn session_expires_queued_request_behind_slow_work() {
    // A short-deadline request stuck behind ~100 device dispatches on a
    // single non-prefetching lane must expire in the queue (resolved
    // with an error at batch-formation time), not execute. chunk = 1
    // forces one dispatch per step, and every dispatch pays the
    // surrogate's whole-parameter digest (~100 µs+), so the blockers
    // hold the lane for tens of milliseconds — far past the deadline.
    let mut cfg = native_cfg(50, 1, 1, true);
    cfg.pipeline = false;
    cfg.chunk = 1;
    let handle = native_server(cfg).start();
    let blockers: Vec<_> = reqs(2, 50)
        .into_iter()
        .map(|r| handle.submit(r).expect("room"))
        .collect();
    let mut doomed = DenoiseRequest::new(9, 9, 2);
    doomed.deadline = Some(Duration::from_millis(2));
    let doomed_ticket = handle.submit(doomed).expect("room");
    let err = doomed_ticket.wait().unwrap_err().to_string();
    assert!(err.contains("expired"), "{err}");
    for t in blockers {
        t.wait().expect("blockers run to completion");
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.admission.expired, 1);
    assert_eq!(metrics.requests_done, 2);
}

#[test]
fn session_priority_preempts_queue_order() {
    // One worker, no prefetch: while a 50-step blocker executes, a
    // low-priority and then a high-priority request are queued. The
    // high-priority one must run (and resolve) first even though it was
    // submitted last. chunk = 1 makes every request take 50 dispatches
    // (milliseconds), so "low is still pending when high resolves" has
    // a wide timing margin.
    let mut cfg = native_cfg(50, 1, 1, true);
    cfg.pipeline = false;
    cfg.priorities = 3;
    cfg.chunk = 1;
    let handle = native_server(cfg).start();
    let blocker = handle.submit(DenoiseRequest::new(0, 1, 50)).unwrap();
    let mut low = DenoiseRequest::new(1, 2, 50);
    low.priority = 2;
    let mut low_ticket = handle.submit(low).unwrap();
    let mut high = DenoiseRequest::new(2, 3, 50);
    high.priority = 0;
    let mut high_ticket = handle.submit(high).unwrap();
    // wait for the high-priority result, then check the low one is
    // still unresolved (it runs after, on the single lane)
    loop {
        if let Some(r) = high_ticket.try_wait() {
            r.expect("high-priority request completes");
            break;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    assert!(
        low_ticket.try_wait().is_none(),
        "low-priority request must still be pending when high resolves"
    );
    blocker.wait().unwrap();
    // low eventually completes too
    loop {
        if let Some(r) = low_ticket.try_wait() {
            r.expect("low-priority request completes");
            break;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 3);
}

#[test]
fn session_shutdown_drains_all_admitted_tickets() {
    // shutdown() must resolve every admitted ticket — the lanes drain
    // the backlog instead of abandoning it.
    let handle = native_server(native_cfg(3, 2, 4, true)).start();
    let tickets: Vec<_> = reqs(10, 3)
        .into_iter()
        .map(|r| handle.submit(r).expect("room"))
        .collect();
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 10);
    assert_eq!(metrics.admission.queue_depth, 0);
    for t in tickets {
        t.wait().expect("admitted ticket resolved by the drain");
    }
}

#[test]
fn session_rejects_submissions_after_begin_shutdown() {
    let handle = native_server(native_cfg(3, 1, 2, true)).start();
    let t = handle.submit(DenoiseRequest::new(0, 5, 3)).unwrap();
    handle.begin_shutdown();
    assert_eq!(
        handle.try_submit(DenoiseRequest::new(1, 6, 3)).unwrap_err(),
        AdmissionError::ShuttingDown
    );
    assert_eq!(
        handle.submit(DenoiseRequest::new(2, 7, 3)).unwrap_err(),
        AdmissionError::ShuttingDown,
        "blocking submit refuses too"
    );
    t.wait().expect("pre-shutdown request still drains");
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.admission.rejected_shutdown, 2);
    assert_eq!(metrics.requests_done, 1);
}

#[test]
fn session_metrics_snapshot_reads_live_counters() {
    let cfg = native_cfg(3, 2, 4, true);
    let handle = native_server(cfg).start();
    let before = handle.metrics_snapshot();
    assert_eq!(before.admission.offered, 0);
    assert_eq!(before.requests_done, 0);
    let tickets: Vec<_> = reqs(4, 3)
        .into_iter()
        .map(|r| handle.submit(r).expect("room"))
        .collect();
    let mid = handle.metrics_snapshot();
    assert_eq!(mid.admission.admitted, 4);
    for t in tickets {
        t.wait().unwrap();
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 4);
    assert!(metrics.wall >= mid.wall, "wall clock advances");
    let rendered = metrics.render();
    assert!(rendered.contains("admission:"), "{rendered}");
    assert!(rendered.contains("e2e latency"), "{rendered}");
}

#[test]
fn session_streaming_bit_identical_to_serve_under_trickled_arrivals() {
    // Trickled arrivals change batch composition but must never change
    // the math: every image equals the standing-start serve() result.
    let cfg = native_cfg(4, 2, 4, true);
    let (r_serve, _) = native_server(cfg.clone()).serve(reqs(5, 4)).unwrap();
    let r_serve = by_id(r_serve);
    let handle = native_server(cfg).start();
    let mut tickets = Vec::new();
    for r in reqs(5, 4) {
        tickets.push(handle.submit(r).expect("room"));
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut r_sess: Vec<DenoiseResult> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    r_sess.sort_by_key(|r| r.id);
    handle.shutdown().unwrap();
    for (a, b) in r_sess.iter().zip(&r_serve) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.image.data, b.image.data,
            "request {} diverged under trickled arrivals",
            a.id
        );
    }
}

// ------------------------------------------------- admission races (ISSUE 6)

#[test]
fn session_deadline_expires_between_admit_and_pop() {
    // The race the satellite names: admission accepts the request (its
    // deadline is still in the future) but the deadline passes before a
    // lane pops it. It must count as *expired in queue* — admitted, then
    // resolved with an error at batch-formation time — not as a
    // rejected_deadline admission refusal.
    let mut cfg = native_cfg(50, 1, 1, true);
    cfg.pipeline = false;
    cfg.chunk = 1;
    let handle = native_server(cfg).start();
    let blocker = handle.submit(DenoiseRequest::new(0, 1, 50)).expect("room");
    let mut doomed = DenoiseRequest::new(9, 9, 2);
    doomed.deadline = Some(Duration::from_millis(1));
    let mut doomed_ticket = handle.submit(doomed).expect("admitted: deadline still live");
    // deliverance arrives through polling, not a blocking wait
    let err = loop {
        if let Some(r) = doomed_ticket.try_wait() {
            break r.expect_err("deadline passed while queued");
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    assert!(err.to_string().contains("expired"), "{err}");
    blocker.wait().unwrap();
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.admission.admitted, 2, "the doomed request was admitted");
    assert_eq!(metrics.admission.rejected_deadline, 0);
    assert_eq!(metrics.admission.expired, 1);
    assert_eq!(metrics.requests_done, 1, "the expired request never executed");
}

#[test]
fn ticket_try_wait_before_and_after_delivery() {
    // try_wait: None while in flight, Some(Ok) exactly once on delivery,
    // then the spent-ticket error forever after.
    let mut cfg = native_cfg(50, 1, 1, true);
    cfg.pipeline = false;
    cfg.chunk = 1;
    let handle = native_server(cfg).start();
    // a 50-dispatch request cannot finish between submit and the first
    // poll, so the None branch is observed deterministically
    let mut t = handle.submit(DenoiseRequest::new(0, 1, 50)).unwrap();
    assert!(t.try_wait().is_none(), "still executing on the single lane");
    let r = loop {
        if let Some(r) = t.try_wait() {
            break r;
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    assert_eq!(r.expect("delivered").id, 0);
    let spent = t.try_wait().expect("spent ticket resolves immediately");
    let msg = spent.expect_err("single-shot delivery").to_string();
    assert!(msg.contains("already consumed"), "{msg}");
    handle.shutdown().unwrap();
}

#[test]
fn ticket_wait_after_try_wait_is_single_shot() {
    // Double-wait on a resolved ticket: once try_wait has returned Some,
    // the blocking wait() must fail fast instead of hanging on a channel
    // that will never receive a second result.
    let handle = native_server(native_cfg(2, 1, 1, true)).start();
    let mut t = handle.submit(DenoiseRequest::new(3, 3, 2)).unwrap();
    loop {
        if let Some(r) = t.try_wait() {
            r.expect("request completes");
            break;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let err = t.wait().expect_err("resolved ticket cannot be waited again");
    assert!(err.to_string().contains("already consumed"), "{err}");
    handle.shutdown().unwrap();
}

// ------------------------------------------------- panic isolation (ISSUE 6)

#[test]
fn lane_panic_fails_exactly_one_ticket() {
    // Fault plane: panic while executing the shard's third request. On
    // the per-request path each executed request is one fault-plane
    // claim, so exactly one ticket fails — with the panic message — and
    // the lane keeps serving everything else.
    let mut cfg = native_cfg(3, 1, 2, false);
    cfg.pipeline = false;
    let spec = FaultSpec::parse("panic:0:2:injected boom").unwrap();
    let server = native_server(cfg);
    let handle = server.start_with_faults(Some(Arc::new(spec.plane_for(0))));
    let tickets: Vec<_> = reqs(5, 3)
        .into_iter()
        .map(|r| handle.submit(r).expect("room"))
        .collect();
    let mut failures = Vec::new();
    let mut ok = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(_) => ok += 1,
            Err(e) => failures.push(e.to_string()),
        }
    }
    assert_eq!(failures.len(), 1, "exactly one ticket fails: {failures:?}");
    assert!(failures[0].contains("panic"), "{}", failures[0]);
    assert!(failures[0].contains("injected boom"), "{}", failures[0]);
    assert_eq!(ok, 4, "the lane survives and serves the rest");
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_failed, 1);
    assert_eq!(metrics.requests_done, 4);
    assert_eq!(metrics.lanes_down, 0, "panic isolation keeps the lane up");
}

// ----------------------------------------------------------------- pjrt

/// Build a PJRT server (and its config), or None (with a skip note) when
/// the artifacts or the PJRT runtime are unavailable in this build.
fn server(steps: usize, workers: usize) -> Option<(DiffusionServer, ServeConfig)> {
    let cfg = ServeConfig {
        steps,
        workers,
        requests: 0,
        max_batch: 2,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: true,
        fused: false,
        ..ServeConfig::default()
    };
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve(&cfg.artifact) else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    };
    let mut exe = Executor::new().ok()?;
    if let Err(e) = exe.load_hlo_text("probe", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return None;
    }
    let server = DiffusionServer::new(cfg.clone(), &store).expect("artifacts resolved above");
    Some((server, cfg))
}

#[test]
fn serves_all_requests_exactly_once() {
    let Some((s, _)) = server(4, 2) else { return };
    let reqs: Vec<DenoiseRequest> = (0..5)
        .map(|i| DenoiseRequest::new(i, 100 + i, 4))
        .collect();
    let (results, metrics) = s.serve(reqs).unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(metrics.requests_done, 5);
    assert_eq!(metrics.steps_done, 20);
    assert_eq!(metrics.request_latency.count(), 5);
    assert_eq!(metrics.step_latency.count(), 20);
}

#[test]
fn deterministic_per_seed() {
    let Some((s, _)) = server(3, 1) else { return };
    let req = |seed| DenoiseRequest::new(0, seed, 3);
    let (r1, _) = s.serve(vec![req(42)]).unwrap();
    let (r2, _) = s.serve(vec![req(42)]).unwrap();
    let (r3, _) = s.serve(vec![req(43)]).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same image");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
}

#[test]
fn outputs_bounded_with_trained_weights() {
    let Some((s, cfg)) = server(8, 2) else { return };
    let reqs = workload(&cfg, cfg.seed, 0..3);
    let (results, _) = s.serve(reqs).unwrap();
    for r in &results {
        let max = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(
            max < 20.0,
            "request {} diverged (max |px| = {max}) — artifacts untrained?",
            r.id
        );
    }
}

#[test]
fn cosim_reports_accelerator_ppa() {
    let Some((s, cfg)) = server(2, 1) else { return };
    let (_, metrics) = s.serve(workload(&cfg, cfg.seed, 0..1)).unwrap();
    let rep = metrics.sim_report(&CAL_40NM, 8).expect("cosim enabled");
    assert!(rep.cycles > 0);
    assert!(rep.gops > 10.0, "U-net sustains > 10 GOPs on the array");
    assert!(rep.u_pe > 0.8, "U-net keeps the array busy");
}

#[test]
fn fused_scan_matches_step_mode() {
    // The fused 50-step scan artifact and the step-at-a-time loop draw
    // noise in the same order, so the same seed must produce the same
    // image up to XLA re-association.
    if server(50, 1).is_none() {
        return; // artifacts or PJRT unavailable
    }
    let store = ArtifactStore::new("artifacts");
    if store.resolve("unet_denoise_scan50_16").is_err() {
        eprintln!("skipping: scan artifact missing (run `make artifacts`)");
        return;
    }
    let mk = |fused| ServeConfig {
        steps: 50,
        workers: 1,
        requests: 0,
        max_batch: 1,
        seed: 21,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused,
        ..ServeConfig::default()
    };
    let req = DenoiseRequest::new(0, 777, 50);
    let s_step = DiffusionServer::new(mk(false), &store).unwrap();
    let (r_step, _) = s_step.serve(vec![req.clone()]).unwrap();
    let s_fused = DiffusionServer::new(mk(true), &store).unwrap();
    let (r_fused, m_fused) = s_fused.serve(vec![req]).unwrap();
    assert_eq!(r_fused[0].steps, 50);
    let max_diff = r_step[0]
        .image
        .data
        .iter()
        .zip(&r_fused[0].image.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3,
        "fused and step-mode images diverged: {max_diff}"
    );
    assert_eq!(m_fused.steps_done, 50);
}

#[test]
fn fused_rejects_mismatched_step_counts() {
    // ISSUE 3 satellite: the fused PJRT path used to silently run the
    // artifact's baked step count; now a mismatch is a clear error.
    if server(50, 1).is_none() {
        return; // artifacts or PJRT unavailable
    }
    let store = ArtifactStore::new("artifacts");
    if store.resolve("unet_denoise_scan50_16").is_err() {
        eprintln!("skipping: scan artifact missing (run `make artifacts`)");
        return;
    }
    let cfg = ServeConfig {
        steps: 50,
        workers: 1,
        requests: 0,
        max_batch: 1,
        seed: 21,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: true,
        ..ServeConfig::default()
    };
    let s = DiffusionServer::new(cfg, &store).unwrap();
    let err = s
        .serve(vec![DenoiseRequest::new(0, 1, 20)])
        .unwrap_err()
        .to_string();
    assert!(err.contains("exactly 50 steps"), "{err}");
}

#[test]
fn more_workers_not_slower() {
    // smoke check the scaling direction on a tiny workload (allow noise:
    // just require both complete and report sane wall times)
    let Some((s1, cfg1)) = server(3, 1) else { return };
    let (_, m1) = s1.serve(workload(&cfg1, cfg1.seed, 0..4)).unwrap();
    let Some((s2, cfg2)) = server(3, 2) else { return };
    let (_, m2) = s2.serve(workload(&cfg2, cfg2.seed, 0..4)).unwrap();
    assert!(m1.wall.as_secs_f64() > 0.0 && m2.wall.as_secs_f64() > 0.0);
    assert_eq!(m1.requests_done, m2.requests_done);
}
