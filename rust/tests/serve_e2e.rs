//! End-to-end serving integration tests: the full coordinator path
//! (queue → fair batcher → worker lanes → DDPM loop) on small workloads.
//!
//! Two tiers:
//!
//! * **Native tests** run unconditionally — the serving stack executes on
//!   the host-CPU surrogate runtime with synthetic parameters, so tier-1
//!   exercises batching, pipelining, fairness, and determinism offline.
//! * **PJRT tests** additionally require `make artifacts` *and* a
//!   PJRT-enabled build (`--features pjrt` against the real xla crate);
//!   each skips cleanly when either is missing.

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{DenoiseRequest, DenoiseResult, DiffusionServer};
use sf_mmcn::runtime::{ArtifactStore, Executor};
use sf_mmcn::sim::energy::CAL_40NM;

// ---------------------------------------------------------------- native

/// Offline server on the native surrogate backend (no artifacts needed).
fn native_server(cfg: ServeConfig) -> DiffusionServer {
    let store = ArtifactStore::new("artifacts");
    DiffusionServer::new(cfg, &store).expect("native backend needs no artifacts")
}

fn native_cfg(steps: usize, workers: usize, max_batch: usize, batched: bool) -> ServeConfig {
    ServeConfig {
        steps,
        workers,
        max_batch,
        batched,
        requests: 0,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        pipeline: true,
        chunk: 0,
        pooled: true,
    }
}

fn reqs(n: u64, steps: usize) -> Vec<DenoiseRequest> {
    (0..n)
        .map(|i| DenoiseRequest {
            id: i,
            seed: 500 + i,
            steps,
        })
        .collect()
}

#[test]
fn native_serves_all_requests_exactly_once() {
    let s = native_server(native_cfg(4, 2, 4, true));
    let (results, metrics) = s.serve(reqs(5, 4)).unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(metrics.requests_done, 5);
    assert_eq!(metrics.steps_done, 20);
    assert_eq!(metrics.request_latency.count(), 5);
    assert_eq!(metrics.step_latency.count(), 20);
    assert!(metrics.dispatches >= 1);
    assert_eq!(metrics.batch_items, 5, "each request in exactly one dispatch");
}

#[test]
fn native_batched_bit_identical_to_per_request_path() {
    // The ISSUE 3 determinism contract: for the same seeds, the batched
    // pipelined path must produce bit-identical images to the
    // step-at-a-time per-request path.
    let s_seq = native_server(native_cfg(5, 1, 1, false));
    let (mut r_seq, _) = s_seq.serve(reqs(6, 5)).unwrap();
    let s_bat = native_server(native_cfg(5, 2, 4, true));
    let (mut r_bat, m) = s_bat.serve(reqs(6, 5)).unwrap();
    r_seq.sort_by_key(|r| r.id);
    r_bat.sort_by_key(|r| r.id);
    for (a, b) in r_seq.iter().zip(&r_bat) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.image.data, b.image.data,
            "request {} diverged between batched and per-request paths",
            a.id
        );
        assert_eq!(a.steps, 5);
        assert_eq!(b.steps, 5);
    }
    assert!(
        m.batch_occupancy() > 1.0,
        "batched mode must actually batch (occupancy {})",
        m.batch_occupancy()
    );
}

#[test]
fn native_chunked_dispatch_bit_identical() {
    // Chunked timestep dispatch (several [B, ...] executions per request)
    // must not change the math, only the dispatch count.
    let whole = native_server(native_cfg(5, 1, 4, true));
    let (mut r_whole, m_whole) = whole.serve(reqs(4, 5)).unwrap();
    let mut cfg = native_cfg(5, 1, 4, true);
    cfg.chunk = 2;
    let chunked = native_server(cfg);
    let (mut r_chunk, m_chunk) = chunked.serve(reqs(4, 5)).unwrap();
    r_whole.sort_by_key(|r| r.id);
    r_chunk.sort_by_key(|r| r.id);
    for (a, b) in r_whole.iter().zip(&r_chunk) {
        assert_eq!(a.image.data, b.image.data, "request {} diverged", a.id);
    }
    assert!(
        m_chunk.dispatches > m_whole.dispatches,
        "chunk=2 over 5 steps must dispatch more often ({} vs {})",
        m_chunk.dispatches,
        m_whole.dispatches
    );
}

// ------------------------------------------------- pooled hot path (ISSUE 4)

/// Sort-by-id helper for output comparisons.
fn by_id(mut results: Vec<DenoiseResult>) -> Vec<DenoiseResult> {
    results.sort_by_key(|r| r.id);
    results
}

#[test]
fn pooled_bit_identical_to_allocating_batched_and_per_request() {
    // ISSUE 4 acceptance: the pooled zero-allocation path must be
    // bit-identical to the PR 2 allocating batched path AND to the
    // step-at-a-time per-request path, for the same seeds.
    let pooled = native_server(native_cfg(5, 2, 4, true));
    let (r_pool, m_pool) = pooled.serve(reqs(6, 5)).unwrap();
    let r_pool = by_id(r_pool);
    let mut cfg = native_cfg(5, 2, 4, true);
    cfg.pooled = false;
    let unpooled = native_server(cfg);
    let (r_alloc, m_alloc) = unpooled.serve(reqs(6, 5)).unwrap();
    let r_alloc = by_id(r_alloc);
    let seq = native_server(native_cfg(5, 1, 1, false));
    let (r_seq, _) = seq.serve(reqs(6, 5)).unwrap();
    let r_seq = by_id(r_seq);
    for ((p, a), s) in r_pool.iter().zip(&r_alloc).zip(&r_seq) {
        assert_eq!(p.id, a.id);
        assert_eq!(p.id, s.id);
        assert_eq!(
            p.image.data, a.image.data,
            "request {} diverged between pooled and allocating batched paths",
            p.id
        );
        assert_eq!(
            p.image.data, s.image.data,
            "request {} diverged between pooled and per-request paths",
            p.id
        );
    }
    // the pooled session recycles; the disabled pool never hits
    assert!(m_pool.pool_hits > 0, "pooled run must reuse slabs");
    assert_eq!(m_alloc.pool_hits, 0, "disabled pool must never hit");
    assert!(m_alloc.pool_misses > 0, "disabled pool allocates every lease");
    assert!(
        m_pool.pool_bytes_leased > 0 && m_alloc.pool_bytes_leased > 0,
        "both modes account leased bytes"
    );
}

#[test]
fn pooled_chunked_bit_identical_to_allocating() {
    // Chunked dispatch exercises the partial-chunk scratch leases
    // (t_emb/coeff/noise gathers) on top of the rotating image slabs.
    let mut pooled_cfg = native_cfg(5, 1, 4, true);
    pooled_cfg.chunk = 2;
    let pooled = native_server(pooled_cfg);
    let (r_pool, _) = pooled.serve(reqs(4, 5)).unwrap();
    let r_pool = by_id(r_pool);
    let mut alloc_cfg = native_cfg(5, 1, 4, true);
    alloc_cfg.chunk = 2;
    alloc_cfg.pooled = false;
    let alloc = native_server(alloc_cfg);
    let (r_alloc, _) = alloc.serve(reqs(4, 5)).unwrap();
    let r_alloc = by_id(r_alloc);
    // and the whole-request pooled path for the same workload
    let whole = native_server(native_cfg(5, 1, 4, true));
    let (r_whole, _) = whole.serve(reqs(4, 5)).unwrap();
    let r_whole = by_id(r_whole);
    for ((p, a), w) in r_pool.iter().zip(&r_alloc).zip(&r_whole) {
        assert_eq!(p.image.data, a.image.data, "request {} diverged (chunked)", p.id);
        assert_eq!(p.image.data, w.image.data, "request {} diverged (vs whole)", p.id);
    }
}

#[test]
fn pooled_mixed_step_counts_bit_identical_to_allocating() {
    // Mixed per-request steps mean differently-sized slabs per batch —
    // the best-fit free list must still hand back correct (zeroed)
    // storage for every size.
    let mixed = |pooled: bool| {
        let mut all = reqs(3, 6);
        all.extend((3..6).map(|i| DenoiseRequest {
            id: i,
            seed: 500 + i,
            steps: 2,
        }));
        let mut cfg = native_cfg(6, 2, 4, true);
        cfg.pooled = pooled;
        let s = native_server(cfg);
        let (results, m) = s.serve(all).unwrap();
        (by_id(results), m)
    };
    let (r_pool, _) = mixed(true);
    let (r_alloc, _) = mixed(false);
    for (p, a) in r_pool.iter().zip(&r_alloc) {
        assert_eq!(p.id, a.id);
        assert_eq!(p.steps, a.steps);
        assert_eq!(
            p.image.data, a.image.data,
            "request {} diverged between pooled and allocating mixed-step paths",
            p.id
        );
    }
}

#[test]
fn pool_misses_stay_flat_after_warmup() {
    // Steady-state zero-allocation contract: on a single worker serving
    // many same-shape batches, only the warmup working set allocates —
    // a miss count that grows with the batch count means slabs are not
    // recycling. 16 requests in batches of 2 = 8 batches; each batch
    // leases 5 slabs (4 prep + 1 rotating image slab in whole-request
    // mode), so a non-recycling pool would miss ~40 times.
    let s = native_server(native_cfg(3, 1, 2, true));
    let (_, m) = s.serve(reqs(16, 3)).unwrap();
    assert!(
        m.pool_misses <= 16,
        "pool misses must be bounded by the warmup working set, got {} \
         (hits {})",
        m.pool_misses,
        m.pool_hits
    );
    assert!(
        m.pool_hits > m.pool_misses,
        "steady state must be dominated by free-list hits ({} hits / {} misses)",
        m.pool_hits,
        m.pool_misses
    );
}

#[test]
fn native_deterministic_per_seed() {
    let s = native_server(native_cfg(3, 1, 2, true));
    let req = |seed| {
        vec![DenoiseRequest {
            id: 0,
            seed,
            steps: 3,
        }]
    };
    let (r1, _) = s.serve(req(42)).unwrap();
    let (r2, _) = s.serve(req(42)).unwrap();
    let (r3, _) = s.serve(req(43)).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same image");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
}

#[test]
fn native_fair_batcher_spreads_work_across_workers() {
    // Starvation regression test: with max_batch >= the whole queue, the
    // old greedy batcher let one worker swallow all 8 requests. The fair
    // batcher divides by worker count (first grab <= ceil(8/2) = 4), and
    // the start barrier keeps any lane from draining before all exist.
    let s = native_server(native_cfg(6, 2, 8, true));
    let (results, m) = s.serve(reqs(8, 6)).unwrap();
    assert_eq!(results.len(), 8);
    assert_eq!(m.per_worker_requests.len(), 2);
    assert_eq!(m.per_worker_requests.iter().sum::<usize>(), 8);
    assert!(
        m.per_worker_requests.iter().all(|&c| c >= 1),
        "a worker starved: {:?}",
        m.per_worker_requests
    );
    assert!(
        m.per_worker_requests.iter().all(|&c| c <= 7),
        "a worker swallowed the queue: {:?}",
        m.per_worker_requests
    );
}

#[test]
fn native_mixed_step_counts_honored_per_request() {
    // ISSUE 3 satellite: per-request steps must be honored (the fused
    // path used to ignore them). Mixed-step workloads batch in same-step
    // groups and every result reports its own step count.
    let mut all = reqs(3, 6);
    all.extend((3..6).map(|i| DenoiseRequest {
        id: i,
        seed: 500 + i,
        steps: 2,
    }));
    let s = native_server(native_cfg(6, 2, 4, true));
    let (mut results, m) = s.serve(all).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 6);
    for r in &results[..3] {
        assert_eq!(r.steps, 6, "request {}", r.id);
    }
    for r in &results[3..] {
        assert_eq!(r.steps, 2, "request {}", r.id);
    }
    assert_eq!(m.steps_done, 3 * 6 + 3 * 2);

    // and a 2-step request batched here must equal the same request run
    // solo through the per-request path (same 6-step schedule)
    let s2 = native_server(native_cfg(6, 1, 1, false));
    let (r2, _) = s2
        .serve(vec![DenoiseRequest {
            id: 3,
            seed: 503,
            steps: 2,
        }])
        .unwrap();
    let mixed = results.iter().find(|r| r.id == 3).unwrap();
    assert_eq!(mixed.image.data, r2[0].image.data);
}

#[test]
fn native_rejects_out_of_range_steps() {
    let s = native_server(native_cfg(4, 1, 2, false));
    let bad = vec![DenoiseRequest {
        id: 9,
        seed: 1,
        steps: 99,
    }];
    let err = s.serve(bad).unwrap_err().to_string();
    assert!(err.contains("steps 99"), "{err}");
    assert!(err.contains("out of range"), "{err}");
}

#[test]
fn native_fused_honors_per_request_steps() {
    // fused mode on the native backend runs the request's own step count
    let mut cfg = native_cfg(6, 1, 1, false);
    cfg.fused = true;
    let s = native_server(cfg);
    let (r, m) = s
        .serve(vec![DenoiseRequest {
            id: 0,
            seed: 77,
            steps: 4,
        }])
        .unwrap();
    assert_eq!(r[0].steps, 4);
    assert_eq!(m.steps_done, 4);
    // and matches the step-at-a-time result bit for bit
    let s_step = native_server(native_cfg(6, 1, 1, false));
    let (r_step, _) = s_step
        .serve(vec![DenoiseRequest {
            id: 0,
            seed: 77,
            steps: 4,
        }])
        .unwrap();
    assert_eq!(r[0].image.data, r_step[0].image.data);
}

#[test]
fn native_pipeline_off_is_equivalent() {
    let mut cfg = native_cfg(4, 2, 4, true);
    cfg.pipeline = false;
    let s_inline = native_server(cfg);
    let (mut r_inline, m_inline) = s_inline.serve(reqs(6, 4)).unwrap();
    let s_pipe = native_server(native_cfg(4, 2, 4, true));
    let (mut r_pipe, _) = s_pipe.serve(reqs(6, 4)).unwrap();
    r_inline.sort_by_key(|r| r.id);
    r_pipe.sort_by_key(|r| r.id);
    for (a, b) in r_inline.iter().zip(&r_pipe) {
        assert_eq!(a.image.data, b.image.data);
    }
    assert_eq!(m_inline.pipeline_stalls, 0, "no pipeline, no stalls");
}

#[test]
fn native_cosim_uses_micro_sim_for_batched_traffic() {
    let mut cfg = native_cfg(2, 1, 2, true);
    cfg.cosim = true;
    let s = native_server(cfg);
    let (_, metrics) = s.serve(reqs(2, 2)).unwrap();
    let rep = metrics.sim_report(&CAL_40NM, 8).expect("cosim enabled");
    assert!(rep.cycles > 0);
    assert!(rep.u_pe > 0.0 && rep.u_pe <= 1.0);
    // 2 requests x 2 steps: counts are per-step multiples
    let counts = metrics.sim_counts.unwrap();
    assert_eq!(counts.cycles % 4, 0, "4 identical steps merged");
}

#[test]
fn native_outputs_bounded() {
    let s = native_server(native_cfg(8, 2, 4, true));
    let (results, _) = s.serve(s.workload(3)).unwrap();
    for r in &results {
        let max = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(
            max < 20.0,
            "request {} diverged (max |px| = {max})",
            r.id
        );
    }
}

// ----------------------------------------------------------------- pjrt

/// Build a PJRT server, or None (with a skip note) when the artifacts or
/// the PJRT runtime are unavailable in this build.
fn server(steps: usize, workers: usize) -> Option<DiffusionServer> {
    let cfg = ServeConfig {
        steps,
        workers,
        requests: 0,
        max_batch: 2,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: true,
        fused: false,
        ..ServeConfig::default()
    };
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve(&cfg.artifact) else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    };
    let mut exe = Executor::new().ok()?;
    if let Err(e) = exe.load_hlo_text("probe", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return None;
    }
    Some(DiffusionServer::new(cfg, &store).expect("artifacts resolved above"))
}

#[test]
fn serves_all_requests_exactly_once() {
    let Some(s) = server(4, 2) else { return };
    let reqs: Vec<DenoiseRequest> = (0..5)
        .map(|i| DenoiseRequest {
            id: i,
            seed: 100 + i,
            steps: 4,
        })
        .collect();
    let (results, metrics) = s.serve(reqs).unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(metrics.requests_done, 5);
    assert_eq!(metrics.steps_done, 20);
    assert_eq!(metrics.request_latency.count(), 5);
    assert_eq!(metrics.step_latency.count(), 20);
}

#[test]
fn deterministic_per_seed() {
    let Some(s) = server(3, 1) else { return };
    let req = |seed| DenoiseRequest {
        id: 0,
        seed,
        steps: 3,
    };
    let (r1, _) = s.serve(vec![req(42)]).unwrap();
    let (r2, _) = s.serve(vec![req(42)]).unwrap();
    let (r3, _) = s.serve(vec![req(43)]).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same image");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
}

#[test]
fn outputs_bounded_with_trained_weights() {
    let Some(s) = server(8, 2) else { return };
    let reqs = s.workload(3);
    let (results, _) = s.serve(reqs).unwrap();
    for r in &results {
        let max = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(
            max < 20.0,
            "request {} diverged (max |px| = {max}) — artifacts untrained?",
            r.id
        );
    }
}

#[test]
fn cosim_reports_accelerator_ppa() {
    let Some(s) = server(2, 1) else { return };
    let (_, metrics) = s.serve(s.workload(1)).unwrap();
    let rep = metrics.sim_report(&CAL_40NM, 8).expect("cosim enabled");
    assert!(rep.cycles > 0);
    assert!(rep.gops > 10.0, "U-net sustains > 10 GOPs on the array");
    assert!(rep.u_pe > 0.8, "U-net keeps the array busy");
}

#[test]
fn fused_scan_matches_step_mode() {
    // The fused 50-step scan artifact and the step-at-a-time loop draw
    // noise in the same order, so the same seed must produce the same
    // image up to XLA re-association.
    if server(50, 1).is_none() {
        return; // artifacts or PJRT unavailable
    }
    let store = ArtifactStore::new("artifacts");
    if store.resolve("unet_denoise_scan50_16").is_err() {
        eprintln!("skipping: scan artifact missing (run `make artifacts`)");
        return;
    }
    let mk = |fused| ServeConfig {
        steps: 50,
        workers: 1,
        requests: 0,
        max_batch: 1,
        seed: 21,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused,
        ..ServeConfig::default()
    };
    let req = DenoiseRequest {
        id: 0,
        seed: 777,
        steps: 50,
    };
    let s_step = DiffusionServer::new(mk(false), &store).unwrap();
    let (r_step, _) = s_step.serve(vec![req.clone()]).unwrap();
    let s_fused = DiffusionServer::new(mk(true), &store).unwrap();
    let (r_fused, m_fused) = s_fused.serve(vec![req]).unwrap();
    assert_eq!(r_fused[0].steps, 50);
    let max_diff = r_step[0]
        .image
        .data
        .iter()
        .zip(&r_fused[0].image.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3,
        "fused and step-mode images diverged: {max_diff}"
    );
    assert_eq!(m_fused.steps_done, 50);
}

#[test]
fn fused_rejects_mismatched_step_counts() {
    // ISSUE 3 satellite: the fused PJRT path used to silently run the
    // artifact's baked step count; now a mismatch is a clear error.
    if server(50, 1).is_none() {
        return; // artifacts or PJRT unavailable
    }
    let store = ArtifactStore::new("artifacts");
    if store.resolve("unet_denoise_scan50_16").is_err() {
        eprintln!("skipping: scan artifact missing (run `make artifacts`)");
        return;
    }
    let cfg = ServeConfig {
        steps: 50,
        workers: 1,
        requests: 0,
        max_batch: 1,
        seed: 21,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: true,
        ..ServeConfig::default()
    };
    let s = DiffusionServer::new(cfg, &store).unwrap();
    let err = s
        .serve(vec![DenoiseRequest {
            id: 0,
            seed: 1,
            steps: 20,
        }])
        .unwrap_err()
        .to_string();
    assert!(err.contains("exactly 50 steps"), "{err}");
}

#[test]
fn more_workers_not_slower() {
    // smoke check the scaling direction on a tiny workload (allow noise:
    // just require both complete and report sane wall times)
    let Some(s1) = server(3, 1) else { return };
    let (_, m1) = s1.serve(s1.workload(4)).unwrap();
    let Some(s2) = server(3, 2) else { return };
    let (_, m2) = s2.serve(s2.workload(4)).unwrap();
    assert!(m1.wall.as_secs_f64() > 0.0 && m2.wall.as_secs_f64() > 0.0);
    assert_eq!(m1.requests_done, m2.requests_done);
}
