//! End-to-end serving integration test: the full coordinator path
//! (queue → batcher → workers → PJRT → DDPM loop) on a small workload.
//!
//! Requires `make artifacts` *and* a PJRT-enabled build (`--features
//! pjrt`); each test skips cleanly when either is missing, so the suite
//! stays green on CI builds that have neither.

use sf_mmcn::config::ServeConfig;
use sf_mmcn::coordinator::{DenoiseRequest, DiffusionServer};
use sf_mmcn::runtime::{ArtifactStore, Executor};
use sf_mmcn::sim::energy::CAL_40NM;

/// Build a server, or None (with a skip note) when the artifacts or the
/// PJRT runtime are unavailable in this build.
fn server(steps: usize, workers: usize) -> Option<DiffusionServer> {
    let cfg = ServeConfig {
        steps,
        workers,
        requests: 0,
        max_batch: 2,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: true,
        fused: false,
    };
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve(&cfg.artifact) else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    };
    let mut exe = Executor::new().ok()?;
    if let Err(e) = exe.load_hlo_text("probe", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return None;
    }
    Some(DiffusionServer::new(cfg, &store).expect("artifacts resolved above"))
}

#[test]
fn serves_all_requests_exactly_once() {
    let Some(s) = server(4, 2) else { return };
    let reqs: Vec<DenoiseRequest> = (0..5)
        .map(|i| DenoiseRequest {
            id: i,
            seed: 100 + i,
            steps: 4,
        })
        .collect();
    let (results, metrics) = s.serve(reqs).unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(metrics.requests_done, 5);
    assert_eq!(metrics.steps_done, 20);
    assert_eq!(metrics.request_latency.count(), 5);
    assert_eq!(metrics.step_latency.count(), 20);
}

#[test]
fn deterministic_per_seed() {
    let Some(s) = server(3, 1) else { return };
    let req = |seed| DenoiseRequest {
        id: 0,
        seed,
        steps: 3,
    };
    let (r1, _) = s.serve(vec![req(42)]).unwrap();
    let (r2, _) = s.serve(vec![req(42)]).unwrap();
    let (r3, _) = s.serve(vec![req(43)]).unwrap();
    assert_eq!(r1[0].image.data, r2[0].image.data, "same seed, same image");
    assert_ne!(r1[0].image.data, r3[0].image.data, "different seed differs");
}

#[test]
fn outputs_bounded_with_trained_weights() {
    let Some(s) = server(8, 2) else { return };
    let reqs = s.workload(3);
    let (results, _) = s.serve(reqs).unwrap();
    for r in &results {
        let max = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(
            max < 20.0,
            "request {} diverged (max |px| = {max}) — artifacts untrained?",
            r.id
        );
    }
}

#[test]
fn cosim_reports_accelerator_ppa() {
    let Some(s) = server(2, 1) else { return };
    let (_, metrics) = s.serve(s.workload(1)).unwrap();
    let rep = metrics.sim_report(&CAL_40NM, 8).expect("cosim enabled");
    assert!(rep.cycles > 0);
    assert!(rep.gops > 10.0, "U-net sustains > 10 GOPs on the array");
    assert!(rep.u_pe > 0.8, "U-net keeps the array busy");
}

#[test]
fn fused_scan_matches_step_mode() {
    // The fused 50-step scan artifact and the step-at-a-time loop draw
    // noise in the same order, so the same seed must produce the same
    // image up to XLA re-association.
    if server(50, 1).is_none() {
        return; // artifacts or PJRT unavailable
    }
    let store = ArtifactStore::new("artifacts");
    if store.resolve("unet_denoise_scan50_16").is_err() {
        eprintln!("skipping: scan artifact missing (run `make artifacts`)");
        return;
    }
    let mk = |fused| ServeConfig {
        steps: 50,
        workers: 1,
        requests: 0,
        max_batch: 1,
        seed: 21,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused,
    };
    let req = DenoiseRequest {
        id: 0,
        seed: 777,
        steps: 50,
    };
    let s_step = DiffusionServer::new(mk(false), &store).unwrap();
    let (r_step, _) = s_step.serve(vec![req.clone()]).unwrap();
    let s_fused = DiffusionServer::new(mk(true), &store).unwrap();
    let (r_fused, m_fused) = s_fused.serve(vec![req]).unwrap();
    assert_eq!(r_fused[0].steps, 50);
    let max_diff = r_step[0]
        .image
        .data
        .iter()
        .zip(&r_fused[0].image.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3,
        "fused and step-mode images diverged: {max_diff}"
    );
    assert_eq!(m_fused.steps_done, 50);
}

#[test]
fn more_workers_not_slower() {
    // smoke check the scaling direction on a tiny workload (allow noise:
    // just require both complete and report sane wall times)
    let Some(s1) = server(3, 1) else { return };
    let (_, m1) = s1.serve(s1.workload(4)).unwrap();
    let Some(s2) = server(3, 2) else { return };
    let (_, m2) = s2.serve(s2.workload(4)).unwrap();
    assert!(m1.wall.as_secs_f64() > 0.0 && m2.wall.as_secs_f64() > 0.0);
    assert_eq!(m1.requests_done, m2.requests_done);
}
