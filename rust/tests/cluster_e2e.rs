//! Cluster serving end-to-end tests (ISSUE 10): the acceptance criteria
//! for multi-process serving, on the offline native backend.
//!
//! Same central claim as the in-process fleet, now across process
//! boundaries: request execution is a pure function of
//! `(model, seed, steps)`, so a cluster run — including one where a
//! worker *process* is killed mid-flight — delivers a result set
//! byte-identical to a single-process run of the same seeded workload.
//!
//! Every scenario spawns real `shard-worker` child processes of this
//! crate's own binary and talks to them over the Unix-socket wire
//! protocol; nothing is mocked.

#![cfg(unix)]

use std::path::Path;
use std::time::{Duration, Instant};

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    workload, ClusterFleet, DenoiseResult, DiffusionServer, FleetTicket, ShardState,
};
use sf_mmcn::runtime::ArtifactStore;

/// Cluster config on the native surrogate: single-lane workers,
/// per-step dispatches (chunk = 1) so pulses beat every few
/// milliseconds — far inside the 10 ms x 8 heartbeat tolerance.
fn cluster_cfg(workers: usize, steps: usize) -> ServeConfig {
    ServeConfig {
        steps,
        requests: 0,
        workers: 1,
        max_batch: 2,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        batched: true,
        pipeline: false,
        chunk: 1,
        pooled: true,
        queue_depth: 64,
        priorities: 2,
        shards: 1,
        cluster: workers,
        heartbeat_ms: 10,
        heartbeat_misses: 8,
        ..ServeConfig::default()
    }
}

fn exe() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_sf-mmcn"))
}

/// The single-process reference: the same seeded workload through one
/// plain in-process session. Results sorted by id for positional
/// comparison.
fn baseline(cfg: &ServeConfig, n: usize) -> Vec<DenoiseResult> {
    let mut solo = cfg.clone();
    solo.cluster = 0;
    solo.shards = 1;
    let server =
        DiffusionServer::new(solo, &ArtifactStore::new("artifacts")).expect("baseline server");
    let (mut r, _) = server
        .serve(workload(cfg, cfg.seed, 0..n))
        .expect("single-process baseline serves everything");
    r.sort_by_key(|x| x.id);
    r
}

fn submit_all(fleet: &ClusterFleet, cfg: &ServeConfig, n: usize) -> Vec<FleetTicket> {
    workload(cfg, cfg.seed, 0..n)
        .into_iter()
        .map(|r| fleet.submit(r).expect("cluster front door admits the workload"))
        .collect()
}

fn wait_all(tickets: Vec<FleetTicket>, what: &str) -> Vec<DenoiseResult> {
    let mut results: Vec<DenoiseResult> = tickets
        .into_iter()
        .map(|t| {
            let id = t.id();
            t.wait()
                .unwrap_or_else(|e| panic!("{what}: cluster ticket {id} lost or failed: {e}"))
        })
        .collect();
    results.sort_by_key(|r| r.id);
    results
}

fn assert_bit_identical(got: &[DenoiseResult], want: &[DenoiseResult], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: delivered-set size");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.id, w.id, "{what}: delivered-set ids");
        assert_eq!(
            g.image.data, w.image.data,
            "{what}: request {} diverged from the single-process run — \
             cluster serving must be bit-identical",
            g.id
        );
    }
}

#[test]
fn four_process_cluster_matches_single_process_bit_for_bit() {
    // Acceptance (a): a 4-process cluster delivers the exact result set
    // a single in-process session produces for the same seeded workload
    // — the wire codec, routing, and per-process sessions are all
    // invisible to the bits.
    let n = 16;
    let cfg = cluster_cfg(4, 2);
    let want = baseline(&cfg, n);
    let fleet = ClusterFleet::start(cfg.clone(), exe()).expect("4-process cluster starts");
    assert_eq!(fleet.workers(), 4);
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "4-process cluster");
    assert_bit_identical(&got, &want, "4-process cluster");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.submitted, n as u64);
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 0, "no worker died in a clean run");
    assert_eq!(m.stats.drained, 4, "every worker exited orderly");
    assert_eq!(m.e2e_latency.count(), n as u64);
    // every worker process reported final metrics; together they
    // executed the full workload
    assert_eq!(m.per_shard.len(), 4);
    let done: usize = m.per_shard.iter().map(|s| s.requests_done).sum();
    assert_eq!(done, n, "every request executed exactly once");
}

#[test]
fn worker_process_kill_mid_flight_loses_zero_tickets() {
    // Acceptance (b): kill a worker *process* mid-flight. Every ticket
    // still resolves Ok (zero lost), and every delivered image is
    // byte-equal to the single-process run — failover re-admission is
    // invisible except in the counters.
    let n = 16;
    let cfg = cluster_cfg(2, 3);
    let want = baseline(&cfg, n);
    let fleet = ClusterFleet::start(cfg.clone(), exe()).expect("2-process cluster starts");
    let tickets = submit_all(&fleet, &cfg, n);
    // p2c spreads the burst across both workers, so worker 0 holds
    // in-flight work when the kill lands
    fleet.kill_worker(0).expect("kill reaches the child process");
    let got = wait_all(tickets, "worker kill");
    assert_bit_identical(&got, &want, "worker kill");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.submitted, n as u64);
    assert_eq!(m.stats.delivered, n as u64, "zero lost tickets");
    assert_eq!(m.stats.failed, 0);
    assert!(
        m.stats.failovers >= 1,
        "the killed worker was declared dead"
    );
    assert!(
        m.stats.requeued >= 1,
        "the killed worker held undelivered work"
    );
}

#[test]
fn drain_shutdown_resolves_every_admitted_ticket() {
    // Acceptance (c): shutdown() right after admission is a drain, not
    // an abort — every admitted ticket resolves (here: all Ok), then
    // the workers exit orderly. Mixed-mode traffic keeps all three
    // model kinds on the wire during the drain.
    let n = 12;
    let mut cfg = cluster_cfg(2, 2);
    cfg.model_mix = "unet:1,resnet18:1,vgg16:1".into();
    let want = baseline(&cfg, n);
    let fleet = ClusterFleet::start(cfg.clone(), exe()).expect("2-process cluster starts");
    let tickets = submit_all(&fleet, &cfg, n);
    // no waiting first: the drain itself must resolve the backlog
    let m = fleet.shutdown().unwrap();
    let got = wait_all(tickets, "drain shutdown");
    assert_bit_identical(&got, &want, "drain shutdown");
    assert_eq!(m.stats.submitted, n as u64);
    assert_eq!(m.stats.delivered, n as u64, "drain resolved every ticket");
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 0, "a drain is not a failure");
    assert_eq!(m.stats.drained, 2);
    // 12 requests over a 1:1:1 mix = 4 per mode, all delivered
    for row in &m.per_model {
        assert_eq!(row.requests_done, 4, "{}", row.model.name());
        assert_eq!(row.requests_failed, 0, "{}", row.model.name());
    }
}

#[test]
fn worker_preemption_drains_in_place() {
    // Preempting a worker process drains it: its assigned tickets
    // resolve in place (no requeue, no re-execution), the slot parks as
    // Drained, and the survivor carries new work.
    let n = 12;
    let cfg = cluster_cfg(2, 2);
    let want = baseline(&cfg, n);
    let fleet = ClusterFleet::start(cfg.clone(), exe()).expect("2-process cluster starts");
    let tickets = submit_all(&fleet, &cfg, n);
    fleet.begin_preempt(0).expect("preempt notice accepted");
    let got = wait_all(tickets, "worker preemption");
    assert_bit_identical(&got, &want, "worker preemption");
    // the monitor parks the drained worker asynchronously
    let deadline = Instant::now() + Duration::from_secs(30);
    while fleet.worker_states()[0] != ShardState::Drained {
        assert!(
            Instant::now() < deadline,
            "worker 0 never finished its drain: {:?}",
            fleet.worker_states()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 0, "preemption is not a failure");
    assert_eq!(m.stats.requeued, 0, "drain resolves work in place");
    assert_eq!(m.stats.drained, 2, "both workers parked orderly");
    let done: usize = m.per_shard.iter().map(|s| s.requests_done).sum();
    assert_eq!(done, n, "every request executed exactly once");
}
