//! Golden-equivalence suite for the §Perf simulator hot-path rewrite.
//!
//! [`Accelerator::run_graph`] (flat buffers, per-layer window slabs,
//! scoped-thread units) and [`Accelerator::run_graph_ref`] (the seed
//! scalar implementation, preserved verbatim) must agree **bit-exactly**:
//! fixed-point outputs, per-layer wall cycles, and every event counter
//! (PE, unit, memory). A separate test pins hand-computed golden values
//! for a tiny conv so both paths are also anchored against an external
//! derivation, not just each other.

use sf_mmcn::models::graph::{
    Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape,
};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::util::{Rng, Tensor};

/// Run both paths on fresh accelerators and assert bit-exact agreement.
fn assert_paths_agree(
    g: &ModelGraph,
    cfg: AcceleratorConfig,
    x: &Tensor,
    ws: &WeightStore,
    emb: Option<&[f32]>,
) {
    let mut a_fast = Accelerator::new(cfg);
    let mut a_ref = Accelerator::new(cfg);
    let fast = a_fast.run_graph(g, x, ws, emb).expect("fast path runs");
    let refr = a_ref.run_graph_ref(g, x, ws, emb).expect("ref path runs");

    assert_eq!(fast.output.shape(), refr.output.shape());
    assert_eq!(
        fast.output.data(),
        refr.output.data(),
        "fixed-point outputs must be bit-identical"
    );
    assert_eq!(fast.layers.len(), refr.layers.len());
    for (lf, lr) in fast.layers.iter().zip(&refr.layers) {
        let ctx = format!("layer {} ({})", lf.node_idx, lf.label);
        assert_eq!(lf.label, lr.label, "{ctx}: label");
        assert_eq!(lf.cycles, lr.cycles, "{ctx}: wall cycles");
        assert_eq!(lf.counts, lr.counts, "{ctx}: event counts");
        assert_eq!(lf.macs, lr.macs, "{ctx}: model macs");
    }
    assert_eq!(fast.totals, refr.totals, "graph totals");
    // memory-system grand totals (accumulated across layers)
    assert_eq!(a_fast.mem.stats, a_ref.mem.stats, "memory system totals");
}

fn conv(
    c_in: usize,
    c_out: usize,
    k: usize,
    stride: usize,
    pad: usize,
    act: Act,
    residual: Residual,
    time_dense: Option<usize>,
) -> Layer {
    Layer::Conv {
        c_in,
        c_out,
        k,
        stride,
        pad,
        act,
        residual,
        time_dense,
    }
}

#[test]
fn residual_pair_bit_exact() {
    // The `micro-sim residual pair` bench workload (smaller map): conv +
    // conv-with-identity-skip. Large enough to cross the threading
    // threshold, so this also pins threaded == reference.
    let mut b = GraphBuilder::new("t", TensorShape::new(16, 16, 16));
    b.add(conv(16, 16, 3, 1, 1, Act::Relu, Residual::None, None))
        .unwrap();
    b.add(conv(
        16,
        16,
        3,
        1,
        1,
        Act::None,
        Residual::Identity { from: 0 },
        None,
    ))
    .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 1);
    let mut rng = Rng::new(2);
    let x = Tensor::from_fn(&[16, 16, 16], |_| rng.normal() * 0.4);
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, None);
}

#[test]
fn downsample_conv_residual_bit_exact() {
    // ResNet-style stage entry: stride-2 conv with a 1x1/2 conv skip on
    // PE_9 — exercises the FlatServer::Conv path and strided windows.
    let mut b = GraphBuilder::new("t", TensorShape::new(6, 12, 12));
    b.add(conv(6, 6, 3, 1, 1, Act::Relu, Residual::None, None))
        .unwrap();
    b.add(conv(
        6,
        12,
        3,
        2,
        1,
        Act::None,
        Residual::Conv { from: 0, stride: 2 },
        None,
    ))
    .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 3);
    let mut rng = Rng::new(7);
    let x = Tensor::from_fn(&[6, 12, 12], |_| rng.normal() * 0.5);
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, None);
}

#[test]
fn unet_down_block_bit_exact() {
    // One U-net down-block as built by models::unet: stem conv, then a
    // block conv carrying the time-dense on PE_9, the block's second conv
    // fusing the skip, and the down-sampling max-pool. Exercises
    // FlatServer::Dense (incl. the first-group-only schedule), the skip
    // retention logic, and the pooling path.
    let td = 12usize;
    let mut b = GraphBuilder::new("t", TensorShape::new(1, 16, 16));
    b.add(conv(1, 8, 3, 1, 1, Act::Silu, Residual::None, None))
        .unwrap();
    b.add(conv(8, 8, 3, 1, 1, Act::Silu, Residual::None, Some(td)))
        .unwrap();
    b.add(conv(
        8,
        8,
        3,
        1,
        1,
        Act::None,
        Residual::Identity { from: 0 },
        None,
    ))
    .unwrap();
    b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 5);
    let mut rng = Rng::new(11);
    let x = Tensor::from_fn(&[1, 16, 16], |_| rng.normal() * 0.5);
    let emb: Vec<f32> = (0..td).map(|_| rng.normal() * 0.5).collect();
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, Some(&emb));
}

#[test]
fn full_unet_bit_exact() {
    // The whole default U-net (2 levels, concat skips, upsample, head):
    // every layer kind and SF mode in one pass.
    let g = sf_mmcn::models::unet(sf_mmcn::models::UnetConfig {
        img: 8,
        base_c: 4,
        levels: 1,
        time_dim: 8,
        img_channels: 1,
    });
    let ws = WeightStore::random(&g, 13);
    let mut rng = Rng::new(17);
    let x = Tensor::from_fn(&[1, 8, 8], |_| rng.normal() * 0.5);
    let emb: Vec<f32> = (0..8).map(|_| rng.normal() * 0.5).collect();
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, Some(&emb));
}

#[test]
fn dense_head_bit_exact() {
    // Conv -> pool -> dense classifier head: pins the dense fast path
    // (weight-row windows, broadcast input, per-row zero gating).
    let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
    b.add(conv(4, 6, 3, 1, 1, Act::Relu, Residual::None, None))
        .unwrap();
    b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
    b.add(Layer::GlobalAvgPool).unwrap();
    b.add(Layer::Dense {
        in_f: 6,
        out_f: 19, // partial final neuron group
        act: Act::None,
    })
    .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 23);
    let mut rng = Rng::new(29);
    let x = Tensor::from_fn(&[4, 8, 8], |_| rng.normal() * 0.5);
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, None);
}

#[test]
fn small_input_split_bit_exact() {
    // Tiny maps (<= 4 outputs) take the split PE-array path, which both
    // code paths share — this pins the delegation stays wired up.
    let mut b = GraphBuilder::new("t", TensorShape::new(3, 4, 4));
    b.add(conv(3, 3, 3, 1, 1, Act::None, Residual::None, None))
        .unwrap();
    b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
    b.add(conv(3, 5, 3, 1, 1, Act::None, Residual::None, None))
        .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 31);
    let mut rng = Rng::new(37);
    let x = Tensor::from_fn(&[3, 4, 4], |_| rng.normal() * 0.5);
    assert_paths_agree(&g, AcceleratorConfig::default(), &x, &ws, None);
}

#[test]
fn non_default_unit_counts_bit_exact() {
    // Unit-count sweeps change the round-robin layout and the threading
    // split; results must not.
    let mut b = GraphBuilder::new("t", TensorShape::new(5, 10, 10));
    b.add(conv(5, 7, 3, 1, 1, Act::Relu, Residual::None, None))
        .unwrap();
    b.add(conv(
        7,
        7,
        3,
        1,
        1,
        Act::None,
        Residual::Identity { from: 0 },
        None,
    ))
    .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 41);
    let mut rng = Rng::new(43);
    let x = Tensor::from_fn(&[5, 10, 10], |_| rng.normal() * 0.5);
    for units in [1usize, 2, 4, 16] {
        assert_paths_agree(&g, AcceleratorConfig::with_units(units), &x, &ws, None);
    }
}

#[test]
fn repeated_runs_reuse_quant_cache_identically() {
    // The WeightStore quantized-tap cache is filled on the first run and
    // hit on the second — results must be identical both times.
    let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
    b.add(conv(4, 4, 3, 1, 1, Act::Relu, Residual::None, None))
        .unwrap();
    let g = b.build();
    let ws = WeightStore::random(&g, 47);
    let x = Tensor::full(&[4, 8, 8], 0.3);
    let mut a1 = Accelerator::new(AcceleratorConfig::default());
    let r1 = a1.run_graph(&g, &x, &ws, None).unwrap();
    let mut a2 = Accelerator::new(AcceleratorConfig::default());
    let r2 = a2.run_graph(&g, &x, &ws, None).unwrap();
    assert_eq!(r1.output.data(), r2.output.data());
    assert_eq!(r1.totals, r2.totals);
}

/// Hand-derived golden values: 1-channel 3x3/1/p1 conv over a 4x4 map,
/// one output channel, default 8-unit array, all inputs/weights nonzero.
///
/// Derivation (independent of both implementations):
/// * 16 output positions -> 2 groups of 8 on unit 0; wall = 9 + 9 + 1
///   cold-start = 19 cycles.
/// * Worker MAC slots = 16 windows x 9 taps = 144 active cycles; padding
///   zeros = 4 corners x 5 + 8 edges x 3 = 44 gated, 100 fired.
/// * PE_9 idles through both groups: 18 idle cycles; 16 writebacks.
/// * Buffer reads: per group the reuse registers fetch c_in*k*(k-1+8)
///   = 3*(2+4+2+4) per the row-segment rule -> 36 distinct of 72 taps;
///   two groups -> 72 reads, 144 without reuse, 72 register writes.
/// * Weight broadcasts: 9 taps x 2 groups = 18 reads.
/// * Memory system: 16-elem IFM fits (1 DRAM fill + 16 buffer writes),
///   9 weight elems, 16 output writes -> 25 DRAM reads total.
#[test]
fn hand_computed_golden_values() {
    let mut b = GraphBuilder::new("t", TensorShape::new(1, 4, 4));
    b.add(conv(1, 1, 3, 1, 1, Act::None, Residual::None, None))
        .unwrap();
    let g = b.build();
    let mut ws = WeightStore::random(&g, 53);
    // all-nonzero input and weights so gating is padding-only
    ws.per_node[0].as_mut().unwrap().w = Tensor::full(&[1, 1, 3, 3], 0.5);
    ws.per_node[0].as_mut().unwrap().bias = vec![0.0];
    ws.invalidate_quant();
    let x = Tensor::full(&[1, 4, 4], 0.5);

    for reference in [false, true] {
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = if reference {
            acc.run_graph_ref(&g, &x, &ws, None).unwrap()
        } else {
            acc.run_graph(&g, &x, &ws, None).unwrap()
        };
        let label = if reference { "ref" } else { "fast" };
        let c = &run.layers[0].counts;
        assert_eq!(run.total_cycles(), 19, "{label}: wall cycles");
        assert_eq!(c.pe.active_cycles, 144, "{label}: active");
        assert_eq!(c.pe.macs, 100, "{label}: macs");
        assert_eq!(c.pe.gated_macs, 44, "{label}: gated");
        assert_eq!(c.pe.idle_cycles, 18, "{label}: idle");
        assert_eq!(c.pe.writebacks, 16, "{label}: writebacks");
        assert_eq!(c.pe.residual_adds, 0, "{label}: residual adds");
        assert_eq!(c.unit.cycles, 19, "{label}: unit cycles");
        assert_eq!(c.unit.conv_outputs, 16, "{label}: outputs");
        assert_eq!(c.unit.served_values, 0, "{label}: served");
        assert_eq!(c.unit.buffer_reads, 72, "{label}: buffer reads");
        assert_eq!(
            c.unit.buffer_reads_no_reuse, 144,
            "{label}: no-reuse reads"
        );
        assert_eq!(c.unit.reuse_reg_writes, 72, "{label}: reuse writes");
        assert_eq!(c.unit.weight_reads, 18, "{label}: weight reads");
        assert_eq!(c.mem.dram_reads, 25, "{label}: dram reads");
        assert_eq!(c.mem.input_buf_writes, 16, "{label}: ifm writes");
        assert_eq!(c.mem.weight_buf_writes, 9, "{label}: weight writes");
        assert_eq!(c.mem.output_buf_writes, 16, "{label}: ofm writes");
        // functional check: interior output = 9 taps * 0.5 * 0.5 = 2.25
        let v = run.output.get(&[0, 1, 1]);
        assert!((v - 2.25).abs() < 0.01, "{label}: interior value {v}");
    }
}
