//! Wire-protocol coverage (ISSUE 10, satellite): every message type
//! round-trips bit-exactly through the frame codec; malformed frames
//! (truncated, oversized, garbage) are rejected with position-carrying
//! errors; and a real `shard-worker` process refuses a version-mismatch
//! handshake with a `reject` frame.

use std::time::Duration;

use sf_mmcn::config::{ModelChoice, ServeBackend, ServeConfig};
use sf_mmcn::coordinator::wire::{
    write_frame, FrameReader, WireMetrics, WireModelRow, WireMsg, MAX_FRAME, WIRE_VERSION,
};
use sf_mmcn::coordinator::{
    AdmissionError, AdmissionStats, ClassifyRequest, DenoiseRequest, DenoiseResult,
    InferenceRequest,
};
use sf_mmcn::runtime::TensorBuf;

/// Round-trip one message through a frame and compare the re-rendered
/// payload (the codec's canonical form, so equal rendering means equal
/// message).
fn roundtrip_render(msg: &WireMsg) -> String {
    let mut buf = Vec::new();
    write_frame(&mut buf, msg).expect("frame writes");
    let mut r = FrameReader::new(&buf[..]);
    let back = r.next_msg().expect("frame reads").expect("one frame");
    assert!(
        r.next_msg().expect("clean tail").is_none(),
        "clean EOF after the frame"
    );
    back.render()
}

fn sample_metrics() -> WireMetrics {
    WireMetrics {
        requests_done: 42,
        steps_done: 84,
        dispatches: 21,
        batch_items: 44,
        requests_failed: 1,
        lanes_down: 0,
        cross_model_batches: 0,
        cross_shape_batches: 0,
        wall_ns: 1_234_567_890,
        admission: AdmissionStats {
            offered: 50,
            admitted: 43,
            rejected_queue_full: 5,
            rejected_deadline: 1,
            rejected_shutdown: 1,
            expired: 0,
            queue_depth: 7,
        },
        per_model: vec![
            WireModelRow {
                model: ModelChoice::Unet,
                requests_done: 40,
                steps_done: 80,
                requests_failed: 1,
            },
            WireModelRow {
                model: ModelChoice::Resnet18,
                requests_done: 2,
                steps_done: 4,
                requests_failed: 0,
            },
        ],
    }
}

#[test]
fn every_message_type_roundtrips() {
    let denoise = InferenceRequest::Denoise(DenoiseRequest {
        id: 7,
        seed: u64::MAX, // a seed only a decimal string carries exactly
        steps: 4,
        priority: 2,
        deadline: Some(Duration::from_millis(250)),
    });
    let classify = InferenceRequest::Classify(ClassifyRequest::new(8, 99, ModelChoice::Vgg16));
    let result_ok = DenoiseResult {
        id: 7,
        image: TensorBuf::new(vec![1, 2, 2], vec![0.0, -0.0, f32::MIN_POSITIVE, -1.5e-7])
            .unwrap(),
        latency: Duration::from_micros(456),
        steps: 4,
        model: ModelChoice::Unet,
    };
    let msgs = vec![
        WireMsg::Hello {
            version: WIRE_VERSION,
            worker: 3,
        },
        WireMsg::HelloAck {
            version: WIRE_VERSION,
            worker: 3,
            pid: 12345,
        },
        WireMsg::Reject {
            reason: "tricky \"quoted\" reason\nwith newline".into(),
        },
        WireMsg::Submit {
            ticket: 11,
            req: denoise,
        },
        WireMsg::Submit {
            ticket: 12,
            req: classify,
        },
        WireMsg::SubmitErr {
            ticket: 11,
            error: AdmissionError::QueueFull,
        },
        WireMsg::SubmitErr {
            ticket: 12,
            error: AdmissionError::Deadline,
        },
        WireMsg::TicketResult {
            ticket: 11,
            result: Ok(result_ok),
        },
        WireMsg::TicketResult {
            ticket: 13,
            result: Err("lane dropped the ticket".into()),
        },
        WireMsg::Heartbeat {
            seq: 999,
            queue_depth: 5,
        },
        WireMsg::Drain,
        WireMsg::MetricsReq,
        WireMsg::Metrics {
            last: true,
            snapshot: sample_metrics(),
        },
        WireMsg::Shutdown,
    ];
    for msg in &msgs {
        assert_eq!(
            roundtrip_render(msg),
            msg.render(),
            "round-trip changed {msg:?}"
        );
    }
}

#[test]
fn submit_request_fields_survive_exactly() {
    let req = InferenceRequest::Denoise(DenoiseRequest {
        id: 3,
        seed: 9_007_199_254_740_993, // > 2^53: breaks if sent as a JSON number
        steps: 6,
        priority: 1,
        deadline: None,
    });
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        &WireMsg::Submit {
            ticket: 1,
            req: req.clone(),
        },
    )
    .unwrap();
    match FrameReader::new(&buf[..]).next_msg().unwrap().unwrap() {
        WireMsg::Submit { ticket, req: back } => {
            assert_eq!(ticket, 1);
            assert_eq!(back, req, "request fields round-trip exactly");
        }
        other => panic!("wrong frame back: {other:?}"),
    }
}

#[test]
fn metrics_snapshot_reinflates_to_equal_counters() {
    let snap = sample_metrics();
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        &WireMsg::Metrics {
            last: false,
            snapshot: snap.clone(),
        },
    )
    .unwrap();
    match FrameReader::new(&buf[..]).next_msg().unwrap().unwrap() {
        WireMsg::Metrics { last, snapshot } => {
            assert!(!last);
            assert_eq!(snapshot, snap);
            let m = snapshot.to_metrics();
            assert_eq!(m.requests_done, 42);
            assert_eq!(m.admission.queue_depth, 7);
            assert_eq!(m.per_model[ModelChoice::Unet.index()].requests_done, 40);
            assert_eq!(m.per_model[ModelChoice::Resnet18.index()].steps_done, 4);
        }
        other => panic!("wrong frame back: {other:?}"),
    }
}

#[test]
fn truncated_frames_carry_frame_and_byte_position() {
    let mut buf = Vec::new();
    write_frame(&mut buf, &WireMsg::Drain).unwrap();
    let first = buf.len();
    write_frame(
        &mut buf,
        &WireMsg::Heartbeat {
            seq: 1,
            queue_depth: 0,
        },
    )
    .unwrap();

    // cut mid-header of frame 1
    let mut r = FrameReader::new(&buf[..first + 3]);
    assert!(matches!(r.next_msg().unwrap(), Some(WireMsg::Drain)));
    let err = r.next_msg().unwrap_err().to_string();
    assert!(err.contains("frame 1"), "{err}");
    assert!(err.contains(&format!("byte {first}")), "{err}");
    assert!(err.contains("truncated header (3 of 4 bytes)"), "{err}");

    // cut mid-payload of frame 1
    let mut r = FrameReader::new(&buf[..first + 9]);
    r.next_msg().unwrap();
    let err = r.next_msg().unwrap_err().to_string();
    assert!(err.contains("frame 1"), "{err}");
    assert!(err.contains(&format!("byte {}", first + 4)), "{err}");
    assert!(err.contains("truncated payload"), "{err}");
}

#[test]
fn oversized_garbage_and_non_utf8_frames_rejected() {
    // corrupted length prefix
    let mut buf = (MAX_FRAME + 7).to_le_bytes().to_vec();
    buf.extend_from_slice(b"irrelevant");
    let err = FrameReader::new(&buf[..]).next_msg().unwrap_err().to_string();
    assert!(err.contains("oversized frame"), "{err}");
    assert!(err.contains("frame 0 at byte 0"), "{err}");

    // valid length, garbage payload
    let payload = b"}{ definitely not json";
    let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
    buf.extend_from_slice(payload);
    let err = FrameReader::new(&buf[..]).next_msg().unwrap_err().to_string();
    assert!(err.contains("bad payload"), "{err}");

    // valid length, non-UTF-8 payload
    let payload = [0xffu8, 0xfe, 0xfd, 0xfc];
    let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
    buf.extend_from_slice(&payload);
    let err = FrameReader::new(&buf[..]).next_msg().unwrap_err().to_string();
    assert!(err.contains("not UTF-8"), "{err}");

    // a frame is rejected without consuming it: position stays at 0
    let mut buf = (3u32).to_le_bytes().to_vec();
    buf.extend_from_slice(b"{}x");
    let mut r = FrameReader::new(&buf[..]);
    assert!(r.next_msg().is_err());
    assert_eq!(r.frames_read(), 0);
}

#[test]
fn unknown_types_and_wrong_admission_codes_rejected() {
    for bad in [
        "{\"type\":\"warp\"}",
        "{\"type\":\"submit_err\",\"ticket\":0,\"error\":\"oom\"}",
        "{\"type\":\"result\",\"ticket\":0}",
        "{\"type\":\"result\",\"ticket\":0,\"ok\":{},\"err\":\"both\"}",
        "{\"type\":\"hello\",\"version\":-1,\"worker\":0}",
        "{\"no_type\":true}",
    ] {
        let mut buf = (bad.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(bad.as_bytes());
        assert!(
            FrameReader::new(&buf[..]).next_msg().is_err(),
            "accepted bad payload: {bad}"
        );
    }
}

/// A real `shard-worker` process must answer a version-mismatch hello
/// with a `reject` frame (and a slot-mismatch likewise), then exit —
/// the handshake is what keeps incompatible builds from misparsing
/// each other.
#[cfg(unix)]
mod handshake {
    use super::*;
    use std::io::Write as _;
    use std::os::unix::net::UnixStream;
    use std::process::{Command, Stdio};
    use std::time::Instant;

    fn worker_cfg() -> ServeConfig {
        ServeConfig {
            steps: 1,
            workers: 1,
            max_batch: 1,
            backend: ServeBackend::Native,
            batched: true,
            chunk: 1,
            ..ServeConfig::default()
        }
    }

    fn connect_with_retry(path: &std::path::Path) -> UnixStream {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match UnixStream::connect(path) {
                Ok(s) => return s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        panic!("worker socket {} never came up: {e}", path.display());
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    #[test]
    fn worker_rejects_version_and_slot_mismatch() {
        let dir = std::env::temp_dir().join(format!("sf-mmcn-wire-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("worker.toml");
        std::fs::write(&cfg_path, worker_cfg().to_toml()).unwrap();

        for (hello, expect) in [
            (
                WireMsg::Hello {
                    version: WIRE_VERSION + 1,
                    worker: 0,
                },
                "version mismatch",
            ),
            (
                WireMsg::Hello {
                    version: WIRE_VERSION,
                    worker: 5,
                },
                "slot mismatch",
            ),
        ] {
            let socket = dir.join(format!("handshake-{expect}.sock").replace(' ', "-"));
            let _ = std::fs::remove_file(&socket);
            let mut child = Command::new(env!("CARGO_BIN_EXE_sf-mmcn"))
                .arg("shard-worker")
                .arg("--config")
                .arg(&cfg_path)
                .arg("--socket")
                .arg(&socket)
                .arg("--worker")
                .arg("0")
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn shard-worker");
            let mut stream = connect_with_retry(&socket);
            write_frame(&mut stream, &hello).unwrap();
            stream.flush().unwrap();
            let mut reader = FrameReader::new(stream.try_clone().unwrap());
            match reader.next_msg().expect("reject frame reads") {
                Some(WireMsg::Reject { reason }) => {
                    assert!(reason.contains(expect), "reason `{reason}` for {expect}");
                }
                other => panic!("expected a reject frame, got {other:?}"),
            }
            let status = child.wait().expect("worker exits after reject");
            assert!(!status.success(), "mismatch handshake must exit nonzero");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
