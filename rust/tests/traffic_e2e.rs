//! End-to-end tests for the traffic-realism subsystem (ISSUE 8): seeded
//! arrival-process generators, the `serve.traffic` config grammar, and
//! the JSON-lines trace record/replay format.
//!
//! Everything here runs offline on the native surrogate backend — the
//! determinism contract (request execution is a pure function of
//! `(model, seed, steps)`) is what makes trace replay bit-identical,
//! and these tests are the tier-1 gate on that contract.

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    read_trace, recorded_workload, write_trace, DiffusionServer, TrafficProfile,
};
use sf_mmcn::runtime::ArtifactStore;

fn native_cfg(steps: usize, requests: usize) -> ServeConfig {
    ServeConfig {
        steps,
        requests,
        workers: 2,
        max_batch: 4,
        batched: true,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        pipeline: true,
        chunk: 0,
        pooled: true,
        ..ServeConfig::default()
    }
}

fn all_profiles() -> Vec<TrafficProfile> {
    vec![
        TrafficProfile::parse("uniform:40").unwrap(),
        TrafficProfile::parse("poisson:40").unwrap(),
        TrafficProfile::parse("ou:40:2:10").unwrap(),
        TrafficProfile::parse("burst:20:100:1000:100").unwrap(),
        TrafficProfile::parse("ramp:10:50:2000").unwrap(),
        TrafficProfile::parse("sine:40:20:1000").unwrap(),
    ]
}

// ----------------------------------------------------- arrival schedules

#[test]
fn schedules_are_deterministic_and_monotone() {
    for p in all_profiles() {
        let a = p.schedule(123, 200);
        let b = p.schedule(123, 200);
        assert_eq!(a, b, "{}: same seed must give the same schedule", p.render());
        assert_eq!(a.len(), 200, "{}", p.render());
        for w in a.windows(2) {
            assert!(w[0] <= w[1], "{}: arrivals must be nondecreasing", p.render());
        }
    }
    // stochastic profiles actually use the seed
    for spec in ["poisson:40", "ou:40:2:10"] {
        let p = TrafficProfile::parse(spec).unwrap();
        assert_ne!(
            p.schedule(1, 100),
            p.schedule(2, 100),
            "{spec}: different seeds must give different schedules"
        );
    }
}

#[test]
fn uniform_schedule_matches_the_legacy_fixed_interval() {
    // `--open-loop --rate R` historically placed request i at i/R; the
    // uniform profile must reproduce that exactly so `--traffic
    // uniform:R` is a drop-in replacement.
    let p = TrafficProfile::parse("uniform:8").unwrap();
    let sched = p.schedule(99, 16);
    for (i, &ns) in sched.iter().enumerate() {
        let expect = (i as f64 / 8.0 * 1e9).round() as u64;
        assert_eq!(ns, expect, "request {i}");
    }
}

#[test]
fn ou_rate_path_reverts_to_the_mean_within_bounds() {
    let p = TrafficProfile::parse("ou:60:2:15").unwrap();
    let (lo, hi) = p.ou_bounds().expect("ou has clamp bounds");
    assert!(lo > 0.0 && hi > 60.0);
    let trace = p.rate_trace(7, 4000);
    assert_eq!(trace, p.rate_trace(7, 4000), "rate path is seeded");
    let mut mean = 0.0;
    for &r in &trace {
        assert!((lo..=hi).contains(&r), "rate {r} escaped [{lo}, {hi}]");
        mean += r;
    }
    mean /= trace.len() as f64;
    // mean reversion: the 40 s time-average stays near the long-run mean
    assert!(
        (mean - 60.0).abs() < 15.0,
        "OU time-average {mean:.1} strayed from the mean 60"
    );
}

#[test]
fn burst_and_ramp_schedules_have_the_right_shape() {
    // burst:20:100:1000:100 — 100 ms at 100 req/s then 900 ms at 20
    // req/s: one period holds 10 + 18 arrivals, 10 of them in-burst.
    let p = TrafficProfile::parse("burst:20:100:1000:100").unwrap();
    let sched = p.schedule(0, 28);
    let in_burst = sched.iter().filter(|&&ns| ns < 100_000_000).count();
    assert!(
        (9..=11).contains(&in_burst),
        "expected ~10 of 28 arrivals inside the 100 ms burst window, got {in_burst}"
    );
    // ramp:10:50:2000 — the gap between consecutive arrivals shrinks
    let p = TrafficProfile::parse("ramp:10:50:2000").unwrap();
    let sched = p.schedule(0, 20);
    let first_gap = sched[1] - sched[0];
    let last_gap = sched[19] - sched[18];
    assert!(
        last_gap < first_gap,
        "ramp-up must compress inter-arrival gaps ({first_gap} ns -> {last_gap} ns)"
    );
}

// ------------------------------------------------------- config grammar

#[test]
fn traffic_grammar_errors_name_the_bad_key() {
    let err = TrafficProfile::parse("ou:60:x:15").unwrap_err().to_string();
    assert!(err.contains("bad theta"), "{err}");
    let err = TrafficProfile::parse("warp:9").unwrap_err().to_string();
    assert!(err.contains("unknown profile `warp`"), "{err}");
    let err = TrafficProfile::parse("uniform:0").unwrap_err().to_string();
    assert!(err.contains("rate must be positive"), "{err}");

    // the config layer prefixes the offending key, like serve.fault_spec
    let err = ServeConfig::from_toml("[serve]\ntraffic = \"sine:10:90:500\"\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("serve.traffic"), "{err}");
    assert!(err.contains("amp must be in [0, base]"), "{err}");
}

#[test]
fn traffic_specs_round_trip_through_config_and_render() {
    for p in all_profiles() {
        let spec = p.render();
        let toml = format!("[serve]\ntraffic = \"{spec}\"\n");
        let cfg = ServeConfig::from_toml(&toml).unwrap();
        let parsed = cfg.parsed_traffic().unwrap().expect("profile set");
        assert_eq!(parsed, p, "{spec}");
        assert_eq!(parsed.render(), spec, "render is canonical");
    }
}

// ------------------------------------------------- trace record / replay

#[test]
fn trace_file_round_trips_request_for_request() {
    let mut cfg = native_cfg(3, 10);
    // mixed traffic so the trace holds both denoise and classify records
    cfg.model_mix = "unet:2,resnet18:1,vgg16:1".into();
    let profile = TrafficProfile::parse("ou:200:2:50").unwrap();
    let records = recorded_workload(&cfg, &profile, cfg.seed, 10);
    assert_eq!(records.len(), 10);
    for w in records.windows(2) {
        assert!(w[0].arrival_ns <= w[1].arrival_ns);
    }
    let path = std::env::temp_dir().join("sf_mmcn_traffic_e2e_trace.jsonl");
    write_trace(&path, &records).unwrap();
    let back = read_trace(&path).unwrap();
    assert_eq!(back, records, "parse(render(trace)) must be the identity");
    std::fs::remove_file(&path).ok();
}

#[test]
fn trace_replay_results_are_bit_identical() {
    let mut cfg = native_cfg(3, 8);
    cfg.model_mix = "unet:2,resnet18:1,vgg16:1".into();
    let profile = TrafficProfile::parse("burst:50:400:200:50").unwrap();
    let records = recorded_workload(&cfg, &profile, cfg.seed, 8);
    let path = std::env::temp_dir().join("sf_mmcn_traffic_e2e_replay.jsonl");
    write_trace(&path, &records).unwrap();
    let replayed = read_trace(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let store = ArtifactStore::new("artifacts");
    let serve = |reqs: Vec<_>| {
        let server = DiffusionServer::new(cfg.clone(), &store).expect("native server");
        let (mut results, _) = server.serve(reqs).expect("serve");
        results.sort_by_key(|r| r.id);
        results
    };
    let a = serve(records.into_iter().map(|r| r.request).collect());
    let b = serve(replayed.into_iter().map(|r| r.request).collect());
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.id, rb.id);
        assert_eq!(ra.image.shape, rb.image.shape);
        let bits_a: Vec<u32> = ra.image.data.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = rb.image.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "request {} replayed differently", ra.id);
    }
}
