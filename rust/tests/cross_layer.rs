//! Cross-layer validation: the rust cycle-accurate simulator (16-bit
//! fixed point) and the AOT-compiled Pallas/JAX artifact (f32 via PJRT)
//! must compute the *same U-net* — same trained weights, same input —
//! within quantization tolerance.
//!
//! This closes the loop across all three layers: python L1/L2 define the
//! network, `aot.py` exports weights + HLO, and the rust graph in
//! `models::unet` must be the same architecture node for node.
//!
//! Requires `make artifacts`.

use sf_mmcn::coordinator::ddpm::time_embedding;
use sf_mmcn::coordinator::UnetParams;
use sf_mmcn::models::graph::Layer;
use sf_mmcn::models::{unet, UnetConfig};
use sf_mmcn::runtime::{ArtifactStore, Executor, TensorBuf};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::util::{Rng, Tensor};

/// Map the python manifest (stem/enc0/enc1/mid/dec1/dec0/head) onto the
/// rust graph's conv nodes, in node order.
fn weights_from_params(
    g: &sf_mmcn::models::ModelGraph,
    params: &UnetParams,
) -> WeightStore {
    let get = |name: &str| -> Tensor {
        let idx = params
            .names
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("param {name} missing"));
        let t = &params.tensors[idx];
        Tensor::new(&t.shape, t.data.clone()).unwrap()
    };
    let getv = |name: &str| -> Vec<f32> { get(name).into_data() };

    let mut ws = WeightStore::random(g, 0);
    // Python block tags in rust-graph conv order: stem, enc0 (conv1,
    // conv2), enc1, mid, dec1, dec0, head. Conv nodes appear in exactly
    // this order in models::unet.
    let tags = ["enc0", "enc1", "mid", "dec1", "dec0"];
    let mut conv_nodes = g
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(n.layer, Layer::Conv { .. }));

    // stem
    let (i, _) = conv_nodes.next().unwrap();
    {
        let nw = ws.per_node[i].as_mut().unwrap();
        nw.w = get("stem.w");
        nw.bias = getv("stem.b");
    }
    // blocks
    for tag in tags {
        let (i1, _) = conv_nodes.next().unwrap();
        {
            let nw = ws.per_node[i1].as_mut().unwrap();
            nw.w = get(&format!("{tag}.w1"));
            nw.bias = getv(&format!("{tag}.b1"));
            nw.w_time = Some(get(&format!("{tag}.wt")));
        }
        let (i2, node2) = conv_nodes.next().unwrap();
        {
            let has_res_conv = matches!(
                node2.layer,
                Layer::Conv {
                    residual: sf_mmcn::models::graph::Residual::Conv { .. },
                    ..
                }
            );
            let nw = ws.per_node[i2].as_mut().unwrap();
            nw.w = get(&format!("{tag}.w2"));
            nw.bias = getv(&format!("{tag}.b2"));
            nw.w_res = if has_res_conv {
                Some(get(&format!("{tag}.wres")))
            } else {
                None
            };
        }
    }
    // head
    let (i, _) = conv_nodes.next().unwrap();
    {
        let nw = ws.per_node[i].as_mut().unwrap();
        nw.w = get("head.w");
        nw.bias = getv("head.b");
    }
    assert!(conv_nodes.next().is_none(), "all conv nodes mapped");
    // weights were replaced in place: drop any cached quantized taps
    ws.invalidate_quant();
    ws
}

#[test]
fn unet_sim_matches_pjrt_artifact() {
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve("unet_eps_16") else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let params = UnetParams::load(store.root(), "unet_params").unwrap();

    // ---- PJRT reference (f32, the trained network) ----------------------
    let mut exe = Executor::new().unwrap();
    if let Err(e) = exe.load_hlo_text("eps", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return;
    }
    let mut rng = Rng::new(99);
    let x: Vec<f32> = (0..256).map(|_| rng.normal() * 0.5).collect();
    let t_emb = time_embedding(7.0, 32);
    let mut inputs = vec![
        TensorBuf::new(vec![1, 16, 16], x.clone()).unwrap(),
        TensorBuf::new(vec![32], t_emb.clone()).unwrap(),
    ];
    inputs.extend(params.tensors.iter().cloned());
    let out = exe.run("eps", &inputs).unwrap();
    let pjrt = Tensor::new(&[1, 16, 16], out[0].data.clone()).unwrap();

    // ---- rust micro simulator (Q8.8) -------------------------------------
    let g = unet(UnetConfig::default());
    let ws = weights_from_params(&g, &params);
    let xt = Tensor::new(&[1, 16, 16], x).unwrap();
    let mut acc = Accelerator::new(AcceleratorConfig::default());
    let run = acc.run_graph(&g, &xt, &ws, Some(&t_emb)).unwrap();

    // ---- compare ---------------------------------------------------------
    assert_eq!(run.output.shape(), pjrt.shape());
    let max_diff = run.output.max_abs_diff(&pjrt).unwrap();
    let mean_diff: f64 = run
        .output
        .data()
        .iter()
        .zip(pjrt.data())
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / 256.0;
    println!("unet sim-vs-pjrt: max diff {max_diff:.4}, mean diff {mean_diff:.4}");
    // 18 quantized layers deep: allow a generous fixed-point budget, but
    // the two must clearly compute the same function.
    assert!(
        mean_diff < 0.08,
        "mean deviation {mean_diff} too large — architectures diverged?"
    );
    assert!(max_diff < 0.5, "max deviation {max_diff}");

    // and the run must exercise the SF modes: 5 time-dense layers + 5
    // skip layers
    let time_layers = run
        .layers
        .iter()
        .filter(|l| l.label.contains("+time"))
        .count();
    let skip_layers = run
        .layers
        .iter()
        .filter(|l| l.label.contains("+skip"))
        .count();
    assert_eq!(time_layers, 5);
    assert_eq!(skip_layers, 5);
}

#[test]
fn resnet_block_artifact_matches_sim_unit() {
    let store = ArtifactStore::new("artifacts");
    let Ok(spec) = store.resolve("resnet_block_16") else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut exe = Executor::new().unwrap();
    if let Err(e) = exe.load_hlo_text("rblock", &spec.path) {
        eprintln!("skipping: PJRT runtime unavailable ({e:#})");
        return;
    }

    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..2048).map(|_| rng.normal() * 0.3).collect();
    let w1: Vec<f32> = (0..576).map(|_| rng.normal() * 0.15).collect();
    let w2: Vec<f32> = (0..576).map(|_| rng.normal() * 0.15).collect();
    let out = exe
        .run(
            "rblock",
            &[
                TensorBuf::new(vec![8, 16, 16], x.clone()).unwrap(),
                TensorBuf::new(vec![8, 8, 3, 3], w1.clone()).unwrap(),
                TensorBuf::new(vec![8], vec![0.0; 8]).unwrap(),
                TensorBuf::new(vec![8, 8, 3, 3], w2.clone()).unwrap(),
                TensorBuf::new(vec![8], vec![0.0; 8]).unwrap(),
            ],
        )
        .unwrap();

    // Same block on the simulator: conv1(relu) then conv2+skip, relu at
    // the end. Identity-from-graph-input isn't expressible in the builder
    // (skips reference node indices), so a leading delta conv passes the
    // input through as node 0.
    use sf_mmcn::models::graph::{Act, GraphBuilder, Residual, TensorShape};
    let mut b2 = GraphBuilder::new("rb", TensorShape::new(8, 16, 16));
    b2.add(Layer::Conv {
        c_in: 8,
        c_out: 8,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::None,
        time_dense: None,
    })
    .unwrap();
    b2.add(Layer::Conv {
        c_in: 8,
        c_out: 8,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Relu,
        residual: Residual::None,
        time_dense: None,
    })
    .unwrap();
    b2.add(Layer::Conv {
        c_in: 8,
        c_out: 8,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::Identity { from: 0 },
        time_dense: None,
    })
    .unwrap();
    let g = b2.build();
    let mut ws = WeightStore::random(&g, 0);
    let delta = Tensor::from_fn(&[8, 8, 3, 3], |idx| {
        f32::from(idx[0] == idx[1] && idx[2] == 1 && idx[3] == 1)
    });
    ws.per_node[0].as_mut().unwrap().w = delta;
    ws.per_node[0].as_mut().unwrap().bias = vec![0.0; 8];
    ws.per_node[1].as_mut().unwrap().w = Tensor::new(&[8, 8, 3, 3], w1).unwrap();
    ws.per_node[1].as_mut().unwrap().bias = vec![0.0; 8];
    ws.per_node[2].as_mut().unwrap().w = Tensor::new(&[8, 8, 3, 3], w2).unwrap();
    ws.per_node[2].as_mut().unwrap().bias = vec![0.0; 8];
    ws.invalidate_quant();

    let xt = Tensor::new(&[8, 16, 16], x).unwrap();
    let mut acc = Accelerator::new(AcceleratorConfig::default());
    let run = acc.run_graph(&g, &xt, &ws, None).unwrap();
    // artifact applies a final relu; the sim graph ends without it
    let sim_out = run.output.relu();
    let pjrt = Tensor::new(&[8, 16, 16], out[0].data.clone()).unwrap();
    let diff = sim_out.max_abs_diff(&pjrt).unwrap();
    println!("resnet block sim-vs-pjrt max diff: {diff:.4}");
    assert!(diff < 0.2, "{diff}");
}
