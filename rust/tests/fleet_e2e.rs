//! Fleet failover end-to-end tests (ISSUE 6): the acceptance criteria
//! for fault-tolerant sharded serving, on the offline native backend.
//!
//! The central claim is **bit-identical recovery**: request execution is
//! a pure function of `(model, seed, steps)`, so when a shard dies mid-flight
//! and the fleet re-admits its undelivered work onto survivors, every
//! delivered image equals the no-fault run byte for byte — failover is
//! invisible except in the failover counters.
//!
//! All scenarios are driven by the seeded fault plane (`FaultSpec`), so
//! a failing run replays exactly from the spec string in the assertion
//! message.

use std::time::{Duration, Instant};

use sf_mmcn::config::{ModelChoice, ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    workload, DenoiseResult, DiffusionServer, FaultSpec, FleetTicket, ShardFleet, ShardState,
};
use sf_mmcn::runtime::ArtifactStore;

/// Fleet config on the native surrogate: two-ish small shards, per-step
/// dispatches (chunk = 1) so executing lanes beat the pulse every few
/// milliseconds — far inside the 10 ms × 8 heartbeat tolerance.
fn fleet_cfg(shards: usize, steps: usize) -> ServeConfig {
    ServeConfig {
        steps,
        requests: 0,
        workers: 1,
        max_batch: 2,
        seed: 11,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        batched: true,
        pipeline: false,
        chunk: 1,
        pooled: true,
        queue_depth: 64,
        priorities: 2,
        shards,
        heartbeat_ms: 10,
        heartbeat_misses: 8,
        ..ServeConfig::default()
    }
}

fn store() -> ArtifactStore {
    ArtifactStore::new("artifacts")
}

/// The no-fault reference: the same workload through a plain single
/// session. Results are sorted by id for positional comparison.
fn baseline(cfg: &ServeConfig, n: usize) -> Vec<DenoiseResult> {
    let mut solo = cfg.clone();
    solo.shards = 1;
    solo.fault_spec = String::new();
    let server = DiffusionServer::new(solo, &store()).expect("native baseline server");
    let (mut r, _) = server
        .serve(workload(cfg, cfg.seed, 0..n))
        .expect("no-fault baseline serves everything");
    r.sort_by_key(|x| x.id);
    r
}

fn submit_all(fleet: &ShardFleet, cfg: &ServeConfig, n: usize) -> Vec<FleetTicket> {
    workload(cfg, cfg.seed, 0..n)
        .into_iter()
        .map(|r| fleet.submit(r).expect("front door admits the workload"))
        .collect()
}

fn wait_all(tickets: Vec<FleetTicket>, what: &str) -> Vec<DenoiseResult> {
    let mut results: Vec<DenoiseResult> = tickets
        .into_iter()
        .map(|t| {
            let id = t.id();
            t.wait()
                .unwrap_or_else(|e| panic!("{what}: fleet ticket {id} lost or failed: {e}"))
        })
        .collect();
    results.sort_by_key(|r| r.id);
    results
}

fn assert_bit_identical(got: &[DenoiseResult], want: &[DenoiseResult], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: delivered-set size");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.id, w.id, "{what}: delivered-set ids");
        assert_eq!(
            g.image.data, w.image.data,
            "{what}: request {} diverged from the no-fault run — recovery must be bit-identical",
            g.id
        );
    }
}

#[test]
fn seeded_shard_kill_recovers_bit_identically_with_zero_lost_tickets() {
    // THE acceptance test: a seeded mid-flight shard kill, then failover.
    // Every ticket resolves Ok (zero lost), and every delivered image is
    // byte-equal to the no-fault run.
    let n = 16;
    let cfg = fleet_cfg(2, 3);
    let want = baseline(&cfg, n);
    // horizon 2 pins the kill to the victim's second executed request,
    // so the event is guaranteed to fire early in any balanced routing
    let spec = FaultSpec::seeded_kill(0xf0, 2, 2);
    let rendered = spec.render();
    let fleet = ShardFleet::start_with_spec(cfg.clone(), &store(), spec).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "seeded kill");
    assert_bit_identical(&got, &want, "seeded kill");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.submitted, n as u64);
    assert_eq!(m.stats.delivered, n as u64, "zero lost tickets");
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 1, "the seeded kill fired ({rendered})");
    assert!(
        m.stats.requeued >= 1,
        "the killed shard held undelivered work ({rendered})"
    );
    assert_eq!(m.stats.dead, 1);
    assert_eq!(m.stats.live, 1);
    assert_eq!(m.e2e_latency.count(), n as u64);
}

#[test]
fn mixed_workload_shard_kill_recovers_bit_identically() {
    // ISSUE 7 acceptance: the same failover guarantee under multi-mode
    // traffic. A balanced U-net / ResNet-18 / VGG-16 mix survives a
    // seeded mid-flight shard kill with zero lost tickets, every
    // delivered tensor byte-equal to the no-fault run, and the per-model
    // fleet rows accounting for every mode.
    let n = 12;
    let mut cfg = fleet_cfg(2, 3);
    cfg.model_mix = "unet:1,resnet18:1,vgg16:1".into();
    let want = baseline(&cfg, n);
    let spec = FaultSpec::seeded_kill(0xa7, 2, 2);
    let rendered = spec.render();
    let fleet = ShardFleet::start_with_spec(cfg.clone(), &store(), spec).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "mixed kill");
    assert_bit_identical(&got, &want, "mixed kill");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.submitted, n as u64);
    assert_eq!(m.stats.delivered, n as u64, "zero lost tickets ({rendered})");
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 1, "the seeded kill fired ({rendered})");
    assert_eq!(m.stats.dead, 1);
    // 12 requests over a 1:1:1 mix = 4 per mode, all delivered
    for row in &m.per_model {
        assert_eq!(row.requests_done, 4, "{}", row.model.name());
        assert_eq!(row.requests_failed, 0, "{}", row.model.name());
        assert_eq!(row.e2e_latency.count(), 4, "{}", row.model.name());
    }
    // shard-summed step counters: a dead shard's counters die with it and
    // requeued work re-executes, so exact totals are not deterministic —
    // but the kill fires on the victim's second request, so the survivor
    // executed at least two requests of every mode and every row saw steps.
    assert!(m.per_model[ModelChoice::Unet.index()].steps_done > 0);
    assert!(m.per_model[ModelChoice::Resnet18.index()].steps_done > 0);
    assert!(m.per_model[ModelChoice::Vgg16.index()].steps_done > 0);
    assert!(m.render().contains("per-model:"), "{}", m.render());
}

#[test]
fn literal_fault_spec_kill_matches_seeded_path() {
    // The same scenario via the literal spec grammar — the reproducible
    // form EXPERIMENTS.md documents. kill:0:1 = shard 0 dies claiming
    // its second request.
    let n = 12;
    let mut cfg = fleet_cfg(2, 3);
    cfg.fault_spec = "kill:0:1".into();
    let want = baseline(&cfg, n);
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "literal kill");
    assert_bit_identical(&got, &want, "literal kill");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failovers, 1);
    assert_eq!(m.stats.dead, 1);
}

#[test]
fn preemption_drain_loses_nothing_and_reexecutes_nothing() {
    // Companion acceptance test: a preemption notice drains the shard —
    // every admitted ticket resolves in place (no requeue, no duplicate
    // execution) and the shard parks as Drained.
    let n = 12;
    let cfg = fleet_cfg(2, 3);
    let want = baseline(&cfg, n);
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    fleet.begin_preempt(0).unwrap();
    let got = wait_all(tickets, "preemption");
    assert_bit_identical(&got, &want, "preemption");
    // the monitor parks the drained shard asynchronously
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.shard_states()[0] != ShardState::Drained {
        assert!(Instant::now() < deadline, "shard 0 never finished its drain");
        std::thread::sleep(Duration::from_millis(1));
    }
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 0, "preemption is not a failure");
    assert_eq!(m.stats.requeued, 0, "drain resolves work in place");
    assert_eq!(m.stats.drained, 1);
    assert_eq!(m.stats.live, 1);
    let done: usize = m.per_shard.iter().map(|s| s.requests_done).sum();
    assert_eq!(done, n, "every request executed exactly once");
}

#[test]
fn preempt_file_sentinel_drains_the_named_shard() {
    // ISSUE 10 satellite: the spot-interruption sentinel. When
    // `serve.preempt_file` appears, the monitor reads the shard index
    // from its contents and begins a preemption drain — the file-based
    // analogue of a cloud instance reclaim notice. Same guarantees as
    // an API-driven preemption: nothing lost, nothing re-executed.
    let n = 12;
    let mut cfg = fleet_cfg(2, 2);
    let sentinel = std::env::temp_dir().join(format!(
        "sf-mmcn-preempt-{}.sentinel",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&sentinel);
    cfg.preempt_file = sentinel.display().to_string();
    let want = baseline(&cfg, n);
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    // the reclaim notice arrives mid-flight, naming shard 1
    std::fs::write(&sentinel, "1\n").unwrap();
    let got = wait_all(tickets, "sentinel preemption");
    assert_bit_identical(&got, &want, "sentinel preemption");
    // the monitor notices the file and parks the drained shard
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.shard_states()[1] != ShardState::Drained {
        assert!(
            Instant::now() < deadline,
            "sentinel never drained shard 1: {:?}",
            fleet.shard_states()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    let m = fleet.shutdown().unwrap();
    let _ = std::fs::remove_file(&sentinel);
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failed, 0);
    assert_eq!(m.stats.failovers, 0, "a reclaim notice is not a failure");
    assert_eq!(m.stats.requeued, 0, "drain resolves work in place");
    assert_eq!(m.stats.drained, 1);
    assert_eq!(m.stats.live, 1);
}

#[test]
fn stalled_shard_fails_over_via_missed_heartbeats() {
    // A wedged lane never drops its tickets, so the Lost fast path stays
    // silent — only the heartbeat monitor can notice. Stall shard 0 for
    // 800 ms against a 10 ms x 5 = 50 ms tolerance: the monitor must
    // declare it dead and move its work to the survivor.
    let n = 10;
    let mut cfg = fleet_cfg(2, 3);
    cfg.heartbeat_ms = 10;
    cfg.heartbeat_misses = 5;
    cfg.fault_spec = "stall:0:0:800".into();
    let want = baseline(&cfg, n);
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "stall failover");
    assert_bit_identical(&got, &want, "stall failover");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failed, 0);
    assert_eq!(
        m.stats.failovers, 1,
        "missed heartbeats retired the wedged shard"
    );
    assert!(m.stats.requeued >= 1, "the wedged shard held claimed work");
    assert_eq!(m.stats.dead, 1);
}

#[test]
fn delayed_delivery_fault_slows_but_loses_nothing() {
    // delay events sit inside the heartbeat tolerance: nothing fails
    // over, nothing is lost — latency is the only casualty.
    let n = 6;
    let mut cfg = fleet_cfg(2, 2);
    cfg.fault_spec = "delay:0:1:30;delay:1:1:30".into();
    let want = baseline(&cfg, n);
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    let got = wait_all(tickets, "delayed delivery");
    assert_bit_identical(&got, &want, "delayed delivery");
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.stats.delivered, n as u64);
    assert_eq!(m.stats.failovers, 0, "a slow delivery is not a death");
    assert_eq!(m.stats.requeued, 0);
}

#[test]
fn fleet_render_reports_failover_counters() {
    let n = 8;
    let mut cfg = fleet_cfg(2, 2);
    cfg.fault_spec = "kill:1:0".into();
    let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
    let tickets = submit_all(&fleet, &cfg, n);
    wait_all(tickets, "render scenario");
    let m = fleet.shutdown().unwrap();
    let rendered = m.render();
    assert!(rendered.contains("fleet: 2 shards"), "{rendered}");
    assert!(rendered.contains("failover:"), "{rendered}");
    assert!(rendered.contains("shard 0:"), "{rendered}");
    assert!(rendered.contains("shard 1:"), "{rendered}");
}
