//! Compile-time API shim for the `xla` crate's PJRT surface.
//!
//! The offline build environment has neither the vendored `xla` crate nor
//! the `xla_extension` shared library, which used to mean that `cargo
//! check --features pjrt` could not even *type-check* the real executor —
//! API drift in `src/runtime/executor.rs` went unnoticed until someone
//! built on a machine with the full toolchain. This shim mirrors exactly
//! the API surface the executor consumes (types, generics, error
//! plumbing) so the feature-matrix CI job keeps the PJRT path compiling.
//!
//! Every entry point that would need the real runtime returns
//! [`Error::Unavailable`] at *runtime* (client construction fails first),
//! so a shim-linked binary behaves like the stub: callers that probe the
//! executor (tests, benches) skip cleanly. Host-only `Literal` plumbing
//! (construction/reshape) works for real, since conversions happen before
//! client probing in some call paths — including the batched serving
//! entry points (`Executor::run_batched`/`run_batched_into`, ISSUE 4),
//! which stack `[B, ...]` dispatch tensors into literals before any
//! executable is consulted.

use std::fmt;

/// The shim's error type — mirrors the real crate's in the one way the
/// executor cares about: it converts into `anyhow::Error`.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} is unavailable: this binary links the xla API shim \
                 (vendor/xla_shim), not the real xla crate — point the `xla` \
                 dependency in rust/Cargo.toml at the vendored crate with the \
                 xla_extension library to execute artifacts"
            ),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal: a flat f32 buffer plus dims. Construction and reshape
/// work for real; device-derived accessors error.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = self.data.len() as i64;
        // rank-0 reshape of a 1-element literal is the scalar case
        if n != have && !(dims.is_empty() && have == 1) {
            return Err(Error::Shape(format!(
                "reshape to {dims:?} wants {n} elements, literal has {have}"
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::decompose_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: FromLiteral>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Element types extractable from a literal (the executor only uses f32).
pub trait FromLiteral: Sized {}
impl FromLiteral for f32 {}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Always errors: there is no PJRT runtime behind the shim. Probing
    /// callers (tests, benches) treat this exactly like the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-shim (no runtime)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_host_plumbing_works() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3]).is_err());
        let s = Literal::vec1(&[5.0]).reshape(&[]).unwrap();
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn batched_dispatch_literal_shapes_work() {
        // The executor's batched/in-place serving path reshapes stacked
        // host tensors to [B, ...] before probing any executable; that
        // plumbing must keep working against the shim so the pjrt
        // feature-matrix job exercises the real call sequence.
        let b = 4;
        let images = vec![0.5f32; b * 256];
        let x = Literal::vec1(&images);
        let stacked = x.reshape(&[b as i64, 1, 16, 16]).unwrap();
        assert_eq!(stacked.array_shape().unwrap().dims(), &[4, 1, 16, 16]);
        // chunked noise tensors carry a [B, C, ...] leading pair
        let noises = vec![0.0f32; b * 2 * 256];
        let n = Literal::vec1(&noises);
        let chunk = n.reshape(&[b as i64, 2, 1, 16, 16]).unwrap();
        assert_eq!(chunk.array_shape().unwrap().dims().len(), 5);
        // device-derived accessors still refuse (shim has no runtime)
        assert!(stacked.clone().decompose_tuple().is_err());
        assert!(stacked.to_vec::<f32>().is_err());
    }

    #[test]
    fn runtime_entry_points_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("shim"), "{err}");
    }
}
