//! Network descriptions: a small layer-graph IR plus the three models the
//! paper evaluates — VGG-16 (series), ResNet-18 (parallel/residual) and the
//! diffusion U-net (parallel + time-parameter dense).

pub mod graph;
pub mod resnet;
pub mod unet;
pub mod vgg;

pub use graph::{Act, Layer, ModelGraph, Node, Residual, TensorShape};
pub use resnet::resnet18;
pub use unet::{unet, UnetConfig};
pub use vgg::vgg16;
