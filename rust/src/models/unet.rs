//! The de-noising diffusion U-net (paper Figs 13-16).
//!
//! Each U-net block follows the paper's 4-group decomposition (Fig 14):
//! * Block 1 — time-parameter dense layer  ──┐ run concurrently: PE_9
//! * Block 2 — conv + activation            ──┘ serves the dense while
//!   PE_1..PE_8 convolve (`time_dense: Some(_)`).
//! * Block 3 — conv without activation.
//! * Block 4 — "final logic": the skip around the block, fused into
//!   Block 3's conv via `Residual::{Identity,Conv}` — SF mode again.
//!
//! Encoder levels downsample with max-pool; decoder levels upsample and
//! concat the encoder skip (the long U-net skips), then run a block.

use super::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};

/// Configuration of the small diffusion U-net.
#[derive(Debug, Clone, Copy)]
pub struct UnetConfig {
    /// Input/output channels of the image (1 for grayscale).
    pub img_channels: usize,
    /// Input resolution (square).
    pub img: usize,
    /// Base channel width; doubles per level.
    pub base_c: usize,
    /// Number of down/up levels (>= 1).
    pub levels: usize,
    /// Time-embedding width fed to each block's dense layer.
    pub time_dim: usize,
}

impl Default for UnetConfig {
    fn default() -> Self {
        Self {
            img_channels: 1,
            img: 16,
            base_c: 16,
            levels: 2,
            time_dim: 32,
        }
    }
}

/// One paper-style U-net block: conv(+time dense on PE_9) then conv with
/// the block skip fused. Returns the index of the block's output node.
fn unet_block(b: &mut GraphBuilder, c_in: usize, c_out: usize, time_dim: usize) -> usize {
    let block_input = b.next_index().checked_sub(1);
    b.add(Layer::Conv {
        c_in,
        c_out,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Silu,
        residual: Residual::None,
        time_dense: Some(time_dim),
    })
    .expect("unet block conv1");
    let residual = match block_input {
        Some(from) if c_in == c_out => Residual::Identity { from },
        Some(from) => Residual::Conv { from, stride: 1 },
        None => Residual::None, // block opens the graph: no skip source
    };
    b.add(Layer::Conv {
        c_in: c_out,
        c_out,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual,
        time_dense: None,
    })
    .expect("unet block conv2")
}

/// Build the U-net graph.
pub fn unet(cfg: UnetConfig) -> ModelGraph {
    assert!(cfg.levels >= 1, "need at least one level");
    assert!(
        cfg.img % (1 << cfg.levels) == 0,
        "img {} not divisible by 2^levels",
        cfg.img
    );
    let mut b = GraphBuilder::new(
        "unet",
        TensorShape::new(cfg.img_channels, cfg.img, cfg.img),
    );

    // Stem: lift image to base_c channels.
    b.add(Layer::Conv {
        c_in: cfg.img_channels,
        c_out: cfg.base_c,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Silu,
        residual: Residual::None,
        time_dense: None,
    })
    .expect("stem");

    // Encoder.
    let mut skips = Vec::new();
    let mut c = cfg.base_c;
    for lvl in 0..cfg.levels {
        let c_out = cfg.base_c << lvl;
        let out = unet_block(&mut b, c, c_out, cfg.time_dim);
        skips.push(out);
        b.add(Layer::MaxPool { k: 2, stride: 2 }).expect("down");
        c = c_out;
    }

    // Bottleneck.
    let c_mid = cfg.base_c << cfg.levels;
    unet_block(&mut b, c, c_mid, cfg.time_dim);
    c = c_mid;

    // Decoder.
    for lvl in (0..cfg.levels).rev() {
        b.add(Layer::Upsample2x).expect("up");
        let skip = skips[lvl];
        b.add(Layer::ConcatSkip { from: skip }).expect("concat");
        let c_skip = cfg.base_c << lvl;
        let c_out = cfg.base_c << lvl;
        unet_block(&mut b, c + c_skip, c_out, cfg.time_dim);
        c = c_out;
    }

    // Head: project back to image channels (predicts the noise).
    b.add(Layer::Conv {
        c_in: c,
        c_out: cfg.img_channels,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::None,
        time_dense: None,
    })
    .expect("head");

    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph::Layer as L;

    #[test]
    fn default_unet_shapes() {
        let g = unet(UnetConfig::default());
        let last = g.nodes.last().unwrap();
        assert_eq!(last.out_shape, TensorShape::new(1, 16, 16));
    }

    #[test]
    fn every_block_has_time_dense_and_skip() {
        let g = unet(UnetConfig::default());
        let time_convs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.layer, L::Conv { time_dense: Some(_), .. }))
            .count();
        // levels=2: 2 encoder + 1 bottleneck + 2 decoder = 5 blocks
        assert_eq!(time_convs, 5);
        assert_eq!(g.parallel_nodes(), 10, "conv1 (time) + conv2 (skip) per block");
    }

    #[test]
    fn concat_adds_skip_channels() {
        let g = unet(UnetConfig::default());
        let mut seen = 0;
        for n in &g.nodes {
            if let L::ConcatSkip { from } = n.layer {
                assert_eq!(
                    n.out_shape.c,
                    n.in_shape.c + g.nodes[from].out_shape.c
                );
                seen += 1;
            }
        }
        assert_eq!(seen, 2, "one concat per decoder level");
    }

    #[test]
    fn deeper_unet_builds() {
        let g = unet(UnetConfig {
            img: 32,
            levels: 3,
            base_c: 8,
            ..Default::default()
        });
        assert!(g.total_macs() > 0);
        assert_eq!(g.nodes.last().unwrap().out_shape.h, 32);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_odd_resolution() {
        let _ = unet(UnetConfig {
            img: 18,
            levels: 2,
            ..Default::default()
        });
    }
}
