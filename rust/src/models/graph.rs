//! Layer-graph IR.
//!
//! A [`ModelGraph`] is a topologically-ordered list of [`Node`]s. Skip
//! connections are expressed *on the consuming conv* (`Residual::Identity`
//! / `Residual::Conv`), mirroring how SF-MMCN fuses the skip into the main
//! convolution via PE_9 — the graph says "this conv also absorbs the skip
//! from node `from`", exactly what the hardware executes in one pass.

use anyhow::{bail, Result};

/// CHW feature-map shape (batch is always 1 — §III.D: "the batch size of
/// the proposed SF-MMCN is 1 because of the high-speed ... requirement").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl TensorShape {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    pub fn elems(&self) -> u64 {
        (self.c * self.h * self.w) as u64
    }
}

/// Activation applied at the layer output (in the dedicated activation
/// unit of Fig 18 — free in cycles, priced as buffer traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    /// SiLU-ish smooth activation used by the U-net blocks; numerics only.
    Silu,
}

/// Skip-branch handling for a conv layer (the SF modes of Fig 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residual {
    /// No parallel branch — series mode, PE_9 idle.
    None,
    /// Identity skip from the output of node `from` (Fig 6b).
    Identity { from: usize },
    /// 1x1 conv skip from node `from` with `stride` (Fig 6c) — PE_9
    /// computes it. Channel count is implied by this conv's c_out.
    Conv { from: usize, stride: usize },
}

/// One layer.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv {
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        act: Act,
        residual: Residual,
        /// U-net: this conv's unit-group also runs the time-parameter dense
        /// on PE_9 (Fig 14 block 1 overlapped with block 2).
        time_dense: Option<usize>, // embedding width
    },
    MaxPool {
        k: usize,
        stride: usize,
    },
    /// Global average pool to 1x1 (ResNet head).
    GlobalAvgPool,
    Dense {
        in_f: usize,
        out_f: usize,
        act: Act,
    },
    /// Nearest-neighbour 2x upsample (U-net decoder).
    Upsample2x,
    /// Channel concat with the output of node `from` (U-net skip).
    ConcatSkip { from: usize },
}

/// A node: a layer plus its resolved input/output shapes.
#[derive(Debug, Clone)]
pub struct Node {
    pub layer: Layer,
    pub in_shape: TensorShape,
    pub out_shape: TensorShape,
}

impl Node {
    /// MAC count of this node (model work, not hardware slots).
    pub fn macs(&self) -> u64 {
        match &self.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                residual,
                time_dense,
                ..
            } => {
                let main = self.out_shape.h as u64
                    * self.out_shape.w as u64
                    * *c_out as u64
                    * (*k * *k * *c_in) as u64;
                let skip = match residual {
                    Residual::Conv { .. } => {
                        self.out_shape.h as u64
                            * self.out_shape.w as u64
                            * *c_out as u64
                            * *c_in as u64
                    }
                    _ => 0,
                };
                let td = time_dense
                    .map(|e| (e * self.out_shape.c) as u64)
                    .unwrap_or(0);
                main + skip + td
            }
            Layer::Dense { in_f, out_f, .. } => (*in_f * *out_f) as u64,
            _ => 0,
        }
    }

    /// True if this node has a parallel branch (drives SF mode selection).
    pub fn is_parallel(&self) -> bool {
        matches!(
            &self.layer,
            Layer::Conv {
                residual: Residual::Identity { .. } | Residual::Conv { .. },
                ..
            } | Layer::Conv {
                time_dense: Some(_),
                ..
            }
        )
    }
}

/// A whole network.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    pub input: TensorShape,
    pub nodes: Vec<Node>,
}

/// Builder that performs shape inference as layers are appended.
pub struct GraphBuilder {
    name: String,
    input: TensorShape,
    nodes: Vec<Node>,
}

impl GraphBuilder {
    pub fn new(name: &str, input: TensorShape) -> Self {
        Self {
            name: name.to_string(),
            input,
            nodes: Vec::new(),
        }
    }

    fn cur_shape(&self) -> TensorShape {
        self.nodes
            .last()
            .map(|n| n.out_shape)
            .unwrap_or(self.input)
    }

    /// Index the next node will get (for residual `from` references).
    pub fn next_index(&self) -> usize {
        self.nodes.len()
    }

    /// Output shape of an already-added node.
    pub fn shape_of(&self, idx: usize) -> TensorShape {
        self.nodes[idx].out_shape
    }

    pub fn add(&mut self, layer: Layer) -> Result<usize> {
        let in_shape = self.cur_shape();
        let out_shape = self.infer(&layer, in_shape)?;
        self.nodes.push(Node {
            layer,
            in_shape,
            out_shape,
        });
        Ok(self.nodes.len() - 1)
    }

    fn infer(&self, layer: &Layer, s: TensorShape) -> Result<TensorShape> {
        Ok(match layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                pad,
                residual,
                ..
            } => {
                if *c_in != s.c {
                    bail!("conv expects {c_in} channels, input has {}", s.c);
                }
                if *k == 0 || *stride == 0 {
                    bail!("conv k/stride must be positive");
                }
                if s.h + 2 * pad < *k || s.w + 2 * pad < *k {
                    bail!("conv kernel {k} larger than padded input {}x{}", s.h, s.w);
                }
                let h = (s.h + 2 * pad - k) / stride + 1;
                let w = (s.w + 2 * pad - k) / stride + 1;
                let out = TensorShape::new(*c_out, h, w);
                match residual {
                    Residual::None => {}
                    Residual::Identity { from } => {
                        let fs = self.check_from(*from)?;
                        if fs != out {
                            bail!(
                                "identity skip from node {from} shape {fs:?} \
                                 != conv output {out:?}"
                            );
                        }
                    }
                    Residual::Conv { from, stride } => {
                        let fs = self.check_from(*from)?;
                        let rh = (fs.h - 1) / stride + 1;
                        let rw = (fs.w - 1) / stride + 1;
                        if (rh, rw) != (out.h, out.w) {
                            bail!(
                                "residual conv from node {from}: {rh}x{rw} \
                                 != conv output {}x{}",
                                out.h,
                                out.w
                            );
                        }
                    }
                }
                out
            }
            Layer::MaxPool { k, stride } => {
                if s.h < *k || s.w < *k {
                    bail!("pool kernel {k} larger than input {}x{}", s.h, s.w);
                }
                TensorShape::new(s.c, (s.h - k) / stride + 1, (s.w - k) / stride + 1)
            }
            Layer::GlobalAvgPool => TensorShape::new(s.c, 1, 1),
            Layer::Dense { in_f, out_f, .. } => {
                if *in_f != (s.c * s.h * s.w) {
                    bail!(
                        "dense expects {in_f} inputs, tensor has {}",
                        s.c * s.h * s.w
                    );
                }
                TensorShape::new(*out_f, 1, 1)
            }
            Layer::Upsample2x => TensorShape::new(s.c, s.h * 2, s.w * 2),
            Layer::ConcatSkip { from } => {
                let fs = self.check_from(*from)?;
                if (fs.h, fs.w) != (s.h, s.w) {
                    bail!(
                        "concat skip from node {from}: {}x{} != {}x{}",
                        fs.h,
                        fs.w,
                        s.h,
                        s.w
                    );
                }
                TensorShape::new(s.c + fs.c, s.h, s.w)
            }
        })
    }

    fn check_from(&self, from: usize) -> Result<TensorShape> {
        if from >= self.nodes.len() {
            bail!(
                "skip references node {from}, but only {} nodes exist",
                self.nodes.len()
            );
        }
        Ok(self.nodes[from].out_shape)
    }

    pub fn build(self) -> ModelGraph {
        ModelGraph {
            name: self.name,
            input: self.input,
            nodes: self.nodes,
        }
    }
}

impl ModelGraph {
    /// Total model MACs.
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.macs()).sum()
    }

    /// Total model ops (2 per MAC) — the paper's "OPs ~ FLOPs".
    pub fn total_ops(&self) -> u64 {
        2 * self.total_macs()
    }

    /// Indices of conv nodes (the layers the accelerator computes).
    pub fn conv_indices(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.layer, Layer::Conv { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of nodes with a parallel branch.
    pub fn parallel_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_parallel()).count()
    }

    /// Weight-parameter count.
    pub fn total_weights(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match &n.layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    residual,
                    time_dense,
                    ..
                } => {
                    let main = (c_out * c_in * k * k + c_out) as u64;
                    let skip = match residual {
                        Residual::Conv { .. } => (c_out * c_in) as u64,
                        _ => 0,
                    };
                    let td = time_dense.map(|e| (e * n.out_shape.c) as u64).unwrap_or(0);
                    main + skip + td
                }
                Layer::Dense { in_f, out_f, .. } => (in_f * out_f + out_f) as u64,
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize) -> Layer {
        Layer::Conv {
            c_in,
            c_out,
            k,
            stride,
            pad,
            act: Act::Relu,
            residual: Residual::None,
            time_dense: None,
        }
    }

    #[test]
    fn shape_inference_conv_pool_dense() {
        let mut b = GraphBuilder::new("t", TensorShape::new(3, 32, 32));
        b.add(conv(3, 16, 3, 1, 1)).unwrap();
        assert_eq!(b.cur_shape(), TensorShape::new(16, 32, 32));
        b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
        assert_eq!(b.cur_shape(), TensorShape::new(16, 16, 16));
        b.add(Layer::Dense {
            in_f: 16 * 16 * 16,
            out_f: 10,
            act: Act::None,
        })
        .unwrap();
        let g = b.build();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[2].out_shape, TensorShape::new(10, 1, 1));
    }

    #[test]
    fn channel_mismatch_rejected() {
        let mut b = GraphBuilder::new("t", TensorShape::new(3, 8, 8));
        assert!(b.add(conv(4, 8, 3, 1, 1)).is_err());
    }

    #[test]
    fn identity_skip_shape_checked() {
        let mut b = GraphBuilder::new("t", TensorShape::new(8, 16, 16));
        let first = b.add(conv(8, 8, 3, 1, 1)).unwrap();
        // matching skip ok
        b.add(Layer::Conv {
            c_in: 8,
            c_out: 8,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::Relu,
            residual: Residual::Identity { from: first },
            time_dense: None,
        })
        .unwrap();
        // mismatched skip rejected (stride changes spatial size)
        let r = b.add(Layer::Conv {
            c_in: 8,
            c_out: 8,
            k: 3,
            stride: 2,
            pad: 1,
            act: Act::Relu,
            residual: Residual::Identity { from: first },
            time_dense: None,
        });
        assert!(r.is_err());
    }

    #[test]
    fn forward_reference_rejected() {
        let mut b = GraphBuilder::new("t", TensorShape::new(8, 16, 16));
        let r = b.add(Layer::Conv {
            c_in: 8,
            c_out: 8,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::Relu,
            residual: Residual::Identity { from: 5 },
            time_dense: None,
        });
        assert!(r.is_err());
    }

    #[test]
    fn mac_counting_conv() {
        let mut b = GraphBuilder::new("t", TensorShape::new(2, 4, 4));
        b.add(conv(2, 3, 3, 1, 1)).unwrap();
        let g = b.build();
        // 4*4 spatial * 3 out-ch * (3*3*2) taps = 864
        assert_eq!(g.total_macs(), 864);
        assert_eq!(g.total_ops(), 1728);
    }

    #[test]
    fn concat_and_upsample_shapes() {
        let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
        let skip = b.add(conv(4, 4, 3, 1, 1)).unwrap();
        b.add(Layer::MaxPool { k: 2, stride: 2 }).unwrap();
        b.add(Layer::Upsample2x).unwrap();
        b.add(Layer::ConcatSkip { from: skip }).unwrap();
        assert_eq!(b.cur_shape(), TensorShape::new(8, 8, 8));
    }

    #[test]
    fn time_dense_counts_macs() {
        let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
        b.add(Layer::Conv {
            c_in: 4,
            c_out: 4,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::Relu,
            residual: Residual::None,
            time_dense: Some(16),
        })
        .unwrap();
        let g = b.build();
        let conv_macs = 8 * 8 * 4 * (3 * 3 * 4);
        assert_eq!(g.total_macs(), conv_macs as u64 + 16 * 4);
    }
}
