//! ResNet-18 (He et al.) — the paper's *parallel/residual* evaluation
//! model. Each basic block's second conv absorbs the skip branch through
//! PE_9 (`Residual::Identity`); downsample blocks use the 1x1 residual
//! conv mode (`Residual::Conv`), matching Fig 6 (b)/(c).

use super::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};

fn conv(c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize) -> Layer {
    Layer::Conv {
        c_in,
        c_out,
        k,
        stride,
        pad,
        act: Act::Relu,
        residual: Residual::None,
        time_dense: None,
    }
}

/// One basic block: conv3x3(stride) -> conv3x3 with the skip fused in.
/// `downsample` selects the 1x1-conv skip (stride-2 stage entry).
fn basic_block(b: &mut GraphBuilder, c_in: usize, c_out: usize, stride: usize) {
    // The node whose output feeds the skip branch is the one *before* the
    // block's first conv.
    let skip_from = b.next_index().checked_sub(1);
    let c1 = b.add(conv(c_in, c_out, 3, stride, 1)).expect("block conv1");
    let residual = match (skip_from, stride == 1 && c_in == c_out) {
        (Some(from), true) => Residual::Identity { from },
        (Some(from), false) => Residual::Conv { from, stride },
        // First block right after the stem pool: skip comes from the pool
        // node; `skip_from` is None only if the block opened the graph,
        // which resnet18 below never does.
        (None, _) => unreachable!("basic block at graph start"),
    };
    let _ = c1;
    b.add(Layer::Conv {
        c_in: c_out,
        c_out,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Relu,
        residual,
        time_dense: None,
    })
    .expect("block conv2");
}

/// ResNet-18 for `img` x `img` RGB inputs (canonical: 224) and `classes`.
pub fn resnet18(img: usize, classes: usize) -> ModelGraph {
    assert!(img % 32 == 0, "resnet18 needs input divisible by 32");
    let mut b = GraphBuilder::new("resnet18", TensorShape::new(3, img, img));
    // Stem: 7x7/2 conv + 3x3/2 max pool.
    b.add(conv(3, 64, 7, 2, 3)).expect("stem conv");
    b.add(Layer::MaxPool { k: 3, stride: 2 }).expect("stem pool");
    // Hmm: 3x3/2 pool on even sizes needs pad-1 in the reference model; our
    // pool has no padding, so sizes differ by the border pixel. We follow
    // the paddingless definition consistently (shape checks below pin it).
    let stages: &[(usize, usize)] = &[(64, 1), (128, 2), (256, 2), (512, 2)];
    let mut c_in = 64;
    for &(c_out, first_stride) in stages {
        basic_block(&mut b, c_in, c_out, first_stride);
        basic_block(&mut b, c_out, c_out, 1);
        c_in = c_out;
    }
    b.add(Layer::GlobalAvgPool).expect("gap");
    b.add(Layer::Dense {
        in_f: 512,
        out_f: classes,
        act: Act::None,
    })
    .expect("fc");
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_structure() {
        let g = resnet18(224, 1000);
        // stem conv + pool + 8 blocks x 2 convs + gap + fc = 20 nodes
        assert_eq!(g.nodes.len(), 20);
        assert_eq!(g.conv_indices().len(), 17);
        // 8 blocks: every second conv carries the skip
        assert_eq!(g.parallel_nodes(), 8);
    }

    #[test]
    fn residual_kinds() {
        let g = resnet18(224, 1000);
        let mut identity = 0;
        let mut rconv = 0;
        for n in &g.nodes {
            if let Layer::Conv { residual, .. } = &n.layer {
                match residual {
                    Residual::Identity { .. } => identity += 1,
                    Residual::Conv { .. } => rconv += 1,
                    Residual::None => {}
                }
            }
        }
        // stage-entry blocks of 128/256/512 downsample; the other 5 blocks
        // (both 64-blocks and the three second-blocks) are identity
        assert_eq!(identity, 5);
        assert_eq!(rconv, 3);
    }

    #[test]
    fn resnet18_macs_ballpark() {
        let g = resnet18(224, 1000);
        // ResNet-18 @224 is ~1.8 GFLOPs; paddingless stem pool shaves the
        // border, so accept a band.
        let gflops = g.total_ops() as f64 / 1e9;
        assert!((3.2..4.0).contains(&gflops), "ResNet-18 GFLOPs = {gflops}");
        // NB: torchvision counts 1.8 GFLOPs with MAC=1FLOP; ours counts 2.
    }

    #[test]
    fn final_shape_is_classes() {
        let g = resnet18(224, 10);
        assert_eq!(g.nodes.last().unwrap().out_shape.c, 10);
    }
}
