//! VGG-16 (Simonyan & Zisserman) — the paper's *series* evaluation model.
//! All 3x3/s1/p1 convs + 2x2 max-pools + 3 dense layers; no parallel
//! structure, so PE_9 only performs data-reuse service (Fig 21a).

use super::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};

fn conv3(c_in: usize, c_out: usize) -> Layer {
    Layer::Conv {
        c_in,
        c_out,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Relu,
        residual: Residual::None,
        time_dense: None,
    }
}

/// VGG-16 for `img` x `img` RGB inputs with `classes` outputs.
/// The canonical configuration is `vgg16(224, 1000)`.
pub fn vgg16(img: usize, classes: usize) -> ModelGraph {
    assert!(img % 32 == 0, "vgg16 needs input divisible by 32, got {img}");
    let mut b = GraphBuilder::new("vgg16", TensorShape::new(3, img, img));
    let blocks: &[(usize, usize)] = &[(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut c_in = 3;
    for &(c_out, reps) in blocks {
        for _ in 0..reps {
            b.add(conv3(c_in, c_out)).expect("vgg conv");
            c_in = c_out;
        }
        b.add(Layer::MaxPool { k: 2, stride: 2 }).expect("vgg pool");
    }
    let spatial = img / 32;
    b.add(Layer::Dense {
        in_f: 512 * spatial * spatial,
        out_f: 4096,
        act: Act::Relu,
    })
    .expect("fc1");
    b.add(Layer::Dense {
        in_f: 4096,
        out_f: 4096,
        act: Act::Relu,
    })
    .expect("fc2");
    b.add(Layer::Dense {
        in_f: 4096,
        out_f: classes,
        act: Act::None,
    })
    .expect("fc3");
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_224_structure() {
        let g = vgg16(224, 1000);
        // 13 convs + 5 pools + 3 dense = 21 nodes
        assert_eq!(g.nodes.len(), 21);
        assert_eq!(g.conv_indices().len(), 13);
        assert_eq!(g.parallel_nodes(), 0, "VGG is a pure series model");
    }

    #[test]
    fn vgg16_224_macs_match_literature() {
        let g = vgg16(224, 1000);
        // VGG-16 @224 is ~15.5 G MACs (30.9 GFLOPs at 2 ops/MAC)
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((15.2..15.8).contains(&gmacs), "VGG-16 GMACs = {gmacs}");
    }

    #[test]
    fn vgg16_weights_match_literature() {
        let g = vgg16(224, 1000);
        // ~138 M parameters
        let m = g.total_weights() as f64 / 1e6;
        assert!((135.0..142.0).contains(&m), "VGG-16 params = {m} M");
    }

    #[test]
    fn small_input_variant() {
        let g = vgg16(32, 10);
        assert_eq!(g.nodes.len(), 21);
        assert_eq!(g.nodes.last().unwrap().out_shape.c, 10);
    }

    #[test]
    #[should_panic(expected = "divisible by 32")]
    fn rejects_bad_input_size() {
        let _ = vgg16(100, 10);
    }
}
