//! Fixed-point arithmetic matching the chip's 16-bit datapath.
//!
//! The paper sets "the bit-width of weight, input images data, and bias
//! data ... to 16 bits fixed point" (§IV). We use Q8.8 (1 sign + 7 integer
//! + 8 fraction bits) with a 32-bit accumulator and saturating writeback —
//! the standard arrangement for a 16x16 MAC datapath.

mod fixed;

pub use fixed::{dequantize, quantize, Fixed, FRAC_BITS, ONE};
