//! Q8.8 fixed point: the numeric format of the simulated datapath.

/// Number of fractional bits in the Q8.8 format.
pub const FRAC_BITS: u32 = 8;
/// Fixed-point representation of 1.0.
pub const ONE: i16 = 1 << FRAC_BITS;

/// A Q8.8 fixed-point value stored in an `i16`, as held in the chip's
/// input/weight registers.
///
/// `repr(transparent)` guarantees a `&[Fixed]` has exactly the layout of
/// a `&[i16]`, which is what lets the explicit-SIMD dot product
/// (`util::simd`, `--features simd`) load lanes straight from the
/// simulator's window slabs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
#[repr(transparent)]
pub struct Fixed(pub i16);

impl Fixed {
    pub const ZERO: Fixed = Fixed(0);
    pub const MAX: Fixed = Fixed(i16::MAX);
    pub const MIN: Fixed = Fixed(i16::MIN);

    /// Quantize an `f32` with round-to-nearest and saturation.
    pub fn from_f32(x: f32) -> Self {
        let scaled = (x * ONE as f32).round();
        Fixed(scaled.clamp(i16::MIN as f32, i16::MAX as f32) as i16)
    }

    /// Dequantize back to `f32`.
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / ONE as f32
    }

    /// True iff the stored pattern is exactly zero — the condition the
    /// zero-gate unit detects to clock-gate the multiplier.
    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// 16x16 -> 32-bit product, as produced by the PE multiplier.
    /// The product of two Q8.8 values is Q16.16 in an i32.
    #[inline]
    pub fn mul_wide(self, rhs: Fixed) -> i32 {
        self.0 as i32 * rhs.0 as i32
    }

    /// Saturating writeback of a Q16.16 accumulator to Q8.8.
    pub fn from_acc(acc: i64) -> Fixed {
        // acc is Q16.16 (possibly grown by accumulation); shift with
        // round-to-nearest, then saturate into i16.
        let rounded = (acc + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        Fixed(rounded.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    /// Saturating add in Q8.8 (the residual adder near the PEs).
    pub fn sat_add(self, rhs: Fixed) -> Fixed {
        Fixed(self.0.saturating_add(rhs.0))
    }
}

/// Quantize an f32 slice.
pub fn quantize(xs: &[f32]) -> Vec<Fixed> {
    xs.iter().map(|&x| Fixed::from_f32(x)).collect()
}

/// Dequantize a Fixed slice.
pub fn dequantize(xs: &[Fixed]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, -0.25, 3.125, -7.875] {
            let q = Fixed::from_f32(x);
            assert!(
                (q.to_f32() - x).abs() <= 1.0 / ONE as f32 / 2.0 + 1e-6,
                "{x} -> {}",
                q.to_f32()
            );
        }
    }

    #[test]
    fn saturates_out_of_range() {
        assert_eq!(Fixed::from_f32(1000.0), Fixed::MAX);
        assert_eq!(Fixed::from_f32(-1000.0), Fixed::MIN);
    }

    #[test]
    fn zero_detection() {
        assert!(Fixed::from_f32(0.0).is_zero());
        assert!(!Fixed::from_f32(0.01).is_zero());
        // values below half an LSB quantize to zero -> gated
        assert!(Fixed::from_f32(0.001).is_zero());
    }

    #[test]
    fn mac_matches_float_within_lsb() {
        let a = Fixed::from_f32(1.5);
        let b = Fixed::from_f32(-2.25);
        let acc = a.mul_wide(b) as i64; // Q16.16
        let back = Fixed::from_acc(acc).to_f32();
        assert!((back - (1.5 * -2.25)).abs() < 2.0 / ONE as f32, "{back}");
    }

    #[test]
    fn accumulate_nine_products() {
        // a 3x3 window of 0.5 * 0.5 = nine products of 0.25 -> 2.25
        let x = Fixed::from_f32(0.5);
        let w = Fixed::from_f32(0.5);
        let mut acc: i64 = 0;
        for _ in 0..9 {
            acc += x.mul_wide(w) as i64;
        }
        assert!((Fixed::from_acc(acc).to_f32() - 2.25).abs() < 1e-3);
    }

    #[test]
    fn sat_add_saturates() {
        assert_eq!(Fixed::MAX.sat_add(Fixed::from_f32(1.0)), Fixed::MAX);
        let a = Fixed::from_f32(1.0).sat_add(Fixed::from_f32(2.0));
        assert!((a.to_f32() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn quantize_dequantize_slice() {
        let xs = [0.0f32, 0.5, -0.5, 2.0];
        let back = dequantize(&quantize(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 1e-2);
        }
    }
}
