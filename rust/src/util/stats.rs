//! Small numeric/statistics helpers shared by the bench harness, the
//! coordinator metrics, and the report renderers.

/// Running summary of a stream of samples (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a *sorted* slice using linear interpolation between
/// the two closest ranks (the "exclusive" definition NumPy calls
/// `linear`): the rank is `p/100 * (len-1)` and the result blends the
/// floor/ceil neighbors by the fractional part. When the rank is
/// integral (always the case for p=0 and p=100) the blend weight is
/// exactly 0, so the returned value is the element itself, bit for bit.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Retained samples per [`LatencyHist`]: counts and the mean stay exact
/// beyond this, percentiles come from a uniform reservoir.
const LATENCY_HIST_CAP: usize = 4096;

/// Collects latency samples and reports p50/p95/p99 — used by the
/// coordinator's serving metrics.
///
/// Memory is bounded: the first `LATENCY_HIST_CAP` (4096) samples are kept
/// exactly; beyond that, reservoir sampling (Vitter's algorithm R, with
/// a deterministic xorshift stream) keeps a uniform subset, so a
/// long-running serving session's metrics — and every
/// `metrics_snapshot()` clone of them — stay O(1) no matter how many
/// requests flow through. `count()` and `mean_us()` always cover every
/// recorded sample; `percentile_us()` is exact below the cap and a
/// statistically representative estimate above it.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    samples_us: Vec<f64>,
    /// Total samples ever recorded (not just retained).
    seen: u64,
    /// Exact running sum of every recorded sample.
    sum: f64,
    rng_state: u64,
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deterministic xorshift64 stream for reservoir replacement slots.
    fn next_rand(&mut self) -> u64 {
        let mut x = self.rng_state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng_state = x;
        x
    }

    pub fn record_us(&mut self, us: f64) {
        self.seen += 1;
        self.sum += us;
        if self.samples_us.len() < LATENCY_HIST_CAP {
            self.samples_us.push(us);
        } else {
            // algorithm R: keep the new sample with probability cap/seen,
            // replacing a uniformly chosen retained one
            let j = self.next_rand() % self.seen;
            if (j as usize) < LATENCY_HIST_CAP {
                self.samples_us[j as usize] = us;
            }
        }
    }

    /// Total samples recorded (exact, not the retained subset size).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, p)
    }

    pub fn mean_us(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.sum / self.seen as f64
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self {
            samples_us: Vec::new(),
            seen: 0,
            sum: 0.0,
            // fixed nonzero seed: xorshift has a zero fixed point
            rng_state: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

/// Streaming quantile estimator (the P² algorithm of Jain & Chlamtac,
/// CACM 1985): tracks one quantile of an unbounded stream in O(1) memory
/// — five marker heights, no sample buffer at all (the bounded-reservoir
/// [`LatencyHist`] keeps a capped subset; this keeps nothing). The
/// long-running serving session uses it for live e2e latency
/// percentiles.
///
/// The first five observations are held exactly (and the estimate is the
/// exact percentile over them); from the sixth on, the markers adjust by
/// piecewise-parabolic interpolation toward their ideal positions.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    /// Target quantile in (0, 1).
    p: f64,
    /// Marker heights q0..q4 (q0 = min, q4 = max once initialized).
    q: [f64; 5],
    /// Actual marker positions (1-based observation ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Per-observation increments of the desired positions.
    dn: [f64; 5],
    count: u64,
    /// The first five samples, kept until initialization.
    boot: [f64; 5],
}

impl P2Quantile {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "quantile must be in [0, 1]");
        Self {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            boot: [0.0; 5],
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Piecewise-parabolic (P²) candidate height for marker `i` moved by
    /// `d` (±1).
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    /// Linear fallback when the parabolic candidate leaves (q[i-1], q[i+1]).
    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    pub fn add(&mut self, x: f64) {
        if self.count < 5 {
            self.boot[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                let mut b = self.boot;
                b.sort_by(|a, c| a.partial_cmp(c).unwrap());
                self.q = b;
            }
            return;
        }
        self.count += 1;
        // locate the cell k with q[k] <= x < q[k+1], extending the
        // extremes when x falls outside them
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for (i, q) in self.q.iter().enumerate().take(4) {
                if *q <= x {
                    k = i;
                }
            }
            k
        };
        for n in self.n.iter_mut().skip(k + 1) {
            *n += 1.0;
        }
        for (np, dn) in self.np.iter_mut().zip(self.dn) {
            *np += dn;
        }
        // nudge the three interior markers toward their ideal positions
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let cand = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < cand && cand < self.q[i + 1] {
                    cand
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    /// Current estimate (exact for the first five samples; 0 when empty).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count <= 5 {
            let mut b: Vec<f64> = self.boot[..self.count as usize].to_vec();
            b.sort_by(|a, c| a.partial_cmp(c).unwrap());
            return percentile(&b, self.p * 100.0);
        }
        self.q[2]
    }
}

/// Fixed-memory p50/p95/p99 latency summary over an unbounded stream —
/// three [`P2Quantile`] markers plus running count/mean. This is what the
/// streaming serving session reports live: unlike [`LatencyHist`] it
/// never buffers samples, so `metrics_snapshot()` stays O(1) no matter
/// how long the session runs.
#[derive(Debug, Clone)]
pub struct StreamingPercentiles {
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
    count: u64,
    sum: f64,
}

impl StreamingPercentiles {
    pub fn new() -> Self {
        Self {
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
            count: 0,
            sum: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        self.p50.add(us);
        self.p95.add(us);
        self.p99.add(us);
        self.count += 1;
        self.sum += us;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    pub fn p50_us(&self) -> f64 {
        self.p50.value()
    }

    pub fn p95_us(&self) -> f64 {
        self.p95.value()
    }

    pub fn p99_us(&self) -> f64 {
        self.p99.value()
    }
}

impl Default for StreamingPercentiles {
    fn default() -> Self {
        Self::new()
    }
}

/// Geometric mean — used when aggregating per-layer speedups the way the
/// paper aggregates "x2.67".
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_extremes_are_exact_not_interpolated() {
        // p=0 and p=100 land on integral ranks (frac = 0), so the result
        // must be the boundary element *bit for bit* — values chosen so
        // any stray lerp arithmetic would perturb the low bits.
        let v = [0.1, 0.3, 0.7];
        assert_eq!(percentile(&v, 0.0).to_bits(), 0.1f64.to_bits());
        assert_eq!(percentile(&v, 100.0).to_bits(), 0.7f64.to_bits());
        // singleton: every p returns the one element exactly
        let one = [0.3];
        for p in [0.0, 37.5, 50.0, 99.9, 100.0] {
            assert_eq!(percentile(&one, p).to_bits(), 0.3f64.to_bits());
        }
    }

    #[test]
    fn percentile_two_element_slice() {
        // len 2: rank = p/100. The endpoints hit lo == hi and must stay
        // exact; interior percentiles blend linearly between the two.
        let v = [0.1, 0.3];
        assert_eq!(percentile(&v, 0.0).to_bits(), 0.1f64.to_bits());
        assert_eq!(percentile(&v, 100.0).to_bits(), 0.3f64.to_bits());
        assert!((percentile(&v, 50.0) - 0.2).abs() < 1e-12);
        assert!((percentile(&v, 25.0) - 0.15).abs() < 1e-12);
        assert!((percentile(&v, 75.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_hist_percentiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert!((h.percentile_us(50.0) - 50.5).abs() < 1e-9);
        assert!(h.percentile_us(99.0) > 98.0);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn latency_hist_memory_is_bounded_beyond_cap() {
        // Long-session contract: counts and the mean stay exact while
        // retained storage (and the percentile basis) stays capped.
        let mut h = LatencyHist::new();
        let n = 50_000u64;
        let mut sum = 0.0;
        for i in 0..n {
            // uniform-ish sweep over [0, 1000)
            let x = (i % 1000) as f64;
            sum += x;
            h.record_us(x);
        }
        assert_eq!(h.count(), n as usize, "count covers every sample");
        assert!((h.mean_us() - sum / n as f64).abs() < 1e-9, "mean exact");
        assert!(
            h.samples_us.len() <= super::LATENCY_HIST_CAP,
            "retained reservoir stays bounded ({} samples)",
            h.samples_us.len()
        );
        // the reservoir is a uniform subset: its median must land near
        // the true median (~500) — generous tolerance, deterministic rng
        let p50 = h.percentile_us(50.0);
        assert!(
            (p50 - 500.0).abs() < 60.0,
            "reservoir median drifted: {p50}"
        );
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    /// Exact percentile of an unsorted sample set (test oracle).
    fn exact(samples: &[f64], p: f64) -> f64 {
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, p)
    }

    #[test]
    fn p2_exact_below_six_samples() {
        let mut q = P2Quantile::new(0.5);
        assert_eq!(q.value(), 0.0, "empty estimator reports 0");
        for (i, x) in [5.0, 1.0, 4.0, 2.0, 3.0].iter().enumerate() {
            q.add(*x);
            assert_eq!(q.count(), i as u64 + 1);
        }
        // exactly the sorted-vector median of the five samples
        assert!((q.value() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn p2_tracks_uniform_stream_percentiles() {
        // seeded uniform data on [0, 1000): the P² estimate must land
        // close to the exact sorted-vector percentile
        let mut rng = crate::util::Rng::new(1234);
        let samples: Vec<f64> = (0..20_000).map(|_| rng.f64() * 1000.0).collect();
        // P² is approximate: allow 2.5% of the range (typical error on
        // this size is well under 1%)
        for (p, tol) in [(0.5, 25.0), (0.95, 25.0), (0.99, 25.0)] {
            let mut est = P2Quantile::new(p);
            for &x in &samples {
                est.add(x);
            }
            let truth = exact(&samples, p * 100.0);
            assert!(
                (est.value() - truth).abs() < tol,
                "p{}: estimate {} vs exact {}",
                p * 100.0,
                est.value(),
                truth
            );
        }
    }

    #[test]
    fn p2_tracks_skewed_latency_like_stream() {
        // latency-shaped data: lognormal-ish via exp(normal), scaled —
        // the skewed tail is what p99 estimation exists for
        let mut rng = crate::util::Rng::new(99);
        let samples: Vec<f64> = (0..20_000)
            .map(|_| (rng.normal() as f64 * 0.5).exp() * 100.0)
            .collect();
        for (p, rel_tol) in [(0.5, 0.08), (0.95, 0.12), (0.99, 0.18)] {
            let mut est = P2Quantile::new(p);
            for &x in &samples {
                est.add(x);
            }
            let truth = exact(&samples, p * 100.0);
            let rel = (est.value() - truth).abs() / truth;
            assert!(
                rel < rel_tol,
                "p{}: estimate {} vs exact {} (rel err {rel:.4})",
                p * 100.0,
                est.value(),
                truth
            );
        }
    }

    #[test]
    fn streaming_percentiles_monotone_and_mean() {
        let mut sp = StreamingPercentiles::new();
        assert_eq!(sp.count(), 0);
        assert_eq!(sp.mean_us(), 0.0);
        let mut rng = crate::util::Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..5_000 {
            let x = rng.f64() * 10_000.0;
            sum += x;
            sp.record_us(x);
        }
        assert_eq!(sp.count(), 5_000);
        assert!((sp.mean_us() - sum / 5_000.0).abs() < 1e-6);
        assert!(sp.p50_us() <= sp.p95_us());
        assert!(sp.p95_us() <= sp.p99_us());
        // uniform [0, 10000): p50 ~ 5000, p99 ~ 9900
        assert!((sp.p50_us() - 5000.0).abs() < 300.0, "p50 {}", sp.p50_us());
        assert!(sp.p99_us() > 9500.0, "p99 {}", sp.p99_us());
    }

    #[test]
    fn p2_constant_stream_degenerates_safely() {
        // identical samples collapse all marker heights; the estimator
        // must not divide by zero or drift
        let mut est = P2Quantile::new(0.95);
        for _ in 0..1_000 {
            est.add(42.0);
        }
        assert_eq!(est.value(), 42.0);
    }
}
