//! Small numeric/statistics helpers shared by the bench harness, the
//! coordinator metrics, and the report renderers.

/// Running summary of a stream of samples (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a *sorted* slice using nearest-rank interpolation.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Collects latency samples and reports p50/p95/p99 — used by the
/// coordinator's serving metrics.
#[derive(Debug, Clone, Default)]
pub struct LatencyHist {
    samples_us: Vec<f64>,
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, p)
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }
}

/// Geometric mean — used when aggregating per-layer speedups the way the
/// paper aggregates "x2.67".
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn latency_hist_percentiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert!((h.percentile_us(50.0) - 50.5).abs() < 1e-9);
        assert!(h.percentile_us(99.0) > 98.0);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
