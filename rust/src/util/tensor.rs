//! A minimal dense row-major tensor (`ndarray`-lite) used by the simulator
//! and the functional reference paths.
//!
//! Layout is NCHW-ish but rank-agnostic: `shape = [d0, d1, ...]`, strides
//! derived row-major. Only the operations the project needs are provided:
//! indexing, slicing views are avoided in favour of explicit copies (the
//! hot path lives in the simulator's closed-form counters and in XLA, not
//! here).

use std::fmt;

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(", self.shape)?;
        let n = self.data.len().min(8);
        for (i, v) in self.data[..n].iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > n {
            write!(f, ", …")?;
        }
        write!(f, ")")
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
        }
        Ok(Self {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> f32) -> Self {
        let mut t = Self::zeros(shape);
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..t.data.len() {
            t.unflatten(flat, &mut idx);
            t.data[flat] = f(&idx);
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    fn flatten(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0usize;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} ({dim})");
            flat = flat * dim + ix;
        }
        flat
    }

    fn unflatten(&self, mut flat: usize, idx: &mut [usize]) {
        for i in (0..self.shape.len()).rev() {
            idx[i] = flat % self.shape[i];
            flat /= self.shape[i];
        }
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.flatten(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let f = self.flatten(idx);
        self.data[f] = v;
    }

    /// Reshape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Element-wise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    /// Fraction of exactly-zero elements — drives the zero-gate model.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }

    /// Max |a-b| against another tensor (for numerics checks).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs() as f64)
            .fold(0.0, f64::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_index() {
        let t = Tensor::from_fn(&[2, 3, 4], |idx| (idx[0] * 100 + idx[1] * 10 + idx[2]) as f32);
        assert_eq!(t.get(&[1, 2, 3]), 123.0);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
    }

    #[test]
    fn shape_validation() {
        assert!(Tensor::new(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::new(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn reshape_preserves_order() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.get(&[0, 1]), 1.0);
        assert_eq!(r.get(&[2, 1]), 5.0);
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = Tensor::new(&[4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn relu_and_add() {
        let a = Tensor::new(&[3], vec![-1.0, 0.5, 2.0]).unwrap();
        let b = Tensor::new(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        assert_eq!(a.relu().data(), &[0.0, 0.5, 2.0]);
        assert_eq!(a.add(&b).unwrap().data(), &[0.0, 1.5, 3.0]);
        let c = Tensor::zeros(&[4]);
        assert!(a.add(&c).is_err());
    }
}
