//! Property-based testing mini-harness (proptest-lite).
//!
//! Usage:
//! ```no_run
//! use sf_mmcn::util::proptest_lite::Prop;
//! Prop::new("add commutes", 256).check(|g| {
//!     let a = g.usize_in(0, 100);
//!     let b = g.usize_in(0, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a fresh deterministic [`Gen`]; on panic, the harness
//! re-raises with the case's seed so the failure is reproducible with
//! [`Prop::check_seed`]. No shrinking — cases are kept small instead.

use std::panic::{catch_unwind, AssertUnwindSafe};

use super::rng::Rng;

/// Per-case value generator.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u(lo as u64, hi as u64) as usize
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool_p(0.5)
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// A vec of length in [0, max_len] using the element generator.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }
}

/// A named property with a case budget.
pub struct Prop {
    name: String,
    cases: u64,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &str, cases: u64) -> Self {
        Self {
            name: name.to_string(),
            cases,
            // Fixed base seed: CI-stable. Override per-property if needed.
            base_seed: 0x5F_4D4D_434E, // "SF-MMCN"
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Run all cases; panic (with reproduction seed) on first failure.
    pub fn check(&self, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
            let result = catch_unwind(AssertUnwindSafe(|| {
                let mut g = Gen::new(seed);
                f(&mut g);
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property `{}` failed at case {case} (seed {seed:#x}): {msg}\n\
                     reproduce with Prop::check_seed({seed:#x}, ...)",
                    self.name
                );
            }
        }
    }

    /// Re-run a single failing case by seed.
    pub fn check_seed(seed: u64, f: impl Fn(&mut Gen)) {
        let mut g = Gen::new(seed);
        f(&mut g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("reverse twice is identity", 64).check(|g| {
            let v = g.vec_of(20, |g| g.u64_in(0, 1000));
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            assert_eq!(v, r);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let err = catch_unwind(AssertUnwindSafe(|| {
            Prop::new("always fails", 5).check(|_| panic!("boom"));
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_ranges_inclusive() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let x = g.usize_in(3, 5);
            assert!((3..=5).contains(&x));
        }
    }
}
