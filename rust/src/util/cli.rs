//! Tiny argv parser (clap-lite): subcommands, `--key value` / `--key=value`
//! options, `--flag` booleans, positionals. Enough for the `sf-mmcn` CLI
//! and the bench/example binaries, with helpful errors.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: one optional subcommand, options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, subcommands: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();

        // First non-option token may be a subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && subcommands.contains(&first.as_str()) {
                out.subcommand = Some(it.next().unwrap());
            }
        }

        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends option parsing
                    out.positionals.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process argv.
    pub fn from_env(subcommands: &[&str]) -> Result<Self> {
        Self::parse(std::env::args().skip(1), subcommands)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an integer, got `{s}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .with_context(|| format!("--{name} expects an integer, got `{s}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got `{s}`")),
        }
    }

    /// Error if an unknown option was supplied (catch typos).
    pub fn check_known(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.opts.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known_opts.join(", "));
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known_flags.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_opts() {
        let a = Args::parse(v(&["serve", "--port", "8080", "--verbose"]), &["serve", "run"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(v(&["--model=vgg16", "--steps=10"]), &[]).unwrap();
        assert_eq!(a.get("model"), Some("vgg16"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
    }

    #[test]
    fn positionals_and_double_dash() {
        let a = Args::parse(v(&["run", "file.toml", "--", "--not-an-opt"]), &["run"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positionals, vec!["file.toml", "--not-an-opt"]);
    }

    #[test]
    fn typed_getters_error_on_garbage() {
        let a = Args::parse(v(&["--steps", "ten"]), &[]).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
        assert_eq!(a.get_usize("other", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        let a = Args::parse(v(&["--tpyo", "1"]), &[]).unwrap();
        assert!(a.check_known(&["typo"], &[]).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(v(&["--fast", "--quiet"]), &[]).unwrap();
        assert!(a.flag("fast") && a.flag("quiet"));
    }
}
