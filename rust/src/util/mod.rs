//! From-scratch substrates.
//!
//! The offline build environment vendors only `xla`/`anyhow`/`thiserror`/
//! `num-traits`, so the usual ecosystem crates (rand, clap, serde, rayon,
//! criterion, proptest) are re-implemented here at the scale this project
//! needs. Each submodule is small, tested, and dependency-free.

pub mod affinity;
pub mod bench;
pub mod cli;
pub mod json_lite;
pub mod pool;
pub mod proptest_lite;
pub mod rng;
#[cfg(feature = "simd")]
pub mod simd;
pub mod stats;
pub mod tensor;

pub use rng::Rng;
pub use tensor::Tensor;
