//! Minimal JSON parser (serde_json-lite), in keeping with the crate's
//! from-scratch substrates: enough to read the machine-readable bench
//! result files (`BENCH_*.json`) back in for the CI regression gate.
//!
//! Supports the full JSON value grammar: numbers parse through `f64`,
//! strings support the standard escapes plus `\uXXXX` including UTF-16
//! surrogate pairs (`\uD83D\uDE00` → 😀); unpaired surrogates are a
//! parse error, not a silent replacement char. Errors carry byte
//! offsets.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("bad number `{s}` at byte {start}"),
        }
    }

    /// Four hex digits of a `\u` escape (cursor already past the `u`).
    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape at byte {}", self.pos);
        }
        let raw = &self.bytes[self.pos..self.pos + 4];
        // from_str_radix tolerates a leading `+`; JSON does not
        if !raw.iter().all(|b| b.is_ascii_hexdigit()) {
            let hex = String::from_utf8_lossy(raw);
            bail!("bad \\u escape `{hex}` at byte {}", self.pos);
        }
        let hex = std::str::from_utf8(raw)?;
        let code = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape `{hex}` at byte {}", self.pos))?;
        self.pos += 4;
        Ok(code)
    }

    /// Decode one `\uXXXX` escape (cursor already past the `u`),
    /// consuming a second `\uXXXX` when the first is a UTF-16 high
    /// surrogate. Unpaired or out-of-order surrogates are errors — JSON
    /// strings must encode astral code points as a high/low pair.
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xDC00..=0xDFFF).contains(&hi) {
            bail!("unpaired low surrogate \\u{hi:04X} at byte {}", self.pos);
        }
        if (0xD800..=0xDBFF).contains(&hi) {
            if self.bytes.get(self.pos) != Some(&b'\\')
                || self.bytes.get(self.pos + 1) != Some(&b'u')
            {
                bail!(
                    "high surrogate \\u{hi:04X} not followed by a \\u low surrogate \
                     at byte {}",
                    self.pos
                );
            }
            self.pos += 2;
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                bail!(
                    "high surrogate \\u{hi:04X} paired with non-low-surrogate \
                     \\u{lo:04X} at byte {}",
                    self.pos
                );
            }
            let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return Ok(char::from_u32(code)
                .expect("a surrogate pair always decodes to a valid scalar"));
        }
        Ok(char::from_u32(hi).expect("a non-surrogate BMP code point is a valid char"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        bail!("unterminated escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => bail!("bad escape `\\{}` at byte {}", other as char, self.pos),
                    }
                }
                _ => {
                    // re-scan this (possibly multi-byte) char as utf-8
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!(
                    "expected `,` or `]` at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!(
                    "expected `,` or `}}` at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bench_shaped_document() {
        let text = r#"{
  "bench": "hotpath",
  "mode": "quick",
  "provisional": true,
  "results": [
    {"name": "a", "mean_ns": 123.5, "macs": 72, "speedup_vs_ref": 5.0},
    {"name": "b", "mean_ns": 1e6}
  ]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("bench").and_then(Json::as_str), Some("hotpath"));
        assert_eq!(v.get("provisional").and_then(Json::as_bool), Some(true));
        let rows = v.get("results").and_then(Json::as_array).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").and_then(Json::as_str), Some("a"));
        assert_eq!(rows[0].get("mean_ns").and_then(Json::as_f64), Some(123.5));
        assert_eq!(rows[1].get("mean_ns").and_then(Json::as_f64), Some(1e6));
        assert!(rows[1].get("macs").is_none());
    }

    #[test]
    fn scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e-3").unwrap(), Json::Num(-2.5e-3));
        assert_eq!(
            Json::parse(r#""a\"b\n""#).unwrap(),
            Json::Str("a\"b\n".into())
        );
        let v = Json::parse("[1, [2, {\"k\": false}]]").unwrap();
        let inner = v.as_array().unwrap()[1].as_array().unwrap();
        assert_eq!(inner[1].get("k").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn unicode_escapes_and_surrogate_pairs_round_trip() {
        // BMP escapes, lower/upper-case hex
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse(r#""\u00e9""#).unwrap(), Json::Str("\u{e9}".into()));
        assert_eq!(Json::parse(r#""\u00E9""#).unwrap(), Json::Str("\u{e9}".into()));
        // astral code points arrive as UTF-16 surrogate pairs
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1f600}".into())
        );
        assert_eq!(
            Json::parse(r#""x\uD834\uDD1Ey""#).unwrap(),
            Json::Str("x\u{1d11e}y".into())
        );
        // round trip: the escaped and the raw utf-8 encodings of the
        // same string parse to the same value
        assert_eq!(
            Json::parse(r#""\ud83d\ude00 ok""#).unwrap(),
            Json::parse("\"\u{1f600} ok\"").unwrap()
        );
        // and inside a bench-shaped document field
        let v = Json::parse(r#"{"name": "serve \uD83E\uDD16 bot"}"#).unwrap();
        assert_eq!(
            v.get("name").and_then(Json::as_str),
            Some("serve \u{1f916} bot")
        );
    }

    #[test]
    fn unpaired_surrogates_are_errors() {
        // previously these silently decoded to U+FFFD
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high at end");
        assert!(Json::parse(r#""\ud83dx""#).is_err(), "high + literal");
        assert!(Json::parse(r#""\ud83d\n""#).is_err(), "high + other escape");
        assert!(Json::parse(r#""\ud83dA""#).is_err(), "high + non-low");
        assert!(Json::parse(r#""\ude00""#).is_err(), "lone low");
        assert!(Json::parse(r#""\u12""#).is_err(), "truncated");
        assert!(Json::parse(r#""\uZZZZ""#).is_err(), "non-hex");
        assert!(Json::parse(r#""\u+041""#).is_err(), "sign is not a hex digit");
        assert!(Json::parse(r#""\ud83d\u""#).is_err(), "truncated low");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
