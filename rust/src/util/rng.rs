//! Deterministic PRNG (xoshiro256** — Blackman/Vigna), plus the small set
//! of distributions the workload generators need.
//!
//! Determinism matters here: utilization / cycle numbers in EXPERIMENTS.md
//! must be reproducible run-to-run, so every workload takes an explicit
//! seed and no global RNG state exists.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// True with probability `p`.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with standard-normal values — the allocation-free
    /// variant of [`Rng::normal_vec`]; identical draw order, so the two
    /// produce the same stream from the same state.
    pub fn normal_fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a vec with standard-normal values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.normal_fill(&mut v);
        v
    }

    /// Fill a vec with values that are zero with probability `p_zero` and
    /// otherwise standard-normal — models post-ReLU activation sparsity,
    /// which the zero-gate unit exploits.
    pub fn sparse_vec(&mut self, n: usize, p_zero: f64) -> Vec<f32> {
        (0..n)
            .map(|_| if self.bool_p(p_zero) { 0.0 } else { self.normal() })
            .collect()
    }

    /// Random permutation index shuffle (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sparse_vec_sparsity_close() {
        let mut r = Rng::new(9);
        let v = r.sparse_vec(100_000, 0.6);
        let zeros = v.iter().filter(|&&x| x == 0.0).count();
        let frac = zeros as f64 / v.len() as f64;
        assert!((frac - 0.6).abs() < 0.02, "{frac}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let v = r.normal_vec(100_000);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 =
            v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_fill_matches_normal_vec_stream() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        let v = a.normal_vec(64);
        let mut f = [0.0f32; 64];
        b.normal_fill(&mut f);
        assert_eq!(v, f, "fill and vec variants must draw the same stream");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
