//! Criterion-lite benchmarking harness for the `harness = false` bench
//! targets: warmup, timed iterations, mean/std/percentiles, and a
//! machine-greppable one-line-per-bench output format.

use std::time::{Duration, Instant};

use super::stats::{percentile, Summary};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly; returns timing stats. The closure's return value
    /// is passed through `std::hint::black_box` to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // Measure
        let mut samples = Vec::new();
        let mut sum = Summary::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while (m0.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let ns = t0.elapsed().as_nanos() as f64;
            samples.push(ns);
            sum.add(ns);
            iters += 1;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: sum.mean(),
            std_ns: sum.std(),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
        }
    }

    /// Run and print a one-line summary (the bench binaries' output format).
    pub fn report<T>(&self, name: &str, f: impl FnMut() -> T) -> BenchResult {
        let r = self.run(name, f);
        println!(
            "bench {:<44} {:>10.3} us/iter (p50 {:>10.3}, p95 {:>10.3}, n={})",
            r.name,
            r.mean_us(),
            r.p50_ns / 1e3,
            r.p95_ns / 1e3,
            r.iters
        );
        r
    }
}

/// Format a big ops/second number human-readably.
pub fn fmt_rate(ops_per_s: f64) -> String {
    if ops_per_s >= 1e9 {
        format!("{:.2} Gop/s", ops_per_s / 1e9)
    } else if ops_per_s >= 1e6 {
        format!("{:.2} Mop/s", ops_per_s / 1e6)
    } else if ops_per_s >= 1e3 {
        format!("{:.2} Kop/s", ops_per_s / 1e3)
    } else {
        format!("{ops_per_s:.2} op/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 100_000,
        };
        let r = b.run("noop-ish", || 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(2.5e9), "2.50 Gop/s");
        assert_eq!(fmt_rate(3.0e6), "3.00 Mop/s");
        assert_eq!(fmt_rate(1.5e3), "1.50 Kop/s");
        assert_eq!(fmt_rate(10.0), "10.00 op/s");
    }
}
