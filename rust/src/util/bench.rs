//! Criterion-lite benchmarking harness for the `harness = false` bench
//! targets: warmup, timed iterations, mean/std/percentiles, and a
//! machine-greppable one-line-per-bench output format — plus the
//! baseline comparator behind the CI bench-regression gate
//! ([`BenchBaseline`]/[`compare_baselines`]).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json_lite::Json;
use super::stats::{percentile, Summary};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly; returns timing stats. The closure's return value
    /// is passed through `std::hint::black_box` to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // Measure
        let mut samples = Vec::new();
        let mut sum = Summary::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while (m0.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let ns = t0.elapsed().as_nanos() as f64;
            samples.push(ns);
            sum.add(ns);
            iters += 1;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: sum.mean(),
            std_ns: sum.std(),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
        }
    }

    /// Run and print a one-line summary (the bench binaries' output format).
    pub fn report<T>(&self, name: &str, f: impl FnMut() -> T) -> BenchResult {
        let r = self.run(name, f);
        println!(
            "bench {:<44} {:>10.3} us/iter (p50 {:>10.3}, p95 {:>10.3}, n={})",
            r.name,
            r.mean_us(),
            r.p50_ns / 1e3,
            r.p95_ns / 1e3,
            r.iters
        );
        r
    }
}

/// One row of a `BENCH_*.json` results file, as the comparator sees it.
#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub name: String,
    pub mean_ns: Option<f64>,
    /// Work-per-second column: `mac_rate_per_s` (sim benches) or
    /// `req_per_s` (the serve bench) — either way, bigger is better and
    /// the gate fires on a drop.
    pub mac_rate: Option<f64>,
    /// Machine-independent ratio column: `speedup_vs_ref` (fast vs
    /// reference path) or `speedup_vs_per_request` (batched vs
    /// per-request serving) — measured same-host same-process, so it
    /// always gates, even against provisional baselines.
    pub speedup_vs_ref: Option<f64>,
}

/// A parsed `BENCH_*.json` file (fresh run or committed baseline).
#[derive(Debug, Clone)]
pub struct BenchBaseline {
    /// Provisional baselines carry target-derived, not host-measured,
    /// numbers; only their machine-independent ratio columns gate CI.
    pub provisional: bool,
    pub rows: Vec<BaselineRow>,
}

impl BenchBaseline {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench baseline {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let rows_json = v
            .get("results")
            .and_then(Json::as_array)
            .context("bench file has no `results` array")?;
        let mut rows = Vec::with_capacity(rows_json.len());
        for r in rows_json {
            let name = r
                .get("name")
                .and_then(Json::as_str)
                .context("result row without `name`")?
                .to_string();
            rows.push(BaselineRow {
                name,
                mean_ns: r.get("mean_ns").and_then(Json::as_f64),
                mac_rate: r
                    .get("mac_rate_per_s")
                    .or_else(|| r.get("req_per_s"))
                    .and_then(Json::as_f64),
                speedup_vs_ref: r
                    .get("speedup_vs_ref")
                    .or_else(|| r.get("speedup_vs_per_request"))
                    .and_then(Json::as_f64),
            });
        }
        if rows.is_empty() {
            bail!("bench file has an empty `results` array");
        }
        Ok(Self {
            provisional: v
                .get("provisional")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            rows,
        })
    }
}

/// One detected regression (current worse than baseline by more than the
/// tolerance).
#[derive(Debug, Clone)]
pub struct BenchRegression {
    pub name: String,
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// current / baseline (< 1 − tolerance to be reported).
    pub ratio: f64,
}

impl BenchRegression {
    /// One-line failure report: what regressed, by how much, and the
    /// explicit measured-vs-baseline ratio. Absolute-rate rows only gate
    /// once a non-provisional baseline arms them (ISSUE 9), and on an
    /// armed gate the ratio is the first thing a triager wants — a 0.95x
    /// is host noise to re-baseline away, a 0.3x is a real regression.
    pub fn render(&self) -> String {
        format!(
            "REGRESSION {}: {} {:.3} -> {:.3} \
             (measured/baseline ratio {:.2}x, {:.1}% of baseline)",
            self.name,
            self.metric,
            self.baseline,
            self.current,
            self.ratio,
            self.ratio * 100.0
        )
    }
}

/// Compare a fresh run against a baseline; returns (regressions, notes).
///
/// * `speedup_vs_ref` columns compare directly — the ratio is measured
///   fast-vs-reference *on the same host in the same process*, so it is
///   machine-independent and always gates.
/// * Absolute throughput (`mac_rate_per_s`, else `1/mean_ns`) gates only
///   against non-provisional (host-measured) baselines; a provisional
///   baseline's absolute numbers produce a note instead.
///
/// `tolerance` is fractional (0.15 = fail below 85% of baseline).
pub fn compare_baselines(
    baseline: &BenchBaseline,
    current: &BenchBaseline,
    tolerance: f64,
) -> (Vec<BenchRegression>, Vec<String>) {
    let mut regressions = Vec::new();
    let mut notes = Vec::new();
    for base in &baseline.rows {
        let Some(cur) = current.rows.iter().find(|r| r.name == base.name) else {
            // a vanished row (renamed/dropped bench) must FAIL, not note —
            // otherwise a refactor silently disarms the gate; legitimate
            // renames update the committed baseline in the same PR
            regressions.push(BenchRegression {
                name: base.name.clone(),
                metric: "missing_row",
                baseline: 1.0,
                current: 0.0,
                ratio: 0.0,
            });
            continue;
        };
        if let (Some(b), Some(c)) = (base.speedup_vs_ref, cur.speedup_vs_ref) {
            if b > 0.0 {
                let ratio = c / b;
                if ratio < 1.0 - tolerance {
                    regressions.push(BenchRegression {
                        name: base.name.clone(),
                        metric: "speedup_vs_ref",
                        baseline: b,
                        current: c,
                        ratio,
                    });
                }
            }
        }
        if baseline.provisional {
            continue; // absolute rates from a provisional baseline: skip
        }
        let rate = |r: &BaselineRow| -> Option<(f64, &'static str)> {
            if let Some(m) = r.mac_rate {
                return Some((m, "mac_rate_per_s"));
            }
            r.mean_ns
                .filter(|&ns| ns > 0.0)
                .map(|ns| (1e9 / ns, "iters_per_s"))
        };
        if let (Some((b, metric)), Some((c, cur_metric))) = (rate(base), rate(cur)) {
            if metric != cur_metric {
                // e.g. the baseline recorded mac_rate_per_s but the bench
                // no longer emits it: units apart, never compare
                notes.push(format!(
                    "row `{}`: metric changed ({metric} -> {cur_metric}); not compared",
                    base.name
                ));
            } else if b > 0.0 {
                let ratio = c / b;
                if ratio < 1.0 - tolerance {
                    regressions.push(BenchRegression {
                        name: base.name.clone(),
                        metric,
                        baseline: b,
                        current: c,
                        ratio,
                    });
                }
            }
        }
    }
    if baseline.provisional {
        notes.push(
            "baseline is provisional (target-derived): only speedup_vs_ref ratios gated; \
             commit a measured BENCH json to enable the absolute-rate gate"
                .to_string(),
        );
    }
    (regressions, notes)
}

/// The bench binaries' shared `--check-against` entry point: load the
/// committed baseline, compare `current` against it, print the gate
/// report, and exit(1) on any regression beyond tolerance. Tolerance
/// defaults to 15% (`SF_MMCN_BENCH_TOLERANCE`, in percent); `label`
/// names the bench in the report.
pub fn check_against_baseline(current: &BenchBaseline, baseline_path: &str, label: &str) {
    let tolerance = std::env::var("SF_MMCN_BENCH_TOLERANCE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|pct| pct / 100.0)
        .unwrap_or(0.15);
    let baseline = match BenchBaseline::load(Path::new(baseline_path)) {
        Ok(b) => b,
        Err(e) => {
            println!("\nBENCH GATE ERROR: {e:#}");
            std::process::exit(1);
        }
    };
    let (regressions, notes) = compare_baselines(&baseline, current, tolerance);
    println!(
        "\n==== {label} gate vs {baseline_path} (tolerance {:.0}%) ====",
        tolerance * 100.0
    );
    for n in &notes {
        println!("note: {n}");
    }
    if regressions.is_empty() {
        println!("{label} bench gate OK: no regression beyond tolerance");
        return;
    }
    for r in &regressions {
        println!("{}", r.render());
    }
    std::process::exit(1);
}

/// Format a big ops/second number human-readably.
pub fn fmt_rate(ops_per_s: f64) -> String {
    if ops_per_s >= 1e9 {
        format!("{:.2} Gop/s", ops_per_s / 1e9)
    } else if ops_per_s >= 1e6 {
        format!("{:.2} Mop/s", ops_per_s / 1e6)
    } else if ops_per_s >= 1e3 {
        format!("{:.2} Kop/s", ops_per_s / 1e3)
    } else {
        format!("{ops_per_s:.2} op/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 100_000,
        };
        let r = b.run("noop-ish", || 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    fn fixture(provisional: bool, speedup: f64, rate: f64) -> String {
        format!(
            r#"{{"bench": "hotpath", "mode": "quick", "provisional": {provisional},
  "results": [
    {{"name": "sim_a", "mean_ns": 100.0, "mac_rate_per_s": {rate}, "speedup_vs_ref": {speedup}}},
    {{"name": "analyze_b", "mean_ns": 2000.0}}
  ]}}"#
        )
    }

    #[test]
    fn baseline_roundtrip_and_gate() {
        let base = BenchBaseline::from_json(&fixture(false, 5.0, 1e9)).unwrap();
        assert!(!base.provisional);
        assert_eq!(base.rows.len(), 2);

        // healthy run: slightly faster — no regressions
        let ok = BenchBaseline::from_json(&fixture(false, 5.2, 1.05e9)).unwrap();
        let (regs, _) = compare_baselines(&base, &ok, 0.15);
        assert!(regs.is_empty(), "{regs:?}");

        // collapsed speedup AND rate: both gate
        let bad = BenchBaseline::from_json(&fixture(false, 1.0, 3e8)).unwrap();
        let (regs, _) = compare_baselines(&base, &bad, 0.15);
        assert_eq!(regs.len(), 2, "{regs:?}");
        assert!(regs.iter().any(|r| r.metric == "speedup_vs_ref"));
        assert!(regs.iter().any(|r| r.metric == "mac_rate_per_s"));

        // within tolerance: 10% down passes a 15% gate
        let close = BenchBaseline::from_json(&fixture(false, 4.5, 0.9e9)).unwrap();
        let (regs, _) = compare_baselines(&base, &close, 0.15);
        assert!(regs.is_empty(), "{regs:?}");
    }

    #[test]
    fn provisional_baseline_gates_ratios_only() {
        let base = BenchBaseline::from_json(&fixture(true, 5.0, 1e9)).unwrap();
        assert!(base.provisional);
        // rate collapsed but ratio healthy: provisional baseline must not fail it
        let cur = BenchBaseline::from_json(&fixture(false, 5.0, 1e7)).unwrap();
        let (regs, notes) = compare_baselines(&base, &cur, 0.15);
        assert!(regs.is_empty(), "{regs:?}");
        assert!(notes.iter().any(|n| n.contains("provisional")));
        // ratio collapsed: still caught
        let bad = BenchBaseline::from_json(&fixture(false, 1.2, 1e9)).unwrap();
        let (regs, _) = compare_baselines(&base, &bad, 0.15);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "speedup_vs_ref");
    }

    #[test]
    fn serve_shaped_rows_parse_into_the_same_gate() {
        // The serve bench emits req_per_s / speedup_vs_per_request; both
        // map onto the rate and ratio columns of the comparator.
        let base = BenchBaseline::from_json(
            r#"{"provisional": true, "results": [
                {"name": "per_request", "req_per_s": 50.0},
                {"name": "batched_b4", "req_per_s": 160.0, "speedup_vs_per_request": 2.0}
            ]}"#,
        )
        .unwrap();
        assert_eq!(base.rows[1].mac_rate, Some(160.0));
        assert_eq!(base.rows[1].speedup_vs_ref, Some(2.0));
        // ratio healthy: provisional baseline gates nothing else
        let ok = BenchBaseline::from_json(
            r#"{"results": [
                {"name": "per_request", "req_per_s": 10.0},
                {"name": "batched_b4", "req_per_s": 25.0, "speedup_vs_per_request": 2.5}
            ]}"#,
        )
        .unwrap();
        let (regs, _) = compare_baselines(&base, &ok, 0.15);
        assert!(regs.is_empty(), "{regs:?}");
        // collapsed batching ratio: caught even on a slow host
        let bad = BenchBaseline::from_json(
            r#"{"results": [
                {"name": "per_request", "req_per_s": 10.0},
                {"name": "batched_b4", "req_per_s": 11.0, "speedup_vs_per_request": 1.1}
            ]}"#,
        )
        .unwrap();
        let (regs, _) = compare_baselines(&base, &bad, 0.15);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert_eq!(regs[0].metric, "speedup_vs_ref");
    }

    #[test]
    fn armed_rate_gate_reports_ratio() {
        // The absolute-rate gate only arms on non-provisional baselines
        // (ISSUE 9 commits measured floors with provisional: false); an
        // armed failure must carry the measured-vs-baseline ratio
        // explicitly in its message.
        let base = BenchBaseline::from_json(&fixture(false, 5.0, 1e9)).unwrap();
        let bad = BenchBaseline::from_json(&fixture(false, 5.0, 2.5e8)).unwrap();
        let (regs, _) = compare_baselines(&base, &bad, 0.15);
        assert_eq!(regs.len(), 1, "{regs:?}");
        let r = &regs[0];
        assert_eq!(r.metric, "mac_rate_per_s");
        assert!((r.ratio - 0.25).abs() < 1e-9, "ratio {}", r.ratio);
        let msg = r.render();
        assert!(msg.contains("measured/baseline ratio 0.25x"), "{msg}");
        assert!(msg.contains("25.0% of baseline"), "{msg}");
        // the identical drop against a provisional baseline stays disarmed
        let prov = BenchBaseline::from_json(&fixture(true, 5.0, 1e9)).unwrap();
        let (regs, _) = compare_baselines(&prov, &bad, 0.15);
        assert!(regs.is_empty(), "{regs:?}");
    }

    #[test]
    fn metric_change_is_noted_not_compared() {
        // baseline recorded a MAC rate; the current run only has mean_ns —
        // units apart, must not produce a (spurious) regression
        let base = BenchBaseline::from_json(
            r#"{"results": [{"name": "sim_a", "mean_ns": 100.0, "mac_rate_per_s": 1e9}]}"#,
        )
        .unwrap();
        let cur = BenchBaseline::from_json(
            r#"{"results": [{"name": "sim_a", "mean_ns": 100.0}]}"#,
        )
        .unwrap();
        let (regs, notes) = compare_baselines(&base, &cur, 0.15);
        assert!(regs.is_empty(), "{regs:?}");
        assert!(notes.iter().any(|n| n.contains("metric changed")), "{notes:?}");
    }

    #[test]
    fn missing_rows_fail_the_gate() {
        // dropping/renaming a gated bench must fail, not silently disarm
        let base = BenchBaseline::from_json(&fixture(false, 5.0, 1e9)).unwrap();
        let cur = BenchBaseline::from_json(
            r#"{"results": [{"name": "sim_a", "mean_ns": 100.0, "mac_rate_per_s": 1e9, "speedup_vs_ref": 5.0}]}"#,
        )
        .unwrap();
        let (regs, _) = compare_baselines(&base, &cur, 0.15);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert_eq!(regs[0].metric, "missing_row");
        assert_eq!(regs[0].name, "analyze_b");
        assert!(BenchBaseline::from_json("{\"results\": []}").is_err());
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(2.5e9), "2.50 Gop/s");
        assert_eq!(fmt_rate(3.0e6), "3.00 Mop/s");
        assert_eq!(fmt_rate(1.5e3), "1.50 Kop/s");
        assert_eq!(fmt_rate(10.0), "10.00 op/s");
    }
}
