//! Best-effort NUMA/affinity-aware lane pinning (ISSUE 9).
//!
//! The serving lanes (`coordinator/server.rs`) and their `fanout_threads`
//! children are plain OS threads; on multi-socket hosts the scheduler is
//! free to bounce a lane — and the image slab it keeps resident — across
//! NUMA nodes between timesteps, which costs remote-memory latency
//! exactly on the hot path the resident scan just made contiguous.
//!
//! [`CoreMap`] reads the host's node → CPU topology from
//! `/sys/devices/system/node/node*/cpulist` (falling back to one node
//! spanning every CPU when the sysfs tree is absent), and
//! [`CoreMap::pin_to_node`] pins the *calling thread* to a node's full
//! CPU set via `sched_setaffinity(2)`. Pinning to the whole node — not a
//! single CPU — matters: the lane's fanout children inherit the mask, so
//! they still spread across the node's cores instead of serializing on
//! one.
//!
//! Everything here is best-effort by contract: on non-Linux hosts, in
//! restricted sandboxes (seccomp denying the syscall), or on malformed
//! sysfs, every call degrades to a no-op `false` and serving proceeds
//! unpinned. Affinity never changes served bits — it only moves threads.

/// Maximum CPUs representable in the affinity mask (16 × 64 = 1024).
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
extern "C" {
    /// glibc wrapper for the Linux syscall; the crate already links libc
    /// through std, so no new dependency is involved.
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
}

/// The host's node → CPU-list topology, used to spread serving lanes
/// round-robin across NUMA nodes.
#[derive(Debug, Clone)]
pub struct CoreMap {
    /// CPU ids per node, in node order. Never empty (fallback: one node
    /// holding `0..available_parallelism`).
    nodes: Vec<Vec<usize>>,
}

impl CoreMap {
    /// Detect the host topology. Infallible: absent/odd sysfs degrades to
    /// a single node covering every schedulable CPU.
    pub fn detect() -> Self {
        Self::from_sysfs("/sys/devices/system/node")
    }

    /// Detection against an arbitrary sysfs root (tests point this at a
    /// fixture directory).
    pub fn from_sysfs(root: &str) -> Self {
        let mut nodes = Vec::new();
        // node directories are not guaranteed to list in numeric order
        let mut ids = Vec::new();
        if let Ok(entries) = std::fs::read_dir(root) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if let Some(id) = name
                    .strip_prefix("node")
                    .and_then(|s| s.parse::<usize>().ok())
                {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        for id in ids {
            let path = format!("{root}/node{id}/cpulist");
            if let Ok(list) = std::fs::read_to_string(&path) {
                let cpus = parse_cpulist(&list);
                if !cpus.is_empty() {
                    nodes.push(cpus);
                }
            }
        }
        if nodes.is_empty() {
            let n = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1);
            nodes.push((0..n).collect());
        }
        Self { nodes }
    }

    /// Number of NUMA nodes detected (≥ 1).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The CPU ids of node `node % node_count` (round-robin indexing, so
    /// callers can pass a raw lane index).
    pub fn node_cpus(&self, node: usize) -> &[usize] {
        &self.nodes[node % self.nodes.len()]
    }

    /// Pin the calling thread (and, by mask inheritance, every thread it
    /// spawns afterwards) to the full CPU set of node
    /// `node % node_count`. Returns whether the kernel accepted the mask;
    /// `false` (unsupported OS, denied syscall, out-of-range CPUs) means
    /// the thread simply stays unpinned.
    pub fn pin_to_node(&self, node: usize) -> bool {
        pin_to_cpus(self.node_cpus(node))
    }
}

/// Parse a sysfs `cpulist` string (`"0-15,32-47"`) into CPU ids.
/// Malformed segments are skipped rather than failing the whole list.
fn parse_cpulist(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in list.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi && hi - lo < 4096 {
                    cpus.extend(lo..=hi);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            cpus.push(c);
        }
    }
    cpus
}

/// Pin the calling thread to an explicit CPU set. Best-effort: returns
/// `false` on unsupported hosts or when the kernel rejects the mask.
pub fn pin_to_cpus(cpus: &[usize]) -> bool {
    if cpus.is_empty() || cpus.iter().any(|&c| c >= MASK_WORDS * 64) {
        return false;
    }
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; MASK_WORDS];
        for &c in cpus {
            mask[c / 64] |= 1u64 << (c % 64);
        }
        // SAFETY: mask points at MASK_WORDS u64s and cpusetsize matches;
        // pid 0 means "calling thread" for sched_setaffinity.
        let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
        rc == 0
    }
    #[cfg(not(target_os = "linux"))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_grammar() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4,6-7\n"), vec![0, 1, 4, 6, 7]);
        assert_eq!(parse_cpulist(" 2 "), vec![2]);
        assert!(parse_cpulist("").is_empty());
        assert!(parse_cpulist("garbage").is_empty());
        // malformed segments are dropped, valid ones kept
        assert_eq!(parse_cpulist("x-y,3"), vec![3]);
        // inverted and absurd ranges are rejected
        assert!(parse_cpulist("7-3").is_empty());
        assert!(parse_cpulist("0-99999999").is_empty());
    }

    #[test]
    fn detect_always_yields_a_node() {
        let map = CoreMap::detect();
        assert!(map.node_count() >= 1);
        assert!(!map.node_cpus(0).is_empty());
        // round-robin indexing wraps instead of panicking
        assert_eq!(map.node_cpus(map.node_count()), map.node_cpus(0));
    }

    #[test]
    fn missing_sysfs_falls_back_to_one_full_node() {
        let map = CoreMap::from_sysfs("/nonexistent/sysfs/root");
        assert_eq!(map.node_count(), 1);
        let hw = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        assert_eq!(map.node_cpus(0).len(), hw);
    }

    #[test]
    fn fixture_sysfs_topology_parsed_in_node_order() {
        let dir = std::env::temp_dir().join(format!("sfmmcn-affinity-{}", std::process::id()));
        for (node, list) in [(0usize, "0-1\n"), (1usize, "2,3\n"), (10usize, "4\n")] {
            let d = dir.join(format!("node{node}"));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        // a non-node directory must be ignored
        std::fs::create_dir_all(dir.join("possible")).unwrap();
        let map = CoreMap::from_sysfs(dir.to_str().unwrap());
        assert_eq!(map.node_count(), 3);
        assert_eq!(map.node_cpus(0), &[0, 1]);
        assert_eq!(map.node_cpus(1), &[2, 3]);
        assert_eq!(map.node_cpus(2), &[4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pinning_is_best_effort() {
        // empty and out-of-range sets are refused without touching the OS
        assert!(!pin_to_cpus(&[]));
        assert!(!pin_to_cpus(&[usize::MAX]));
        // pinning to the detected node 0 either succeeds or degrades to a
        // no-op false — both are within contract; it must not panic
        let map = CoreMap::detect();
        let _ = map.pin_to_node(0);
    }
}
