//! Explicit-SIMD hot-path kernels (`--features simd`).
//!
//! Three kernels, each with a runtime-dispatched AVX2 path (stable
//! `std::arch` x86_64 intrinsics) and a portable 8-lane fallback:
//!
//! * [`step_kernel`] — the f32x8 DDPM step update with a polynomial
//!   `tanh` (the scalar kernel's dominant op is libm `tanhf`; the
//!   rational approximation below is the speed win). **Bounded-ULP**:
//!   the polynomial differs from libm `tanh` by a few ULP, so outputs
//!   differ from the default build's within the bound documented and
//!   tested in `tests/kernel_equiv.rs` / EXPERIMENTS.md §Kernels.
//! * [`classify_accumulate`] — the classification sweep's
//!   product-accumulate loop, vectorizing the f32 products while keeping
//!   every f64 accumulation in the scalar kernel's exact order.
//!   **Bit-identical** to the scalar sweep.
//! * [`dot_wide_fixed`] — the simulator's widening Q8.8 MAC loop.
//!   Integer addition is associative, so any lane order is
//!   **bit-exact** with the scalar accumulator.
//!
//! The AVX2 and portable paths of the f32 kernels perform the *same*
//! IEEE operations in the same per-lane order (explicit mul+add, no FMA
//! contraction), so they are bit-identical to each other — "same build,
//! different host" never changes served bits; only the default↔`simd`
//! build boundary carries the ULP bound, and only for the step kernel.

// The tanh coefficients are f64-precision literals rounded to f32 at
// compile time (the standard Eigen/XLA constants); keep them verbatim so
// the approximation is recognizable.
#![allow(clippy::excessive_precision)]

use crate::quant::Fixed;

/// Clamp bound of the rational tanh approximation: beyond ±8 the f32
/// tanh is exactly ±1 anyway, and the polynomial would diverge.
const CLAMP: f32 = 7.99881172180175781;
/// Below this magnitude the approximation returns `x` itself (tanh(x) ≈ x
/// to f32 precision, and p/q loses accuracy in the denormal tail).
const TINY: f32 = 0.0004;
const A1: f32 = 4.89352455891786e-03;
const A3: f32 = 6.37261928875436e-04;
const A5: f32 = 1.48572235717979e-05;
const A7: f32 = 5.12229709037114e-08;
const A9: f32 = -8.60467152213735e-11;
const A11: f32 = 2.00018790482477e-13;
const A13: f32 = -2.76076847742355e-16;
const B0: f32 = 4.89352518554385e-03;
const B2: f32 = 2.26843463243900e-03;
const B4: f32 = 1.18534705686654e-04;
const B6: f32 = 1.19825839466702e-06;

/// Cached AVX2 runtime detection (one CPUID, then an atomic load).
#[cfg(target_arch = "x86_64")]
fn avx2() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

/// Rational polynomial `tanh` (the Eigen/XLA f32 approximation): clamp
/// to ±[`CLAMP`], odd 13th-order numerator over even 6th-order
/// denominator, identity below [`TINY`]. Explicit mul+add (no FMA), so
/// the AVX2 vector version computes bit-identical lanes.
///
/// Accuracy vs libm `tanhf`: within a few ULP everywhere (measured and
/// asserted ≤ 8 ULP by the `kernel_equiv` property suite).
#[inline]
pub fn tanh_poly(x: f32) -> f32 {
    let xc = x.min(CLAMP).max(-CLAMP);
    let x2 = xc * xc;
    let mut p = A13;
    p = p * x2 + A11;
    p = p * x2 + A9;
    p = p * x2 + A7;
    p = p * x2 + A5;
    p = p * x2 + A3;
    p = p * x2 + A1;
    p *= xc;
    let mut q = B6;
    q = q * x2 + B4;
    q = q * x2 + B2;
    q = q * x2 + B0;
    let r = p / q;
    if xc.abs() < TINY {
        xc
    } else {
        r
    }
}

/// One DDPM reverse step over `x` in place, polynomial-tanh SIMD path:
/// `x[i] = c1 * (x[i] - c2 * tanh_poly(g0 * x[i] + bias + pos[i % 31]))
/// + sigma * noise[i]`. `bias = g1 * mean(t_emb)` is computed by the
/// caller exactly as in the scalar kernel.
#[allow(clippy::too_many_arguments)]
pub fn step_kernel(
    x: &mut [f32],
    noise: &[f32],
    pos: &[f32; 31],
    g0: f32,
    bias: f32,
    c1: f32,
    c2: f32,
    sigma: f32,
) {
    debug_assert_eq!(x.len(), noise.len());
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: avx2() verified the CPU supports every intrinsic the
        // target_feature fn uses; slices are plain &[f32]s of equal len.
        unsafe { step_kernel_avx2(x, noise, pos, g0, bias, c1, c2, sigma) };
        return;
    }
    step_kernel_portable(x, noise, pos, g0, bias, c1, c2, sigma);
}

/// Portable lane-wise body of [`step_kernel`]: 8-wide chunks of the
/// exact per-lane IEEE ops the AVX2 path performs (autovectorizable),
/// plus the scalar tail. Public so the equivalence suite can pin
/// portable ≡ AVX2 bit-identity on hosts that have both.
#[allow(clippy::too_many_arguments)]
pub fn step_kernel_portable(
    x: &mut [f32],
    noise: &[f32],
    pos: &[f32; 31],
    g0: f32,
    bias: f32,
    c1: f32,
    c2: f32,
    sigma: f32,
) {
    const W: usize = 8;
    const P: usize = 31;
    let main = x.len() / W * W;
    let (xh, xt) = x.split_at_mut(main);
    let (nh, nt) = noise.split_at(main);
    for (ci, (xc, nc)) in xh.chunks_exact_mut(W).zip(nh.chunks_exact(W)).enumerate() {
        let base = ci * W;
        for j in 0..W {
            let xi = xc[j];
            let eps = tanh_poly(g0 * xi + bias + pos[(base + j) % P]);
            xc[j] = c1 * (xi - c2 * eps) + sigma * nc[j];
        }
    }
    for (j, xi) in xt.iter_mut().enumerate() {
        let v = *xi;
        let eps = tanh_poly(g0 * v + bias + pos[(main + j) % P]);
        *xi = c1 * (v - c2 * eps) + sigma * nt[j];
    }
}

/// AVX2 vector tanh: the same clamp/poly/div/tiny-select sequence as
/// [`tanh_poly`], eight lanes at a time, explicit mul+add (no FMA) so
/// lanes match the portable path bit for bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tanh_avx2(x: std::arch::x86_64::__m256) -> std::arch::x86_64::__m256 {
    use std::arch::x86_64::*;
    let xc = _mm256_max_ps(_mm256_min_ps(x, _mm256_set1_ps(CLAMP)), _mm256_set1_ps(-CLAMP));
    let x2 = _mm256_mul_ps(xc, xc);
    let mut p = _mm256_set1_ps(A13);
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A11));
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A9));
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A7));
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A5));
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A3));
    p = _mm256_add_ps(_mm256_mul_ps(p, x2), _mm256_set1_ps(A1));
    p = _mm256_mul_ps(p, xc);
    let mut q = _mm256_set1_ps(B6);
    q = _mm256_add_ps(_mm256_mul_ps(q, x2), _mm256_set1_ps(B4));
    q = _mm256_add_ps(_mm256_mul_ps(q, x2), _mm256_set1_ps(B2));
    q = _mm256_add_ps(_mm256_mul_ps(q, x2), _mm256_set1_ps(B0));
    let r = _mm256_div_ps(p, q);
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let ax = _mm256_and_ps(xc, abs_mask);
    let tiny = _mm256_cmp_ps::<_CMP_LT_OQ>(ax, _mm256_set1_ps(TINY));
    _mm256_blendv_ps(r, xc, tiny)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn step_kernel_avx2(
    x: &mut [f32],
    noise: &[f32],
    pos: &[f32; 31],
    g0: f32,
    bias: f32,
    c1: f32,
    c2: f32,
    sigma: f32,
) {
    use std::arch::x86_64::*;
    const W: usize = 8;
    const P: usize = 31;
    let n = x.len();
    let main = n / W * W;
    let vg0 = _mm256_set1_ps(g0);
    let vbias = _mm256_set1_ps(bias);
    let vc1 = _mm256_set1_ps(c1);
    let vc2 = _mm256_set1_ps(c2);
    let vsigma = _mm256_set1_ps(sigma);
    let mut base = 0usize;
    while base < main {
        // the 31-entry position table has no power-of-two period, so
        // each 8-wide chunk gathers its lane constants scalar-side
        let mut pl = [0.0f32; W];
        for (j, p) in pl.iter_mut().enumerate() {
            *p = pos[(base + j) % P];
        }
        let xv = _mm256_loadu_ps(x.as_ptr().add(base));
        let nv = _mm256_loadu_ps(noise.as_ptr().add(base));
        let t = _mm256_add_ps(
            _mm256_add_ps(_mm256_mul_ps(vg0, xv), vbias),
            _mm256_loadu_ps(pl.as_ptr()),
        );
        let eps = tanh_avx2(t);
        let upd = _mm256_add_ps(
            _mm256_mul_ps(vc1, _mm256_sub_ps(xv, _mm256_mul_ps(vc2, eps))),
            _mm256_mul_ps(vsigma, nv),
        );
        _mm256_storeu_ps(x.as_mut_ptr().add(base), upd);
        base += W;
    }
    for j in main..n {
        let v = x[j];
        let eps = tanh_poly(g0 * v + bias + pos[j % P]);
        x[j] = c1 * (v - c2 * eps) + sigma * noise[j];
    }
}

/// The classification sweep's accumulate loops with vectorized products:
/// for every pass `p`, `acc[(i + p) % k_n] += (x[i] * wtab[(i * rot + p)
/// % 31]) as f64` in increasing-`i` order — exactly the scalar kernel's
/// products and accumulation order, so the result is **bit-identical**.
/// The weight-table lookup is hoisted into a per-pass periodic sequence
/// (`(i * rot + p) % 31` depends only on `i % 31`) and the f32 products
/// are computed 8 lanes at a time.
pub fn classify_accumulate(
    x: &[f32],
    wtab: &[f32; 31],
    passes: usize,
    k_n: usize,
    acc: &mut [f64],
) {
    debug_assert_eq!(acc.len(), k_n);
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: avx2() verified CPU support; slices are plain f32/f64.
        unsafe { classify_accumulate_avx2(x, wtab, passes, k_n, acc) };
        return;
    }
    classify_accumulate_portable(x, wtab, passes, k_n, acc);
}

/// Per-pass periodic weight sequence: `wtab[(i * rot + p) % 31]` as a
/// function of `i % 31`.
fn pass_weights(wtab: &[f32; 31], p: usize) -> [f32; 31] {
    let rot = p * 7 + 1;
    let mut seq = [0.0f32; 31];
    for (m, w) in seq.iter_mut().enumerate() {
        *w = wtab[(m * rot + p) % 31];
    }
    seq
}

/// Portable body of [`classify_accumulate`] (public for the equivalence
/// suite): identical products and accumulation order as the AVX2 path.
pub fn classify_accumulate_portable(
    x: &[f32],
    wtab: &[f32; 31],
    passes: usize,
    k_n: usize,
    acc: &mut [f64],
) {
    const W: usize = 8;
    for p in 0..passes {
        let seq = pass_weights(wtab, p);
        let main = x.len() / W * W;
        for (ci, xc) in x[..main].chunks_exact(W).enumerate() {
            let base = ci * W;
            let mut prod = [0.0f32; W];
            for j in 0..W {
                prod[j] = xc[j] * seq[(base + j) % 31];
            }
            for (j, &pr) in prod.iter().enumerate() {
                acc[(base + j + p) % k_n] += pr as f64;
            }
        }
        for (i, &v) in x.iter().enumerate().skip(main) {
            acc[(i + p) % k_n] += (v * seq[i % 31]) as f64;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn classify_accumulate_avx2(
    x: &[f32],
    wtab: &[f32; 31],
    passes: usize,
    k_n: usize,
    acc: &mut [f64],
) {
    use std::arch::x86_64::*;
    const W: usize = 8;
    for p in 0..passes {
        let seq = pass_weights(wtab, p);
        let main = x.len() / W * W;
        let mut base = 0usize;
        while base < main {
            let mut wl = [0.0f32; W];
            for (j, w) in wl.iter_mut().enumerate() {
                *w = seq[(base + j) % 31];
            }
            let prod = _mm256_mul_ps(
                _mm256_loadu_ps(x.as_ptr().add(base)),
                _mm256_loadu_ps(wl.as_ptr()),
            );
            let mut pr = [0.0f32; W];
            _mm256_storeu_ps(pr.as_mut_ptr(), prod);
            for (j, &v) in pr.iter().enumerate() {
                acc[(base + j + p) % k_n] += v as f64;
            }
            base += W;
        }
        for (i, &v) in x.iter().enumerate().skip(main) {
            acc[(i + p) % k_n] += (v * seq[i % 31]) as f64;
        }
    }
}

/// Widening Q8.8 dot product over [`Fixed`] slices: i16×i16 → i32
/// products summed into i64. Integer addition is associative, so the
/// SIMD lane order is **bit-exact** with the scalar MAC accumulator at
/// every length.
#[inline]
pub fn dot_wide_fixed(window: &[Fixed], weights: &[Fixed]) -> i64 {
    let n = window.len().min(weights.len());
    // SAFETY: Fixed is repr(transparent) over i16, so a &[Fixed] prefix
    // reinterprets as a &[i16] of the same length and alignment.
    let a = unsafe { std::slice::from_raw_parts(window.as_ptr() as *const i16, n) };
    let b = unsafe { std::slice::from_raw_parts(weights.as_ptr() as *const i16, n) };
    dot_wide_i16(a, b)
}

/// [`dot_wide_fixed`] over raw i16 slices (equal lengths).
#[inline]
pub fn dot_wide_i16(a: &[i16], b: &[i16]) -> i64 {
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: avx2() verified CPU support; slices are equal-length.
        return unsafe { dot_wide_avx2(a, b) };
    }
    dot_wide_portable(a, b)
}

/// Portable 8-lane body of [`dot_wide_i16`] (public for the equivalence
/// suite): per-lane i64 partials summed at the end — autovectorizable,
/// and exact regardless of order.
pub fn dot_wide_portable(a: &[i16], b: &[i16]) -> i64 {
    const W: usize = 8;
    let main = a.len() / W * W;
    let mut lanes = [0i64; W];
    for (ca, cb) in a[..main].chunks_exact(W).zip(b[..main].chunks_exact(W)) {
        for j in 0..W {
            lanes[j] += (ca[j] as i32 * cb[j] as i32) as i64;
        }
    }
    let mut acc: i64 = lanes.iter().sum();
    for i in main..a.len() {
        acc += (a[i] as i32 * b[i] as i32) as i64;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_wide_avx2(a: &[i16], b: &[i16]) -> i64 {
    use std::arch::x86_64::*;
    const W: usize = 8;
    let n = a.len();
    let main = n / W * W;
    // i32 products are widened to i64 lanes before accumulating:
    // _mm256_madd_epi16 would be faster but pairs adjacent products in
    // i32, and (i16::MIN)^2 * 2 overflows i32 — correctness first.
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i < main {
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        let prod = _mm256_mullo_epi32(_mm256_cvtepi16_epi32(va), _mm256_cvtepi16_epi32(vb));
        let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(prod));
        let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(prod));
        acc = _mm256_add_epi64(acc, _mm256_add_epi64(lo, hi));
        i += W;
    }
    let mut lanes = [0i64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    while i < n {
        s += (a[i] as i32 * b[i] as i32) as i64;
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ulp_diff(a: f32, b: f32) -> u32 {
        let ia = a.to_bits() as i32;
        let ib = b.to_bits() as i32;
        // map to a monotonic integer line (sign-magnitude → offset)
        let ma = if ia < 0 { i32::MIN.wrapping_sub(ia) } else { ia };
        let mb = if ib < 0 { i32::MIN.wrapping_sub(ib) } else { ib };
        ma.wrapping_sub(mb).unsigned_abs()
    }

    #[test]
    fn tanh_poly_close_to_libm_on_a_dense_grid() {
        let mut max_ulp = 0u32;
        for i in -4000..=4000 {
            let x = i as f32 * 0.0025; // [-10, 10]
            let d = ulp_diff(tanh_poly(x), x.tanh());
            max_ulp = max_ulp.max(d);
        }
        assert!(max_ulp <= 8, "tanh_poly drifted to {max_ulp} ULP from libm");
        // saturation and symmetry
        assert_eq!(tanh_poly(50.0), tanh_poly(CLAMP));
        assert_eq!(tanh_poly(-50.0), -tanh_poly(50.0));
        assert_eq!(tanh_poly(0.0), 0.0);
        assert_eq!(tanh_poly(1e-5), 1e-5, "tiny inputs return x");
    }

    #[test]
    fn dot_wide_matches_scalar_at_awkward_lengths() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 257] {
            let a: Vec<Fixed> = (0..n).map(|i| Fixed((i as i32 * 37 - 900) as i16)).collect();
            let b: Vec<Fixed> = (0..n).map(|i| Fixed((i as i32 * 61 - 700) as i16)).collect();
            let mut want = 0i64;
            for (x, w) in a.iter().zip(&b) {
                want += x.mul_wide(*w) as i64;
            }
            assert_eq!(dot_wide_fixed(&a, &b), want, "n = {n}");
            let ar: Vec<i16> = a.iter().map(|f| f.0).collect();
            let br: Vec<i16> = b.iter().map(|f| f.0).collect();
            assert_eq!(dot_wide_portable(&ar, &br), want, "portable n = {n}");
        }
    }

    #[test]
    fn dot_wide_extreme_values_do_not_overflow_lanes() {
        // i16::MIN * i16::MIN is the worst single product; 1024 of them
        // must survive (this is what rules out _mm256_madd_epi16)
        let a = vec![Fixed(i16::MIN); 1024];
        let b = vec![Fixed(i16::MIN); 1024];
        let want = 1024i64 * (i16::MIN as i32 * i16::MIN as i32) as i64;
        assert_eq!(dot_wide_fixed(&a, &b), want);
    }

    #[test]
    fn step_kernel_auto_matches_portable_bitwise() {
        let pos: [f32; 31] = std::array::from_fn(|k| (k as f32) * 0.021 - 0.31);
        for n in [0usize, 1, 7, 8, 9, 31, 100] {
            let x0: Vec<f32> = (0..n).map(|i| (i as f32) * 0.013 - 0.6).collect();
            let noise: Vec<f32> = (0..n).map(|i| (i as f32) * 0.003 - 0.1).collect();
            let mut a = x0.clone();
            let mut b = x0.clone();
            step_kernel(&mut a, &noise, &pos, 0.8, 0.05, 1.01, 0.05, 0.1);
            step_kernel_portable(&mut b, &noise, &pos, 0.8, 0.05, 1.01, 0.05, 0.1);
            assert_eq!(a, b, "AVX2 and portable lanes diverged at n = {n}");
        }
    }

    #[test]
    fn classify_accumulate_auto_matches_portable_bitwise() {
        let wtab: [f32; 31] = std::array::from_fn(|k| (k as f32) * 0.017 - 0.26);
        for n in [0usize, 1, 7, 8, 9, 31, 200] {
            let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.11).sin() * 0.5).collect();
            let mut a = vec![0.0f64; 10];
            let mut b = vec![0.0f64; 10];
            classify_accumulate(&x, &wtab, 3, 10, &mut a);
            classify_accumulate_portable(&x, &wtab, 3, 10, &mut b);
            assert_eq!(a, b, "classify accumulate diverged at n = {n}");
        }
    }
}
