//! Fixed-size worker thread pool (rayon-lite) built on std threads and
//! channels. Used by the coordinator's worker lanes and by the design-space
//! sweep to parallelize independent simulations.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("sfmmcn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker finished")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u64> = pool.map(Vec::<u64>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_runs_jobs_still_queued_at_drop_time() {
        // Drop closes the submission side and JOINS — it must not strand
        // jobs still sitting in the queue. One slow worker guarantees a
        // backlog exists the moment the pool is dropped; every queued
        // job must still execute before drop returns.
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        // head job holds the single worker so the rest stay queued
        {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(30));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // the backlog cannot have drained yet: the head job sleeps far
        // longer than the submission loop takes
        drop(pool);
        assert_eq!(
            counter.load(Ordering::SeqCst),
            51,
            "drop must drain the queued backlog, not discard it"
        );
    }

    #[test]
    fn workers_shut_down_after_drop() {
        // After drop returns, the worker threads are joined — submitting
        // through a clone of nothing is impossible by construction, and
        // a second pool can be created immediately (no thread leakage
        // across pools sharing names).
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        let n_after_join = counter.load(Ordering::SeqCst);
        assert_eq!(n_after_join, 10);
        // fresh pool over the same counter works independently
        let pool2 = ThreadPool::new(3);
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool2.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool2);
        assert_eq!(counter.load(Ordering::SeqCst), 15);
    }
}
