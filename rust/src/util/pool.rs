//! Fixed-size worker thread pool (rayon-lite) built on std threads and
//! channels. Used by the coordinator's worker lanes and by the design-space
//! sweep to parallelize independent simulations.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("sfmmcn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker finished")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u64> = pool.map(Vec::<u64>::new(), |x| x);
        assert!(out.is_empty());
    }
}
