//! # SF-MMCN — Server-Flow Multi-Mode CNN / Diffusion Accelerator
//!
//! A full-system reproduction of *"SF-MMCN: Low-Power Server Flow Multi-Mode
//! Diffusion Model Accelerator"* (Hsu, Wey, Teo — 2024).
//!
//! The paper describes a silicon CNN accelerator (TSMC 40 nm). This crate
//! reproduces the *system* in software as three layers:
//!
//! * **L3 (this crate)** — a cycle-accurate simulator of the SF-MMCN
//!   micro-architecture (9-PE server-flow units, zero-gating, pipelining,
//!   data-reuse registers), an energy/area model calibrated to the paper's
//!   synthesis numbers, a layer-graph compiler/mapper, baseline accelerators
//!   (CARLA-like row-stationary, MMCN series-mode, dense PE array), and a
//!   diffusion-serving coordinator that drives functional numerics through
//!   PJRT-compiled XLA executables.
//! * **L2 (python/compile)** — JAX model definitions (VGG-16, ResNet-18,
//!   U-Net with time embedding, DDPM de-noise step), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels implementing the
//!   server-flow fused conv+residual dataflow, validated against a pure-jnp
//!   oracle.
//!
//! Python never runs at serving time: `make artifacts` lowers everything
//! once; the rust binary loads `artifacts/*.hlo.txt` through the PJRT C API.

pub mod baselines;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod models;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
