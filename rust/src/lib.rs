//! # SF-MMCN — Server-Flow Multi-Mode CNN / Diffusion Accelerator
//!
//! A full-system reproduction of *"SF-MMCN: Low-Power Server Flow Multi-Mode
//! Diffusion Model Accelerator"* (Hsu, Wey, Teo — 2024).
//!
//! The paper describes a silicon CNN accelerator (TSMC 40 nm). This crate
//! reproduces the *system* in software as three layers:
//!
//! * **L3 (this crate)** — a cycle-accurate simulator of the SF-MMCN
//!   micro-architecture (9-PE server-flow units, zero-gating, pipelining,
//!   data-reuse registers), an energy/area model calibrated to the paper's
//!   synthesis numbers, a layer-graph compiler/mapper, baseline accelerators
//!   (CARLA-like row-stationary, MMCN series-mode, dense PE array), and a
//!   diffusion-serving coordinator that drives functional numerics through
//!   PJRT-compiled XLA executables.
//! * **L2 (python/compile)** — JAX model definitions (VGG-16, ResNet-18,
//!   U-Net with time embedding, DDPM de-noise step), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels implementing the
//!   server-flow fused conv+residual dataflow, validated against a pure-jnp
//!   oracle.
//!
//! Python never runs at serving time: `make artifacts` lowers everything
//! once; the rust binary loads `artifacts/*.hlo.txt` through the PJRT C API.

// The public serving surface (`coordinator`, `config`) is fully
// documented and the CI lint job runs `cargo doc --no-deps` with
// warnings-as-errors, so it can't rot. The simulator/runtime internals
// are ratcheted module by module: remove an `allow` below once that
// module's public items are documented.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod baselines;
#[allow(missing_docs)]
pub mod compiler;
pub mod config;
pub mod coordinator;
#[allow(missing_docs)]
pub mod models;
#[allow(missing_docs)]
pub mod quant;
#[allow(missing_docs)]
pub mod report;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod sim;
#[allow(missing_docs)]
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
