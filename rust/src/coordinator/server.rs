//! The diffusion serving loop: request queue → fair batcher → worker
//! lanes, each lane a two-stage pipeline (host prep ∥ device execute).
//!
//! Rebuilt for ISSUE 3 around a true batched, pipelined request path:
//!
//! * **Fair shared batcher** ([`Batcher`]): a single queue all workers
//!   drain with round-robin-fair grabs — one grab takes at most
//!   `ceil(pending / workers)` requests (capped at `max_batch`), so a
//!   fast worker can no longer swallow `max_batch` requests while the
//!   others starve on an empty queue. Batches only group requests with
//!   identical step counts, so per-request `steps` stays honored.
//! * **Batched fused dispatch** (`cfg.batched`): B requests'
//!   `x`/`t_emb`/`coeff`/`noise` tensors stack into one `[B, ...]`
//!   device execution per timestep chunk ([`BatchDispatch`]) — the
//!   `unet_denoise_scan` idea generalized across the queue, the serving-
//!   layer analogue of Server Flow keeping a small PE pool saturated by
//!   streaming work through it (paper §III).
//! * **Double-buffered host stage** (`cfg.pipeline`): a per-worker host
//!   thread generates the *next* batch's noise draws and time embeddings
//!   while the device executes the current one (a capacity-1 channel is
//!   the double buffer); device-side waits on that channel are counted
//!   as `pipeline_stalls`.
//! * **Pooled zero-allocation hot path** (`cfg.pooled`, ISSUE 4): every
//!   batch tensor leases its slab from a per-worker-lane [`BufferPool`]
//!   and returns it after the dispatch, and the device stage executes in
//!   place against rotating image slabs (`Executor::run_batched_into`)
//!   instead of allocating a fresh output per chunk. With the capacity-1
//!   prep channel, at most two batches are in flight per lane, so the
//!   pool stabilizes at two rotating arenas after warmup and the
//!   allocator drops out of the steady-state loop entirely — the
//!   software analogue of Server Flow reusing a fixed resource set
//!   across a stream (paper §III). `pooled = false` swaps in the
//!   retain-nothing pool: the identical code path, but every lease
//!   allocates — the PR 2 per-batch-allocating baseline the serve bench
//!   compares against. Only the result images still allocate (they
//!   escape to the caller).
//!
//! Workers own their executor (PJRT clients are not shared across
//! threads) and compile/register the denoise artifact once at startup.
//! On the `Native` backend the same loop runs entirely offline against
//! the host-CPU surrogate and synthetic parameters, which is what tier-1
//! and the serve benchmarks exercise.

use std::path::PathBuf;
use std::sync::mpsc::{channel, sync_channel, Sender, TryRecvError};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{ServeBackend, ServeConfig};
use crate::coordinator::ddpm::{time_embedding, time_embedding_into, DdpmSchedule};
use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::params::UnetParams;
use crate::models::{unet, UnetConfig};
use crate::runtime::{
    ArtifactStore, BatchDispatch, BufferPool, Executor, NativeDenoise, PoolStats,
    PreparedInputs, TensorBuf,
};
use crate::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use crate::sim::energy::EventCounts;
use crate::util::{Rng, Tensor};

/// One de-noising request (generate an image from noise).
#[derive(Debug, Clone)]
pub struct DenoiseRequest {
    pub id: u64,
    pub seed: u64,
    /// Reverse steps (defaults to the server's schedule length).
    pub steps: usize,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct DenoiseResult {
    pub id: u64,
    pub image: TensorBuf,
    pub latency: Duration,
    pub steps: usize,
}

/// Shared request queue with fairness: one grab takes at most
/// `ceil(pending / workers)` requests (≤ `max_batch`, ≥ 1), and a batch
/// only groups requests with the same step count. The barrier holds all
/// worker lanes at the line until everyone finished setup, so the fair
/// division is over the real worker count, not over whoever compiled
/// first.
///
/// Fairness is per grab, not end-to-end: with the pipelined host stage a
/// lane prefetches, so it can hold one executing batch plus one buffered
/// batch plus one being prepared (each a fair share of what was pending
/// at its grab). That bounded lookahead is the price of overlapping host
/// prep with device execution; `pipeline = false` restores strict
/// grab-on-demand draining.
struct Batcher {
    queue: Mutex<std::collections::VecDeque<DenoiseRequest>>,
    workers: usize,
    max_batch: usize,
    start: Barrier,
}

impl Batcher {
    fn new(requests: Vec<DenoiseRequest>, workers: usize, max_batch: usize) -> Self {
        Self {
            queue: Mutex::new(requests.into()),
            workers: workers.max(1),
            max_batch: max_batch.max(1),
            start: Barrier::new(workers.max(1)),
        }
    }

    /// Block until every worker lane reached its starting line (called
    /// once per worker thread, before any batch is taken).
    fn ready_wait(&self) {
        self.start.wait();
    }

    /// Cancel all pending work (the error path): workers finish their
    /// in-flight batch, find the queue empty, and exit.
    fn clear(&self) {
        self.queue.lock().unwrap().clear();
    }

    /// Take the next fair batch, or `None` when the queue is drained.
    fn next_batch(&self) -> Option<Vec<DenoiseRequest>> {
        let mut q = self.queue.lock().unwrap();
        let pending = q.len();
        if pending == 0 {
            return None;
        }
        let fair = pending.div_ceil(self.workers);
        let take = fair.clamp(1, self.max_batch);
        let steps0 = q.front().map(|r| r.steps).unwrap_or(0);
        let mut batch = Vec::with_capacity(take);
        while batch.len() < take {
            match q.front() {
                Some(r) if r.steps == steps0 => batch.push(q.pop_front().unwrap()),
                _ => break,
            }
        }
        Some(batch)
    }
}

/// Everything a worker lane needs, owned (moved into its thread).
struct WorkerCtx {
    worker: usize,
    backend: ServeBackend,
    artifact: String,
    artifact_path: Option<PathBuf>,
    params: Arc<UnetParams>,
    schedule: Arc<DdpmSchedule>,
    img_shape: Vec<usize>,
    time_dim: usize,
    fused: bool,
    batched: bool,
    pipeline: bool,
    chunk: usize,
    pooled: bool,
}

/// One per-batch progress report from a worker lane.
struct WorkerMsg {
    worker: usize,
    results: Vec<DenoiseResult>,
    step_us: Vec<f64>,
    host_prep_us: f64,
    dispatches: usize,
    batch_items: usize,
    stalled: bool,
    /// Cumulative snapshot of this worker's buffer pool at send time; the
    /// server keeps the latest per worker and sums them at the end.
    pool: PoolStats,
}

/// A batch with all host-side tensors generated (stage 1 of the lane
/// pipeline). Noise draw order per request matches the step-at-a-time
/// loop exactly — initial x, then one map per step t = T-1..1, none at
/// t = 0 — so every execution mode produces the same images.
///
/// Every tensor's backing slab is leased from the lane's [`BufferPool`];
/// [`execute_batch`] reclaims them all once the batch completes.
struct PreparedBatch {
    reqs: Vec<DenoiseRequest>,
    steps: usize,
    /// `[B, c, h, w]` initial noise images.
    x0: TensorBuf,
    /// `[steps, time_dim]`, rows in descending-t order.
    t_embs: TensorBuf,
    /// `[steps, 3]` = (c1, c2, sigma) rows, descending-t order.
    coeffs: TensorBuf,
    /// `[B, steps, c, h, w]` per-request per-step noise draws.
    noises: TensorBuf,
    prep_us: f64,
}

fn prepare_host_batch(
    reqs: Vec<DenoiseRequest>,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    pool: &BufferPool,
) -> Result<PreparedBatch> {
    let t0 = Instant::now();
    let steps = reqs.first().map(|r| r.steps).unwrap_or(0);
    if steps == 0 || steps > schedule.t_max() {
        bail!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            reqs.first().map(|r| r.id).unwrap_or(0),
            schedule.t_max()
        );
    }
    let n: usize = img_shape.iter().product();
    let b = reqs.len();
    // Every slab takes the no-memset dirty lease: each row below is
    // written exactly once — noise rows by `normal_fill` (the exact
    // stream `normal_vec` used to draw, keeping images bit-identical),
    // and the per-request t = 0 row (no noise is injected at the final
    // step) by an explicit zero fill.
    let mut x0 = pool.lease_dirty(b * n);
    let mut noises = pool.lease_dirty(b * steps * n);
    for (i, req) in reqs.iter().enumerate() {
        debug_assert_eq!(req.steps, steps, "batcher groups by step count");
        let mut rng = Rng::new(req.seed);
        rng.normal_fill(&mut x0[i * n..(i + 1) * n]);
        for (r, t) in (0..steps).rev().enumerate() {
            let base = (i * steps + r) * n;
            if t > 0 {
                rng.normal_fill(&mut noises[base..base + n]);
            } else {
                noises[base..base + n].fill(0.0);
            }
        }
    }
    let mut t_embs = pool.lease_dirty(steps * time_dim);
    let mut coeffs = pool.lease_dirty(steps * 3);
    for (r, t) in (0..steps).rev().enumerate() {
        time_embedding_into(t as f32, &mut t_embs[r * time_dim..(r + 1) * time_dim]);
        let (c1, c2, sigma) = schedule.coefficients(t);
        coeffs[r * 3..(r + 1) * 3].copy_from_slice(&[c1, c2, sigma]);
    }
    let mut xshape = vec![b];
    xshape.extend_from_slice(img_shape);
    let mut nshape = vec![b, steps];
    nshape.extend_from_slice(img_shape);
    Ok(PreparedBatch {
        steps,
        x0: TensorBuf::new(xshape, x0)?,
        t_embs: TensorBuf::new(vec![steps, time_dim], t_embs)?,
        coeffs: TensorBuf::new(vec![steps, 3], coeffs)?,
        noises: TensorBuf::new(nshape, noises)?,
        reqs,
        prep_us: t0.elapsed().as_micros() as f64,
    })
}

/// Gather one timestep chunk's noise rows `[B, len, ...]` out of the
/// whole-request `[B, steps, ...]` tensor into a caller slab sized to
/// exactly `B * len` rows.
fn copy_noise_chunk_into(
    noises: &TensorBuf,
    b: usize,
    steps: usize,
    lo: usize,
    len: usize,
    out: &mut [f32],
) -> Result<()> {
    if noises.shape.len() < 2 || noises.shape[0] != b || noises.shape[1] != steps {
        bail!(
            "noise tensor shape {:?} != [B={b}, steps={steps}, ...]",
            noises.shape
        );
    }
    if lo + len > steps {
        bail!("noise chunk {lo}..{} out of {steps} steps", lo + len);
    }
    let n: usize = noises.shape[2..].iter().product();
    if out.len() != b * len * n {
        bail!(
            "noise chunk slab holds {} elements, chunk [B={b}, {len}, ...] needs {}",
            out.len(),
            b * len * n
        );
    }
    for i in 0..b {
        let src = (i * steps + lo) * n;
        out[i * len * n..(i + 1) * len * n]
            .copy_from_slice(&noises.data[src..src + len * n]);
    }
    Ok(())
}

/// Fused path (§Perf, L2): the whole reverse process in one device
/// dispatch per request. On the native backend the scan honors the
/// request's own step count; a PJRT scan artifact bakes T into its
/// signature, so a mismatching request is rejected with a clear error
/// instead of silently running the wrong number of steps.
#[allow(clippy::too_many_arguments)]
fn denoise_one_fused(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    native: bool,
    req: &DenoiseRequest,
    step_latency_us: &mut Vec<f64>,
) -> Result<DenoiseResult> {
    let t0 = Instant::now();
    let steps = req.steps;
    if steps == 0 || steps > schedule.t_max() {
        bail!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            req.id,
            schedule.t_max()
        );
    }
    if !native && steps != schedule.t_max() {
        bail!(
            "request {}: the fused scan artifact executes exactly {} steps but the \
             request asked for {steps} — send steps = {} or use the step-mode path",
            req.id,
            schedule.t_max(),
            schedule.t_max()
        );
    }
    let mut rng = Rng::new(req.seed);
    let n: usize = img_shape.iter().product();
    let x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
    let mut t_embs = Vec::with_capacity(steps * time_dim);
    let mut coeffs = Vec::with_capacity(steps * 3);
    let mut noises = Vec::with_capacity(steps * n);
    for t in (0..steps).rev() {
        t_embs.extend(time_embedding(t as f32, time_dim));
        let (c1, c2, sigma) = schedule.coefficients(t);
        coeffs.extend([c1, c2, sigma]);
        if t > 0 {
            noises.extend(rng.normal_vec(n));
        } else {
            noises.extend(std::iter::repeat_n(0.0f32, n));
        }
    }
    let mut full_shape = vec![steps];
    full_shape.extend_from_slice(img_shape);
    let dynamic = vec![
        x,
        TensorBuf::new(vec![steps, time_dim], t_embs)?,
        TensorBuf::new(vec![steps, 3], coeffs)?,
        TensorBuf::new(full_shape, noises)?,
    ];
    let out = exe.run_prepared(artifact, &dynamic, prepared)?;
    let image = out.into_iter().next().context("scan returned nothing")?;
    let total = t0.elapsed();
    // one sample per step (the fused dispatch's wall spread over its
    // steps), so histogram counts line up with `steps_done` across modes
    let per_step = total.as_micros() as f64 / steps as f64;
    for _ in 0..steps {
        step_latency_us.push(per_step);
    }
    Ok(DenoiseResult {
        id: req.id,
        image,
        latency: total,
        steps,
    })
}

/// Run one de-noise request step-at-a-time on a prepared executor.
///
/// §Perf: the 33 weight tensors (~530 KB) are pre-converted once per
/// worker ([`Executor::prepare`]); each step only converts the six
/// small per-step tensors (~1.3 KB).
#[allow(clippy::too_many_arguments)]
fn denoise_one(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    req: &DenoiseRequest,
    step_latency_us: &mut Vec<f64>,
) -> Result<DenoiseResult> {
    let t0 = Instant::now();
    let steps = req.steps;
    if steps == 0 || steps > schedule.t_max() {
        bail!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            req.id,
            schedule.t_max()
        );
    }
    let mut rng = Rng::new(req.seed);
    let n: usize = img_shape.iter().product();
    let mut x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
    let mut dynamic: Vec<TensorBuf> = vec![
        x.clone(),
        TensorBuf::zeros(&[time_dim]),
        TensorBuf::scalar(0.0),
        TensorBuf::scalar(0.0),
        TensorBuf::scalar(0.0),
        TensorBuf::zeros(img_shape),
    ];
    for t in (0..steps).rev() {
        let s0 = Instant::now();
        let (c1, c2, sigma) = schedule.coefficients(t);
        dynamic[0] = x;
        dynamic[1] = TensorBuf::new(vec![time_dim], time_embedding(t as f32, time_dim))?;
        dynamic[2] = TensorBuf::scalar(c1);
        dynamic[3] = TensorBuf::scalar(c2);
        dynamic[4] = TensorBuf::scalar(sigma);
        dynamic[5] = if t > 0 {
            TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?
        } else {
            TensorBuf::zeros(img_shape)
        };
        let out = exe.run_prepared(artifact, &dynamic, prepared)?;
        x = out.into_iter().next().context("artifact returned nothing")?;
        step_latency_us.push(s0.elapsed().as_micros() as f64);
    }
    Ok(DenoiseResult {
        id: req.id,
        image: x,
        latency: t0.elapsed(),
        steps,
    })
}

/// One timestep-chunk dispatch, in place: the updated images overwrite
/// `out`'s slab. A whole-request chunk borrows the prepared tensors
/// directly; a partial chunk gathers its rows into pool-leased scratch
/// and returns it before reporting (on the error path the scratch is
/// simply dropped — an error tears the serving session down).
#[allow(clippy::too_many_arguments)]
fn dispatch_chunk(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    pool: &BufferPool,
    pb: &PreparedBatch,
    x: &TensorBuf,
    out: &mut TensorBuf,
    lo: usize,
    len: usize,
) -> Result<()> {
    let b = pb.reqs.len();
    let steps = pb.steps;
    if lo == 0 && len == steps {
        let d = BatchDispatch {
            batch: b,
            steps: len,
            x,
            t_embs: &pb.t_embs,
            coeffs: &pb.coeffs,
            noises: &pb.noises,
        };
        return exe.run_batched_into(artifact, &d, prepared, out);
    }
    // gather scratch is fully overwritten by the exact-length copies, so
    // it takes the no-memset dirty lease
    let time_dim = pb.t_embs.shape[1];
    let mut te = pool.lease_tensor_dirty(&[len, time_dim]);
    pb.t_embs.copy_rows_into(lo, len, &mut te.data)?;
    let mut co = pool.lease_tensor_dirty(&[len, 3]);
    pb.coeffs.copy_rows_into(lo, len, &mut co.data)?;
    let mut nshape = vec![b, len];
    nshape.extend_from_slice(&pb.noises.shape[2..]);
    let mut no = pool.lease_tensor_dirty(&nshape);
    copy_noise_chunk_into(&pb.noises, b, steps, lo, len, &mut no.data)?;
    let d = BatchDispatch {
        batch: b,
        steps: len,
        x,
        t_embs: &te,
        coeffs: &co,
        noises: &no,
    };
    let r = exe.run_batched_into(artifact, &d, prepared, out);
    pool.reclaim(te);
    pool.reclaim(co);
    pool.reclaim(no);
    r
}

/// Stage 2 of a batched lane: run one prepared batch through the device
/// in timestep chunks — in place against two rotating pool-leased image
/// slabs — and report results. All leased slabs (the prepared batch's
/// and the rotating pair) go back to the pool on completion.
fn execute_batch(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    pool: &BufferPool,
    pb: PreparedBatch,
    stalled: bool,
    res_tx: &Sender<Result<WorkerMsg>>,
) {
    let t0 = Instant::now();
    let b = pb.reqs.len();
    let steps = pb.steps;
    // A PJRT scan artifact bakes its step count; reject mismatches with
    // the same clear error as the per-request fused path instead of
    // dispatching wrong-shaped literals into XLA.
    if ctx.backend == ServeBackend::Pjrt && steps != ctx.schedule.t_max() {
        let _ = res_tx.send(Err(anyhow::anyhow!(
            "request {}: the fused scan artifact executes exactly {} steps but the \
             request asked for {steps} — send steps = {} or use the native backend",
            pb.reqs[0].id,
            ctx.schedule.t_max(),
            ctx.schedule.t_max()
        )));
        return;
    }
    let chunk = if ctx.chunk == 0 {
        steps
    } else {
        ctx.chunk.min(steps)
    };
    // Rotating image slabs, materialized lazily: each dispatch reads the
    // current images and writes a destination slab, then the old current
    // becomes the next destination — in-place ping-pong instead of a
    // fresh output allocation per chunk. The first chunk reads `pb.x0`
    // directly, so a whole-request batch (chunk = 0, the default) leases
    // exactly one slab and a chunked batch exactly two.
    let mut cur: Option<TensorBuf> = None;
    let mut spare: Option<TensorBuf> = None;
    let mut dispatches = 0usize;
    let mut batch_items = 0usize;
    let mut done = 0usize;
    while done < steps {
        let c = chunk.min(steps - done);
        // the dispatch fully overwrites its destination, so the rotation
        // slabs take the no-memset dirty lease
        let mut dst = spare
            .take()
            .unwrap_or_else(|| pool.lease_tensor_dirty(&pb.x0.shape));
        let src = cur.as_ref().unwrap_or(&pb.x0);
        if let Err(e) = dispatch_chunk(
            exe,
            &ctx.artifact,
            prepared,
            pool,
            &pb,
            src,
            &mut dst,
            done,
            c,
        ) {
            let _ = res_tx.send(Err(e));
            return;
        }
        spare = cur.replace(dst);
        dispatches += 1;
        batch_items += b;
        done += c;
    }
    let latency = t0.elapsed();
    // per-step latency: each request experienced the batch's wall time,
    // spread over its steps — one sample per request-step, so the
    // histogram counts line up with `steps_done` across modes.
    let per_step = latency.as_micros() as f64 / steps as f64;
    let step_us = vec![per_step; steps * b];
    // The result images escape to the caller, so they are the one
    // allocation this path keeps (sized exactly, filled by unstack_into);
    // every scratch slab goes back. `cur` is always Some here: prepare
    // guarantees steps >= 1, so at least one chunk dispatched.
    let final_x = match cur {
        Some(t) => t,
        None => {
            let _ = res_tx.send(Err(anyhow::anyhow!(
                "batched dispatch loop executed no chunks for {steps} steps"
            )));
            return;
        }
    };
    let n_inner: usize = pb.x0.shape[1..].iter().product();
    // capacity-only construction: unstack_into rewrites shape and data,
    // so pre-zeroing the images would be a dead fill pass
    let mut images: Vec<TensorBuf> = (0..b)
        .map(|_| TensorBuf {
            shape: vec![0],
            data: Vec::with_capacity(n_inner),
        })
        .collect();
    if let Err(e) = final_x.unstack_into(&mut images) {
        let _ = res_tx.send(Err(e));
        return;
    }
    pool.reclaim(final_x);
    if let Some(s) = spare {
        pool.reclaim(s);
    }
    let PreparedBatch {
        reqs,
        x0,
        t_embs,
        coeffs,
        noises,
        prep_us,
        ..
    } = pb;
    pool.reclaim(x0);
    pool.reclaim(t_embs);
    pool.reclaim(coeffs);
    pool.reclaim(noises);
    // (a dispatch that returned the wrong leading dim already failed
    // above: unstack_into rejects a row-count mismatch)
    let results: Vec<DenoiseResult> = reqs
        .iter()
        .zip(images)
        .map(|(req, image)| DenoiseResult {
            id: req.id,
            image,
            latency,
            steps,
        })
        .collect();
    let _ = res_tx.send(Ok(WorkerMsg {
        worker: ctx.worker,
        results,
        step_us,
        host_prep_us: prep_us,
        dispatches,
        batch_items,
        stalled,
        pool: pool.stats(),
    }));
}

/// Batched lane: host-prep stage (optionally on its own thread, double-
/// buffered through a capacity-1 channel) feeding the device stage.
fn run_batched_lane(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    batcher: &Arc<Batcher>,
    res_tx: &Sender<Result<WorkerMsg>>,
) {
    // One buffer pool per worker lane, shared by the host-prep stage and
    // the device stage (at most two threads contend, at batch
    // granularity). `pooled = false` swaps in the retain-nothing pool:
    // the identical code path, but every lease allocates and every
    // return frees — the per-batch-allocating baseline.
    let pool = Arc::new(if ctx.pooled {
        BufferPool::new()
    } else {
        BufferPool::disabled()
    });
    if ctx.pipeline {
        let (prep_tx, prep_rx) = sync_channel::<Result<PreparedBatch>>(1);
        let b2 = Arc::clone(batcher);
        let schedule = Arc::clone(&ctx.schedule);
        let img_shape = ctx.img_shape.clone();
        let time_dim = ctx.time_dim;
        let prep_pool = Arc::clone(&pool);
        let prep = std::thread::Builder::new()
            .name(format!("sfmmcn-hostprep-{}", ctx.worker))
            .spawn(move || {
                while let Some(reqs) = b2.next_batch() {
                    let pb =
                        prepare_host_batch(reqs, &schedule, &img_shape, time_dim, &prep_pool);
                    if prep_tx.send(pb).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn host-prep thread");
        // The first wait is the pipeline filling, not a stall.
        let mut first = true;
        loop {
            let (pb, stalled) = match prep_rx.try_recv() {
                Ok(pb) => (pb, false),
                Err(TryRecvError::Empty) => match prep_rx.recv() {
                    Ok(pb) => (pb, !first),
                    Err(_) => break, // prep stage done: queue drained
                },
                Err(TryRecvError::Disconnected) => break,
            };
            first = false;
            match pb {
                Ok(pb) => execute_batch(ctx, exe, prepared, &pool, pb, stalled, res_tx),
                Err(e) => {
                    let _ = res_tx.send(Err(e));
                }
            }
        }
        let _ = prep.join();
    } else {
        while let Some(reqs) = batcher.next_batch() {
            match prepare_host_batch(reqs, &ctx.schedule, &ctx.img_shape, ctx.time_dim, &pool) {
                Ok(pb) => execute_batch(ctx, exe, prepared, &pool, pb, false, res_tx),
                Err(e) => {
                    let _ = res_tx.send(Err(e));
                }
            }
        }
    }
}

/// Per-request lane (the pre-ISSUE-3 execution mode, kept as the
/// comparison baseline): requests still come through the fair batcher,
/// but each runs solo — per step, or one fused scan when `fused`.
fn run_request_lane(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    batcher: &Arc<Batcher>,
    res_tx: &Sender<Result<WorkerMsg>>,
) {
    while let Some(batch) = batcher.next_batch() {
        for req in batch {
            let mut step_us = Vec::new();
            let r = if ctx.fused {
                denoise_one_fused(
                    exe,
                    &ctx.artifact,
                    prepared,
                    &ctx.schedule,
                    &ctx.img_shape,
                    ctx.time_dim,
                    ctx.backend == ServeBackend::Native,
                    &req,
                    &mut step_us,
                )
            } else {
                denoise_one(
                    exe,
                    &ctx.artifact,
                    prepared,
                    &ctx.schedule,
                    &ctx.img_shape,
                    ctx.time_dim,
                    &req,
                    &mut step_us,
                )
            };
            match r {
                Ok(res) => {
                    let dispatches = if ctx.fused { 1 } else { res.steps };
                    let _ = res_tx.send(Ok(WorkerMsg {
                        worker: ctx.worker,
                        results: vec![res],
                        step_us,
                        host_prep_us: 0.0,
                        dispatches,
                        batch_items: dispatches,
                        stalled: false,
                        // the per-request lane allocates per dispatch by
                        // design (it is the comparison baseline)
                        pool: PoolStats::default(),
                    }));
                }
                Err(e) => {
                    let _ = res_tx.send(Err(e));
                }
            }
        }
    }
}

/// Executor setup for one worker: create, compile/register the artifact,
/// pre-convert the weights (§Perf).
fn worker_setup(ctx: &WorkerCtx) -> Result<(Executor, PreparedInputs)> {
    let mut exe = Executor::new()?;
    match ctx.backend {
        ServeBackend::Pjrt => {
            let path = ctx
                .artifact_path
                .as_ref()
                .expect("pjrt backend resolved an artifact path");
            exe.load_hlo_text(&ctx.artifact, path)?;
        }
        ServeBackend::Native => {
            exe.register_native(
                &ctx.artifact,
                NativeDenoise::new(ctx.img_shape.clone(), ctx.time_dim),
            );
        }
    }
    let prepared = exe.prepare(&ctx.params.tensors)?;
    Ok((exe, prepared))
}

fn worker_main(ctx: WorkerCtx, batcher: Arc<Batcher>, res_tx: Sender<Result<WorkerMsg>>) {
    // Setup (PJRT compilation can take seconds and varies per thread)
    // happens BEFORE the barrier; every worker then reaches the line
    // exactly once, success or not, so the barrier cannot deadlock and
    // the fair queue division starts from a simultaneous standing start.
    let setup = worker_setup(&ctx);
    batcher.ready_wait();
    let (exe, prepared) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = res_tx.send(Err(e));
            return;
        }
    };
    if ctx.batched {
        run_batched_lane(&ctx, &exe, &prepared, &batcher, &res_tx);
    } else {
        run_request_lane(&ctx, &exe, &prepared, &batcher, &res_tx);
    }
}

/// Serving coordinator.
pub struct DiffusionServer {
    cfg: ServeConfig,
    artifact: String,
    artifact_path: Option<PathBuf>,
    params: Arc<UnetParams>,
    schedule: Arc<DdpmSchedule>,
    img_shape: Vec<usize>,
    time_dim: usize,
}

impl DiffusionServer {
    /// Build a server for the given config. The PJRT backend resolves the
    /// artifact and loads the weight blob (deferring PJRT setup to the
    /// workers); the native backend synthesizes deterministic parameters
    /// and needs no artifacts at all.
    pub fn new(cfg: ServeConfig, store: &ArtifactStore) -> Result<Self> {
        let ucfg = UnetConfig::default();
        let schedule = DdpmSchedule::standard(cfg.steps);
        // the fused artifact bakes T into its name and signature
        let artifact = if cfg.fused && cfg.backend == ServeBackend::Pjrt {
            format!("unet_denoise_scan{}_16", cfg.steps)
        } else {
            cfg.artifact.clone()
        };
        let (artifact_path, params) = match cfg.backend {
            ServeBackend::Pjrt => {
                let spec = store.resolve(&artifact)?;
                let params = UnetParams::load(store.root(), "unet_params")
                    .context("loading unet params blob")?;
                (Some(spec.path), params)
            }
            ServeBackend::Native => (None, UnetParams::synthetic(&ucfg, cfg.seed)),
        };
        if cfg.batched && cfg.backend == ServeBackend::Pjrt {
            if !cfg.fused {
                bail!(
                    "batched serving on the PJRT backend dispatches through the fused \
                     scan artifact — enable serve.fused (--fused), or use the native backend"
                );
            }
            if cfg.chunk != 0 && cfg.chunk != cfg.steps {
                bail!(
                    "serve.chunk = {} is only supported on the native backend — a PJRT \
                     scan artifact bakes its step count, so use chunk = 0 (whole request)",
                    cfg.chunk
                );
            }
        }
        Ok(Self {
            cfg,
            artifact,
            artifact_path,
            params: Arc::new(params),
            schedule: Arc::new(schedule),
            img_shape: vec![ucfg.img_channels, ucfg.img, ucfg.img],
            time_dim: ucfg.time_dim,
        })
    }

    /// Serve a batch of requests across `cfg.workers` threads; returns the
    /// results (in completion order) and aggregated metrics.
    pub fn serve(&self, requests: Vec<DenoiseRequest>) -> Result<(Vec<DenoiseResult>, ServeMetrics)> {
        let t0 = Instant::now();
        let n_requests = requests.len();
        let batcher = Arc::new(Batcher::new(
            requests,
            self.cfg.workers,
            self.cfg.max_batch,
        ));
        let (res_tx, res_rx) = channel::<Result<WorkerMsg>>();

        let mut handles = Vec::new();
        for w in 0..self.cfg.workers {
            let ctx = WorkerCtx {
                worker: w,
                backend: self.cfg.backend,
                artifact: self.artifact.clone(),
                artifact_path: self.artifact_path.clone(),
                params: Arc::clone(&self.params),
                schedule: Arc::clone(&self.schedule),
                img_shape: self.img_shape.clone(),
                time_dim: self.time_dim,
                fused: self.cfg.fused,
                batched: self.cfg.batched,
                pipeline: self.cfg.pipeline,
                chunk: self.cfg.chunk,
                pooled: self.cfg.pooled,
            };
            let batcher = Arc::clone(&batcher);
            let res_tx = res_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sfmmcn-serve-{w}"))
                    .spawn(move || worker_main(ctx, batcher, res_tx))
                    .expect("spawn worker"),
            );
        }
        drop(res_tx);

        let mut results = Vec::with_capacity(n_requests);
        let mut metrics = ServeMetrics::new();
        metrics.per_worker_requests = vec![0; self.cfg.workers];
        // Pool counters are cumulative per worker lane, so keep each
        // worker's latest snapshot and sum them once at the end.
        let mut worker_pools = vec![PoolStats::default(); self.cfg.workers];
        for msg in res_rx {
            let m = match msg {
                Ok(m) => m,
                Err(e) => {
                    // cancel: drain the queue so workers exit after their
                    // in-flight batch, then wait for them (bounded)
                    batcher.clear();
                    for h in std::mem::take(&mut handles) {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            };
            for res in m.results {
                metrics
                    .request_latency
                    .record_us(res.latency.as_micros() as f64);
                metrics.steps_done += res.steps;
                metrics.requests_done += 1;
                metrics.per_worker_requests[m.worker] += 1;
                results.push(res);
            }
            for us in m.step_us {
                metrics.step_latency.record_us(us);
            }
            if m.host_prep_us > 0.0 {
                metrics.host_prep.record_us(m.host_prep_us);
            }
            metrics.dispatches += m.dispatches;
            metrics.batch_items += m.batch_items;
            if m.stalled {
                metrics.pipeline_stalls += 1;
            }
            worker_pools[m.worker] = m.pool;
        }
        for h in handles {
            let _ = h.join();
        }
        let mut pool_total = PoolStats::default();
        for s in &worker_pools {
            pool_total.absorb(s);
        }
        metrics.pool_hits = pool_total.hits;
        metrics.pool_misses = pool_total.misses;
        metrics.pool_bytes_leased = pool_total.bytes_leased;
        metrics.wall = t0.elapsed();

        // Co-simulation: the SF-MMCN accelerator's counts for the same
        // work — one U-net pass per executed step. Batched traffic goes
        // through the cycle-accurate flat micro simulator (ISSUE 3: it is
        // cheap since the §Perf rewrite, and its fixed-point numerics and
        // event counts are real); the per-request path keeps the fast
        // analytic model.
        if self.cfg.cosim {
            let acfg = AcceleratorConfig::default();
            let g = unet(UnetConfig::default());
            let mut totals = EventCounts {
                total_pes: acfg.total_pes(),
                ..Default::default()
            };
            if self.cfg.batched {
                let ws = WeightStore::random(&g, self.cfg.seed);
                let mut rng = Rng::new(self.cfg.seed ^ 0xc0_51);
                let x = Tensor::from_fn(&[g.input.c, g.input.h, g.input.w], |_| {
                    rng.normal() * 0.5
                });
                let emb: Vec<f32> = (0..self.time_dim).map(|_| rng.normal() * 0.5).collect();
                let mut acc = Accelerator::new(acfg);
                let run = acc.run_graph(&g, &x, &ws, Some(&emb))?;
                for _ in 0..metrics.steps_done {
                    totals.merge_run(&run.totals);
                }
            } else {
                let a = crate::compiler::analyze_graph(&acfg, &g, 0.0);
                for _ in 0..metrics.steps_done {
                    totals.merge_run(&a.totals);
                }
            }
            metrics.sim_counts = Some(totals);
        }
        Ok((results, metrics))
    }

    /// Generate a deterministic workload of `n` requests.
    pub fn workload(&self, n: usize) -> Vec<DenoiseRequest> {
        (0..n)
            .map(|i| DenoiseRequest {
                id: i as u64,
                seed: self.cfg.seed.wrapping_add(i as u64 * 7919),
                steps: self.cfg.steps,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, steps: usize) -> DenoiseRequest {
        DenoiseRequest {
            id,
            seed: id,
            steps,
        }
    }

    #[test]
    fn batcher_fair_division_prevents_starvation() {
        // 8 pending, 2 workers, max_batch 8: the first grab may take at
        // most ceil(8/2) = 4 — the greedy drain that let one worker
        // swallow everything is gone.
        let b = Batcher::new((0..8).map(|i| req(i, 3)).collect(), 2, 8);
        let sizes: Vec<usize> = std::iter::from_fn(|| b.next_batch().map(|v| v.len())).collect();
        assert_eq!(sizes, vec![4, 2, 1, 1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn batcher_respects_max_batch() {
        let b = Batcher::new((0..12).map(|i| req(i, 3)).collect(), 1, 4);
        let sizes: Vec<usize> = std::iter::from_fn(|| b.next_batch().map(|v| v.len())).collect();
        assert_eq!(sizes, vec![4, 4, 4]);
    }

    #[test]
    fn batcher_groups_by_step_count() {
        // mixed steps: a batch never mixes step counts, so the batched
        // dispatch can honor per-request steps.
        let reqs = vec![req(0, 5), req(1, 5), req(2, 3), req(3, 3)];
        let b = Batcher::new(reqs, 1, 8);
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 2);
        assert!(first.iter().all(|r| r.steps == 5));
        let second = b.next_batch().unwrap();
        assert_eq!(second.len(), 2);
        assert!(second.iter().all(|r| r.steps == 3));
    }

    #[test]
    fn prepared_batch_layout_and_noise_order() {
        let schedule = DdpmSchedule::standard(4);
        let reqs = vec![req(0, 4), req(1, 4)];
        let pool = BufferPool::disabled();
        let pb = prepare_host_batch(reqs, &schedule, &[1, 2, 2], 8, &pool).unwrap();
        assert_eq!(pb.x0.shape, vec![2, 1, 2, 2]);
        assert_eq!(pb.t_embs.shape, vec![4, 8]);
        assert_eq!(pb.coeffs.shape, vec![4, 3]);
        assert_eq!(pb.noises.shape, vec![2, 4, 1, 2, 2]);
        // the t = 0 row (last chunk row) injects no noise
        let n = 4;
        for i in 0..2 {
            let last = &pb.noises.data[(i * 4 + 3) * n..(i * 4 + 4) * n];
            assert!(last.iter().all(|&v| v == 0.0), "sigma row at t=0 must be zero");
        }
        // draw order matches denoise_one: x first, then per-step noise
        let mut rng = Rng::new(0);
        let x_expect = rng.normal_vec(n);
        assert_eq!(&pb.x0.data[..n], &x_expect[..]);
        let first_noise = rng.normal_vec(n);
        assert_eq!(&pb.noises.data[..n], &first_noise[..]);
    }

    #[test]
    fn noise_chunk_gather() {
        let schedule = DdpmSchedule::standard(3);
        let pool = BufferPool::disabled();
        let pb =
            prepare_host_batch(vec![req(0, 3), req(1, 3)], &schedule, &[1, 2, 2], 4, &pool)
                .unwrap();
        let mut chunk = vec![0.0f32; 2 * 2 * 4];
        copy_noise_chunk_into(&pb.noises, 2, 3, 1, 2, &mut chunk).unwrap();
        // row 1 of request 0 lands at the front of the chunk
        assert_eq!(chunk[..4], pb.noises.data[4..8]);
        // row 1 of request 1 follows
        assert_eq!(chunk[8..12], pb.noises.data[16..20]);
        // out-of-range chunks and wrong-sized slabs rejected
        assert!(copy_noise_chunk_into(&pb.noises, 2, 3, 2, 2, &mut chunk).is_err());
        let mut short = vec![0.0f32; 3];
        assert!(copy_noise_chunk_into(&pb.noises, 2, 3, 1, 2, &mut short).is_err());
    }

    #[test]
    fn prepare_rejects_bad_step_counts() {
        let schedule = DdpmSchedule::standard(4);
        let pool = BufferPool::disabled();
        assert!(prepare_host_batch(vec![req(0, 0)], &schedule, &[1, 2, 2], 4, &pool).is_err());
        assert!(prepare_host_batch(vec![req(0, 9)], &schedule, &[1, 2, 2], 4, &pool).is_err());
    }

    #[test]
    fn prepared_batch_identical_on_recycled_slabs() {
        // The pooled prepare must produce the same bits whether its slabs
        // are freshly allocated or recycled: the noise slab's zeroed
        // lease keeps the t = 0 rows correct, and the dirty-leased slabs
        // (x0/t_embs/coeffs) are fully overwritten — this test is the
        // guard that they really are.
        let schedule = DdpmSchedule::standard(4);
        let mk = |pool: &BufferPool| {
            prepare_host_batch(
                vec![req(0, 4), req(1, 4)],
                &schedule,
                &[1, 2, 2],
                8,
                pool,
            )
            .unwrap()
        };
        let cold = mk(&BufferPool::disabled());
        let pool = BufferPool::new();
        let warm = mk(&pool);
        // return every slab dirty, then prepare again from the free list
        pool.reclaim(warm.x0);
        pool.reclaim(warm.t_embs);
        pool.reclaim(warm.coeffs);
        pool.reclaim(warm.noises);
        let recycled = mk(&pool);
        assert!(pool.stats().hits >= 1, "second prepare must reuse slabs");
        assert_eq!(recycled.x0, cold.x0);
        assert_eq!(recycled.t_embs, cold.t_embs);
        assert_eq!(recycled.coeffs, cold.coeffs);
        assert_eq!(recycled.noises, cold.noises);
    }
}
