//! The diffusion serving loop: request queue → batcher → worker lanes.
//!
//! Each worker thread owns its *own* PJRT executor (the `xla` handles are
//! not shared across threads) and compiles the denoise artifact once at
//! startup; the request path afterwards is pure rust + PJRT — python never
//! runs. Batch size per execution is 1, as on the chip (§III.D); the
//! batcher amortizes queue overhead by handing workers runs of requests.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::ddpm::{time_embedding, DdpmSchedule};
use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::params::UnetParams;
use crate::models::{unet, UnetConfig};
use crate::runtime::{ArtifactStore, Executor, TensorBuf};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::EventCounts;
use crate::util::Rng;

/// One de-noising request (generate an image from noise).
#[derive(Debug, Clone)]
pub struct DenoiseRequest {
    pub id: u64,
    pub seed: u64,
    /// Reverse steps (defaults to the server's schedule length).
    pub steps: usize,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct DenoiseResult {
    pub id: u64,
    pub image: TensorBuf,
    pub latency: Duration,
    pub steps: usize,
}

/// Serving coordinator.
pub struct DiffusionServer {
    cfg: ServeConfig,
    artifact_path: PathBuf,
    params: Arc<UnetParams>,
    schedule: Arc<DdpmSchedule>,
    img_shape: Vec<usize>,
    time_dim: usize,
}

impl DiffusionServer {
    /// Build a server for the given config; resolves the artifact and
    /// loads the weight blob (but defers PJRT setup to the workers).
    pub fn new(mut cfg: ServeConfig, store: &ArtifactStore) -> Result<Self> {
        if cfg.fused {
            // the fused artifact bakes T into its name and signature
            cfg.artifact = format!("unet_denoise_scan{}_16", cfg.steps);
        }
        let spec = store.resolve(&cfg.artifact)?;
        let params = UnetParams::load(store.root(), "unet_params")
            .context("loading unet params blob")?;
        let ucfg = UnetConfig::default();
        let schedule = DdpmSchedule::standard(cfg.steps);
        Ok(Self {
            cfg,
            artifact_path: spec.path,
            params: Arc::new(params),
            schedule: Arc::new(schedule),
            img_shape: vec![ucfg.img_channels, ucfg.img, ucfg.img],
            time_dim: ucfg.time_dim,
        })
    }

    /// Fused path (§Perf, L2): the whole reverse process in one PJRT
    /// dispatch. Noise draws follow the same order as the step-at-a-time
    /// loop (initial x, then one map per step t = T-1..1; none at t = 0),
    /// so the two modes generate the same images up to XLA re-association.
    #[allow(clippy::too_many_arguments)]
    fn denoise_one_fused(
        exe: &Executor,
        artifact: &str,
        prepared: &crate::runtime::PreparedInputs,
        schedule: &DdpmSchedule,
        img_shape: &[usize],
        time_dim: usize,
        req: &DenoiseRequest,
        step_latency_us: &mut Vec<f64>,
    ) -> Result<DenoiseResult> {
        let t0 = Instant::now();
        let mut rng = Rng::new(req.seed);
        let n: usize = img_shape.iter().product();
        let steps = schedule.t_max();
        let x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
        let mut t_embs = Vec::with_capacity(steps * time_dim);
        let mut coeffs = Vec::with_capacity(steps * 3);
        let mut noises = Vec::with_capacity(steps * n);
        for t in (0..steps).rev() {
            t_embs.extend(time_embedding(t as f32, time_dim));
            let (c1, c2, sigma) = schedule.coefficients(t);
            coeffs.extend([c1, c2, sigma]);
            if t > 0 {
                noises.extend(rng.normal_vec(n));
            } else {
                noises.extend(std::iter::repeat_n(0.0f32, n));
            }
        }
        let mut full_shape = vec![steps];
        full_shape.extend_from_slice(img_shape);
        let dynamic = vec![
            x,
            TensorBuf::new(vec![steps, time_dim], t_embs)?,
            TensorBuf::new(vec![steps, 3], coeffs)?,
            TensorBuf::new(full_shape, noises)?,
        ];
        let out = exe.run_prepared(artifact, &dynamic, prepared)?;
        let image = out.into_iter().next().context("scan returned nothing")?;
        let total = t0.elapsed();
        step_latency_us.push(total.as_micros() as f64 / steps as f64);
        Ok(DenoiseResult {
            id: req.id,
            image,
            latency: total,
            steps,
        })
    }

    /// Run one de-noise request on a prepared executor.
    ///
    /// §Perf: the 33 weight tensors (~530 KB) are pre-converted once per
    /// worker ([`Executor::prepare`]); each step only converts the six
    /// small per-step tensors (~1.3 KB).
    #[allow(clippy::too_many_arguments)]
    fn denoise_one(
        exe: &Executor,
        artifact: &str,
        prepared: &crate::runtime::PreparedInputs,
        schedule: &DdpmSchedule,
        img_shape: &[usize],
        time_dim: usize,
        req: &DenoiseRequest,
        step_latency_us: &mut Vec<f64>,
    ) -> Result<DenoiseResult> {
        let t0 = Instant::now();
        let mut rng = Rng::new(req.seed);
        let n: usize = img_shape.iter().product();
        let mut x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
        let steps = req.steps.min(schedule.t_max());
        let mut dynamic: Vec<TensorBuf> = vec![
            x.clone(),
            TensorBuf::zeros(&[time_dim]),
            TensorBuf::scalar(0.0),
            TensorBuf::scalar(0.0),
            TensorBuf::scalar(0.0),
            TensorBuf::zeros(img_shape),
        ];
        for t in (0..steps).rev() {
            let s0 = Instant::now();
            let (c1, c2, sigma) = schedule.coefficients(t);
            dynamic[0] = x;
            dynamic[1] = TensorBuf::new(vec![time_dim], time_embedding(t as f32, time_dim))?;
            dynamic[2] = TensorBuf::scalar(c1);
            dynamic[3] = TensorBuf::scalar(c2);
            dynamic[4] = TensorBuf::scalar(sigma);
            dynamic[5] = if t > 0 {
                TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?
            } else {
                TensorBuf::zeros(img_shape)
            };
            let out = exe.run_prepared(artifact, &dynamic, prepared)?;
            x = out.into_iter().next().context("artifact returned nothing")?;
            step_latency_us.push(s0.elapsed().as_micros() as f64);
        }
        Ok(DenoiseResult {
            id: req.id,
            image: x,
            latency: t0.elapsed(),
            steps,
        })
    }

    /// Serve a batch of requests across `cfg.workers` threads; returns the
    /// results (in completion order) and aggregated metrics.
    pub fn serve(&self, requests: Vec<DenoiseRequest>) -> Result<(Vec<DenoiseResult>, ServeMetrics)> {
        let t0 = Instant::now();
        let (req_tx, req_rx): (Sender<DenoiseRequest>, Receiver<DenoiseRequest>) = channel();
        let req_rx = Arc::new(Mutex::new(req_rx));
        let (res_tx, res_rx) = channel::<Result<(DenoiseResult, Vec<f64>)>>();

        let n_requests = requests.len();
        for r in requests {
            req_tx.send(r).expect("queue open");
        }
        drop(req_tx);

        let mut handles = Vec::new();
        for w in 0..self.cfg.workers {
            let req_rx = Arc::clone(&req_rx);
            let res_tx = res_tx.clone();
            let params = Arc::clone(&self.params);
            let schedule = Arc::clone(&self.schedule);
            let artifact_path = self.artifact_path.clone();
            let artifact = self.cfg.artifact.clone();
            let img_shape = self.img_shape.clone();
            let time_dim = self.time_dim;
            let max_batch = self.cfg.max_batch;
            let fused = self.cfg.fused;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sfmmcn-serve-{w}"))
                    .spawn(move || {
                        // Each worker owns a PJRT client + compiled artifact.
                        let mut exe = match Executor::new() {
                            Ok(e) => e,
                            Err(e) => {
                                let _ = res_tx.send(Err(e));
                                return;
                            }
                        };
                        if let Err(e) = exe.load_hlo_text(&artifact, &artifact_path) {
                            let _ = res_tx.send(Err(e));
                            return;
                        }
                        // pre-convert the weights once per worker (§Perf)
                        let prepared = match exe.prepare(&params.tensors) {
                            Ok(p) => p,
                            Err(e) => {
                                let _ = res_tx.send(Err(e));
                                return;
                            }
                        };
                        loop {
                            // batcher: take up to max_batch requests at once
                            let batch: Vec<DenoiseRequest> = {
                                let rx = req_rx.lock().unwrap();
                                let mut b = Vec::new();
                                while b.len() < max_batch {
                                    match rx.try_recv() {
                                        Ok(r) => b.push(r),
                                        Err(_) => break,
                                    }
                                }
                                if b.is_empty() {
                                    // queue empty: one blocking attempt
                                    match rx.recv() {
                                        Ok(r) => b.push(r),
                                        Err(_) => return, // closed: done
                                    }
                                }
                                b
                            };
                            for req in batch {
                                let mut steps_us = Vec::new();
                                let r = if fused {
                                    Self::denoise_one_fused(
                                        &exe,
                                        &artifact,
                                        &prepared,
                                        &schedule,
                                        &img_shape,
                                        time_dim,
                                        &req,
                                        &mut steps_us,
                                    )
                                } else {
                                    Self::denoise_one(
                                        &exe,
                                        &artifact,
                                        &prepared,
                                        &schedule,
                                        &img_shape,
                                        time_dim,
                                        &req,
                                        &mut steps_us,
                                    )
                                };
                                let _ = res_tx.send(r.map(|res| (res, steps_us)));
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        drop(res_tx);

        let mut results = Vec::with_capacity(n_requests);
        let mut metrics = ServeMetrics::new();
        for msg in res_rx {
            let (res, steps_us) = msg?;
            metrics
                .request_latency
                .record_us(res.latency.as_micros() as f64);
            for us in steps_us {
                metrics.step_latency.record_us(us);
            }
            metrics.steps_done += res.steps;
            metrics.requests_done += 1;
            results.push(res);
        }
        for h in handles {
            let _ = h.join();
        }
        metrics.wall = t0.elapsed();

        // Co-simulation: the SF-MMCN accelerator's counts for the same
        // work — one analytic U-net pass per executed step.
        if self.cfg.cosim {
            let g = unet(UnetConfig::default());
            let a = crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
            let mut totals = EventCounts {
                total_pes: AcceleratorConfig::default().total_pes(),
                ..Default::default()
            };
            for _ in 0..metrics.steps_done {
                totals.merge_run(&a.totals);
            }
            metrics.sim_counts = Some(totals);
        }
        Ok((results, metrics))
    }

    /// Generate a deterministic workload of `n` requests.
    pub fn workload(&self, n: usize) -> Vec<DenoiseRequest> {
        (0..n)
            .map(|i| DenoiseRequest {
                id: i as u64,
                seed: self.cfg.seed.wrapping_add(i as u64 * 7919),
                steps: self.cfg.steps,
            })
            .collect()
    }
}
