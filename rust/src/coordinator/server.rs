//! The diffusion serving loop: bounded admission queue → fair batcher →
//! worker lanes, each lane a two-stage pipeline (host prep ∥ device
//! execute), behind a long-running session API.
//!
//! Redesigned for ISSUE 5 from a one-shot drain into a streaming server:
//!
//! * **Session API** ([`DiffusionServer::start`] → [`ServerHandle`]): the
//!   handle owns the worker lanes for as long as the session lives.
//!   [`ServerHandle::submit`] blocks for queue space, `try_submit`
//!   returns [`AdmissionError::QueueFull`] instead — callers choose
//!   backpressure or load shedding. Every admitted request yields a
//!   [`Ticket`] whose `wait()`/`try_wait()` delivers that request's
//!   [`DenoiseResult`]. This is the software analogue of the paper's
//!   Server Flow: a small fixed resource set (the lanes) continuously
//!   fed by streaming work, instead of a pre-staged burst (§III).
//! * **Bounded admission** (`AdmissionQueue`): at most
//!   `serve.queue_depth` requests wait at once, split across
//!   `serve.priorities` FIFO lanes (priority 0 drains first). Overload
//!   is rejected at the door — latency stays bounded and memory flat.
//! * **Deadlines**: a request may carry a relative deadline (or inherit
//!   `serve.default_deadline_ms`). A deadline that already passed is
//!   rejected at admission; one that passes while queued resolves the
//!   ticket with an "expired" error at batch-formation time rather than
//!   occupying a lane. In-flight work is never aborted — a dispatched
//!   timestep chunk runs to completion (see EXPERIMENTS.md §Streaming
//!   for how deadlines interact with chunking).
//! * **Graceful drain** ([`ServerHandle::shutdown`]): admission closes
//!   (further submits see [`AdmissionError::ShuttingDown`]), the lanes
//!   drain everything already admitted — every ticket resolves — and the
//!   threads join. [`ServerHandle::metrics_snapshot`] reads live
//!   counters (queue depth, admitted/rejected/expired, fixed-memory
//!   latency percentiles) at any point without disturbing the lanes.
//!
//! The PR 2/PR 4 engine is unchanged behind the handle: the fair shared
//! batcher (one grab ≤ `ceil(pending / workers)`, batches group equal
//! step counts), batched `[B, ...]` fused dispatch per timestep chunk,
//! the double-buffered host stage, and the pooled zero-allocation hot
//! path all run exactly as before — [`DiffusionServer::serve`] is now a
//! thin submit-all-then-wait wrapper over the session API and stays
//! bit-identical to the historical drain.
//!
//! Workers own their executor (PJRT clients are not shared across
//! threads) and compile/register the denoise artifact once at startup.
//! On the `Native` backend the same loop runs entirely offline against
//! the host-CPU surrogate and synthetic parameters, which is what tier-1
//! and the serve benchmarks exercise.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelChoice, ModelMix, ServeBackend, ServeConfig};
use crate::coordinator::faults::{FaultAction, FaultPlane};
use crate::coordinator::ddpm::{time_embedding, time_embedding_into, DdpmSchedule};
use crate::coordinator::metrics::{AdmissionStats, ServeMetrics};
use crate::coordinator::params::UnetParams;
use crate::models::{resnet18, unet, vgg16, UnetConfig};
use crate::runtime::{
    ArtifactStore, BatchDispatch, BufferPool, Executor, NativeClassify, NativeDenoise,
    PoolStats, PreparedInputs, TensorBuf,
};
use crate::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use crate::sim::energy::EventCounts;
use crate::util::{Rng, Tensor};

/// One de-noising request (generate an image from noise).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DenoiseRequest {
    /// Caller-chosen request id, echoed in the result.
    pub id: u64,
    /// Seeds the starting noise — what makes retry / failover / trace
    /// replay re-execution bit-identical.
    pub seed: u64,
    /// Reverse steps (defaults to the server's schedule length).
    pub steps: usize,
    /// Admission priority: 0 is the most urgent; values clamp to the
    /// session's `serve.priorities - 1`. Within a priority level the
    /// queue is FIFO.
    pub priority: u8,
    /// Relative completion budget, measured from submission. `None`
    /// inherits `serve.default_deadline_ms` (which may itself be "no
    /// deadline"). An expired deadline rejects at admission or, once
    /// queued, resolves the ticket with an error instead of running.
    pub deadline: Option<Duration>,
}

impl DenoiseRequest {
    /// Request with default admission attributes (most-urgent priority,
    /// no explicit deadline).
    pub fn new(id: u64, seed: u64, steps: usize) -> Self {
        Self {
            id,
            seed,
            steps,
            priority: 0,
            deadline: None,
        }
    }
}

/// One classification request (ISSUE 7): run one seeded synthetic image
/// through a provisioned classifier (ResNet-18 / VGG-16), yielding a
/// `[classes]` logits vector in the result's `image`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClassifyRequest {
    /// Caller-chosen request id, echoed in the result.
    pub id: u64,
    /// Seeds the deterministic input image — the classification analogue
    /// of the denoise request's starting noise, and what makes retry /
    /// failover re-execution bit-identical.
    pub seed: u64,
    /// Which classifier serves this request. [`ModelChoice::Unet`] is not
    /// a classifier; such a request fails at batch preparation.
    pub model: ModelChoice,
    /// Admission priority, same semantics as [`DenoiseRequest::priority`].
    pub priority: u8,
    /// Relative completion budget, same semantics as
    /// [`DenoiseRequest::deadline`].
    pub deadline: Option<Duration>,
}

impl ClassifyRequest {
    /// Request with default admission attributes (most-urgent priority,
    /// no explicit deadline).
    pub fn new(id: u64, seed: u64, model: ModelChoice) -> Self {
        Self {
            id,
            seed,
            model,
            priority: 0,
            deadline: None,
        }
    }
}

/// A request for any of the session's serveable models (ISSUE 7): the
/// admission queue, batcher, lanes, and fleet all speak this type.
/// Single-model call sites stay source-compatible through the `From`
/// impls — `submit(DenoiseRequest::new(..))` still compiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferenceRequest {
    /// A U-net de-noising request.
    Denoise(DenoiseRequest),
    /// A ResNet-18 / VGG-16 classification request.
    Classify(ClassifyRequest),
}

impl InferenceRequest {
    /// The caller-chosen request id (either mode).
    pub fn id(&self) -> u64 {
        match self {
            InferenceRequest::Denoise(r) => r.id,
            InferenceRequest::Classify(r) => r.id,
        }
    }

    /// The seed deriving this request's deterministic input.
    pub fn seed(&self) -> u64 {
        match self {
            InferenceRequest::Denoise(r) => r.seed,
            InferenceRequest::Classify(r) => r.seed,
        }
    }

    /// The model this request runs on (denoise is always the U-net).
    pub fn model(&self) -> ModelChoice {
        match self {
            InferenceRequest::Denoise(_) => ModelChoice::Unet,
            InferenceRequest::Classify(r) => r.model,
        }
    }

    /// Device step count (classification is a single forward pass).
    pub fn steps(&self) -> usize {
        match self {
            InferenceRequest::Denoise(r) => r.steps,
            InferenceRequest::Classify(_) => 1,
        }
    }

    /// The admission priority lane (0 = highest).
    pub fn priority(&self) -> u8 {
        match self {
            InferenceRequest::Denoise(r) => r.priority,
            InferenceRequest::Classify(r) => r.priority,
        }
    }

    /// The relative completion budget, if any.
    pub fn deadline(&self) -> Option<Duration> {
        match self {
            InferenceRequest::Denoise(r) => r.deadline,
            InferenceRequest::Classify(r) => r.deadline,
        }
    }

    /// Set the admission priority lane (0 = highest) on either mode.
    pub fn set_priority(&mut self, priority: u8) {
        match self {
            InferenceRequest::Denoise(r) => r.priority = priority,
            InferenceRequest::Classify(r) => r.priority = priority,
        }
    }

    /// Set the admission-to-dispatch deadline on either mode.
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        match self {
            InferenceRequest::Denoise(r) => r.deadline = deadline,
            InferenceRequest::Classify(r) => r.deadline = deadline,
        }
    }

    /// Batch compatibility key: a batch only groups requests with the
    /// same model, step count, and served-image shape, so one device
    /// dispatch serves them all. The shape component (ISSUE 9) is
    /// derived from the model — every model currently serves one
    /// canonical shape — but keying on it makes the batcher invariant
    /// explicit: a batch's rows must stack into one `[B, c, h, w]` slab.
    fn batch_key(&self) -> (ModelChoice, usize, (usize, usize, usize)) {
        (self.model(), self.steps(), img_shape_hint(self.model()))
    }
}

/// Canonical served `[c, h, w]` shape for a model's requests: the U-net
/// serves the diffusion image shape, the classifiers serve RGB
/// `CLASSIFY_IMG`² inputs (see [`ClassifyModel`]). This is the batch
/// key's shape component (ISSUE 9).
fn img_shape_hint(model: ModelChoice) -> (usize, usize, usize) {
    match model {
        ModelChoice::Unet => {
            let u = UnetConfig::default();
            (u.img_channels, u.img, u.img)
        }
        ModelChoice::Resnet18 | ModelChoice::Vgg16 => (3, CLASSIFY_IMG, CLASSIFY_IMG),
    }
}

impl From<DenoiseRequest> for InferenceRequest {
    fn from(r: DenoiseRequest) -> Self {
        InferenceRequest::Denoise(r)
    }
}

impl From<ClassifyRequest> for InferenceRequest {
    fn from(r: ClassifyRequest) -> Self {
        InferenceRequest::Classify(r)
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct DenoiseResult {
    /// The request id this result answers.
    pub id: u64,
    /// Denoise: the generated `[c, h, w]` image. Classification: the
    /// `[classes]` logits vector.
    pub image: TensorBuf,
    /// Service latency (batch wall time for batched execution); queue
    /// wait is reported separately via the session's e2e percentiles.
    pub latency: Duration,
    /// Denoise steps executed (1 for classification).
    pub steps: usize,
    /// Which model served this request.
    pub model: ModelChoice,
}

/// Why a submission was turned away at the admission queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The bounded queue is at `serve.queue_depth`; shed load or use the
    /// blocking [`ServerHandle::submit`].
    QueueFull,
    /// The request's deadline already passed (or passed while a blocking
    /// submit waited for space).
    Deadline,
    /// [`ServerHandle::shutdown`] (or `begin_shutdown`) already closed
    /// admission.
    ShuttingDown,
    /// The fleet front door found no live shard to route to (every shard
    /// dead, drained, or preempting). Fleet-only; a single session never
    /// returns this.
    NoLiveShards,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull => write!(f, "admission queue full (bounded depth)"),
            AdmissionError::Deadline => write!(f, "deadline already expired at admission"),
            AdmissionError::ShuttingDown => write!(f, "server is shutting down"),
            AdmissionError::NoLiveShards => write!(f, "no live shards available"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Heartbeat sequence published by one session's worker lanes (ISSUE 6).
/// Every lane bumps it at least once per heartbeat period while alive
/// (idle waits use a timed condvar, so an empty queue still beats) and
/// per dispatched chunk while executing. A reader that samples the
/// sequence and sees no movement across several periods may conclude the
/// shard's lanes are gone — the fleet monitor's failover trigger.
#[derive(Debug, Default)]
pub struct ShardPulse {
    seq: AtomicU64,
}

impl ShardPulse {
    /// A fresh pulse at sequence 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump the heartbeat (lane-side).
    pub fn beat(&self) {
        self.seq.fetch_add(1, Ordering::Relaxed);
    }

    /// Sample the heartbeat sequence (monitor-side).
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }
}

/// Outcome of a non-consuming [`Ticket::poll`]: distinguishes "still in
/// flight" and "resolved" from "the lane died without resolving it" —
/// the signal the fleet uses to re-admit work after a shard kill.
#[derive(Debug)]
pub enum TicketPoll {
    /// Still queued or executing.
    Pending,
    /// Resolved: the request's result or a genuine execution/expiry
    /// error (deliver it; do not retry).
    Ready(Result<DenoiseResult>),
    /// The serving lane dropped the ticket without resolving it (shard
    /// death). The request is safe to re-admit elsewhere: execution is a
    /// pure function of `(seed, steps)`, so a retry is bit-identical.
    Lost,
}

/// Claim on one admitted request's future result. Delivery is
/// single-shot: `wait()` consumes the ticket; after `try_wait()` has
/// returned `Some`, the ticket is spent.
#[derive(Debug)]
pub struct Ticket {
    id: u64,
    rx: Receiver<Result<DenoiseResult>>,
    done: bool,
}

impl Ticket {
    /// Session-unique ticket id (monotonic admission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request resolves (result, execution error, or
    /// queue expiry).
    pub fn wait(self) -> Result<DenoiseResult> {
        if self.done {
            bail!("ticket {}: already consumed by try_wait", self.id);
        }
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => bail!(
                "ticket {}: serving lane dropped without resolving it",
                self.id
            ),
        }
    }

    /// Non-blocking poll: `None` while the request is still queued or
    /// executing, `Some(result)` exactly once when it resolves.
    pub fn try_wait(&mut self) -> Option<Result<DenoiseResult>> {
        if self.done {
            return Some(Err(anyhow!(
                "ticket {}: already consumed by try_wait",
                self.id
            )));
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = true;
                Some(r)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(anyhow!(
                    "ticket {}: serving lane dropped without resolving it",
                    self.id
                )))
            }
        }
    }

    /// Non-blocking poll that keeps "lane died" distinct from a genuine
    /// error (see [`TicketPoll`]). Used by the fleet's delivery pumps to
    /// decide between forwarding a result and re-admitting the request
    /// on a surviving shard. A ticket already spent by `try_wait`/`poll`
    /// reports `Lost` (re-admission is always safe: results are
    /// deterministic and fleet delivery is single-shot).
    pub fn poll(&mut self) -> TicketPoll {
        if self.done {
            return TicketPoll::Lost;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = true;
                TicketPoll::Ready(r)
            }
            Err(TryRecvError::Empty) => TicketPoll::Pending,
            Err(TryRecvError::Disconnected) => {
                self.done = true;
                TicketPoll::Lost
            }
        }
    }
}

/// An admitted request: the queue entry the lanes execute. Carries the
/// ticket's response channel and the absolute deadline fixed at
/// admission.
#[derive(Debug)]
struct Admitted {
    req: InferenceRequest,
    ticket: u64,
    admitted_at: Instant,
    deadline: Option<Instant>,
    tx: Sender<Result<DenoiseResult>>,
}

/// Resolve a whole batch's tickets with (a copy of) one error.
fn resolve_batch_err(reqs: &[Admitted], e: &anyhow::Error) {
    for a in reqs {
        let _ = a.tx.send(Err(anyhow!("{e:#}")));
    }
}

/// Monotonic admission counters (lock-free; the queue mutex is not
/// needed to read them).
#[derive(Default)]
struct AdmissionCounters {
    offered: AtomicU64,
    admitted: AtomicU64,
    rejected_queue_full: AtomicU64,
    rejected_deadline: AtomicU64,
    rejected_shutdown: AtomicU64,
    expired: AtomicU64,
}

struct QueueState {
    /// Per-(priority, model) FIFO sub-lanes: `lanes[pri][model.index()]`.
    /// Priority 0 drains first; within a priority, the sub-lane whose
    /// front waited longest is served next (ISSUE 7) — so interleaved
    /// multi-model traffic still forms full same-model batches instead of
    /// degrading to batch-size-1, without starving any model.
    lanes: Vec<Vec<VecDeque<Admitted>>>,
    /// Total queued entries across all lanes.
    len: usize,
    /// Admission closed; lanes drain what is already queued, then exit.
    draining: bool,
    /// Hard death (injected or operational): lanes exit *without*
    /// resolving tickets — the backlog was dropped at kill time, so
    /// undelivered tickets read as disconnected ([`TicketPoll::Lost`]),
    /// which is what lets a fleet re-admit them elsewhere.
    killed: bool,
    /// Workers gated at the starting line (the legacy `serve()` preload
    /// uses this so the fair division sees the whole workload at once).
    held: bool,
    /// Worker lanes that finished setup or are still trying. When the
    /// last lane dies during setup, the queue fails every pending ticket
    /// instead of hanging them.
    alive: usize,
}

/// Shared bounded admission queue with fairness: one grab takes at most
/// `ceil(pending / workers)` requests (≤ `max_batch`, ≥ 1) from the most
/// urgent non-empty priority lane, and a batch only groups requests with
/// the same step count. The barrier holds all worker lanes at the line
/// until everyone finished setup.
///
/// Fairness is per grab, not end-to-end: with the pipelined host stage a
/// lane prefetches, so it can hold one executing batch plus one buffered
/// batch plus one being prepared. That bounded lookahead is the price of
/// overlapping host prep with device execution; `pipeline = false`
/// restores strict grab-on-demand draining.
struct AdmissionQueue {
    state: Mutex<QueueState>,
    /// Signalled on push / drain / release — wakes worker grabs.
    not_empty: Condvar,
    /// Signalled on pop / expiry / drain — wakes blocking submits.
    not_full: Condvar,
    depth: usize,
    levels: usize,
    default_deadline: Option<Duration>,
    workers: usize,
    max_batch: usize,
    start: Barrier,
    next_ticket: AtomicU64,
    counters: AdmissionCounters,
    /// Lane heartbeats (ISSUE 6): bumped by every pass through the
    /// `next_batch` wait loop, whose blocking wait is bounded by
    /// `heartbeat` so idle lanes still beat.
    pulse: Arc<ShardPulse>,
    heartbeat: Duration,
}

impl AdmissionQueue {
    #[allow(clippy::too_many_arguments)]
    fn new(
        depth: usize,
        levels: usize,
        default_deadline: Option<Duration>,
        workers: usize,
        max_batch: usize,
        held: bool,
        pulse: Arc<ShardPulse>,
        heartbeat: Duration,
    ) -> Self {
        let workers = workers.max(1);
        let levels = levels.max(1);
        Self {
            state: Mutex::new(QueueState {
                lanes: (0..levels)
                    .map(|_| ModelChoice::ALL.iter().map(|_| VecDeque::new()).collect())
                    .collect(),
                len: 0,
                draining: false,
                killed: false,
                held,
                alive: workers,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
            levels,
            default_deadline,
            workers,
            max_batch: max_batch.max(1),
            start: Barrier::new(workers),
            next_ticket: AtomicU64::new(0),
            counters: AdmissionCounters::default(),
            pulse,
            heartbeat: heartbeat.max(Duration::from_millis(1)),
        }
    }

    /// Block until every worker lane reached its starting line (called
    /// once per worker thread, before any batch is taken).
    fn ready_wait(&self) {
        self.start.wait();
    }

    /// Admit one request, blocking for queue space when `block`.
    fn admit(
        &self,
        req: impl Into<InferenceRequest>,
        block: bool,
    ) -> std::result::Result<Ticket, AdmissionError> {
        let req: InferenceRequest = req.into();
        self.counters.offered.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let rel = req.deadline().or(self.default_deadline);
        if rel.is_some_and(|d| d.is_zero()) {
            self.counters.rejected_deadline.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionError::Deadline);
        }
        let deadline = rel.and_then(|d| now.checked_add(d));
        let mut st = self.state.lock().unwrap();
        loop {
            if st.draining || st.alive == 0 {
                self.counters.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
                return Err(AdmissionError::ShuttingDown);
            }
            if st.len < self.depth {
                break;
            }
            // a full queue may be holding expired entries no worker has
            // popped yet — free those slots before shedding a live request
            if self.sweep_expired(&mut st, Instant::now()) > 0 {
                self.not_full.notify_all();
                continue;
            }
            if !block {
                self.counters
                    .rejected_queue_full
                    .fetch_add(1, Ordering::Relaxed);
                return Err(AdmissionError::QueueFull);
            }
            st = self.not_full.wait(st).unwrap();
        }
        // a blocking submit can outwait its own deadline
        if deadline.is_some_and(|d| d <= Instant::now()) {
            self.counters.rejected_deadline.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionError::Deadline);
        }
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let pri = (req.priority() as usize).min(self.levels - 1);
        let sub = req.model().index();
        st.lanes[pri][sub].push_back(Admitted {
            req,
            ticket,
            admitted_at: now,
            deadline,
            tx,
        });
        st.len += 1;
        self.counters.admitted.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(Ticket {
            id: ticket,
            rx,
            done: false,
        })
    }

    /// Stop admission and wake everyone: blocked submitters reject with
    /// `ShuttingDown`; lanes drain the remaining queue and then exit.
    fn begin_drain(&self) {
        let mut st = self.state.lock().unwrap();
        st.draining = true;
        st.held = false;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Hard death (ISSUE 6): simulate the host dying mid-flight. The
    /// queued backlog is dropped *unresolved* — each entry's response
    /// sender drops, so undelivered tickets read as
    /// [`TicketPoll::Lost`] — admission closes, and every lane exits at
    /// its next grab without touching the in-flight tickets it holds.
    /// Heartbeats stop with the lanes, which is how a fleet monitor
    /// notices. Contrast `begin_drain`, where every ticket resolves.
    fn kill_now(&self) {
        let mut st = self.state.lock().unwrap();
        st.killed = true;
        st.draining = true;
        st.held = false;
        for lane in st.lanes.iter_mut().flatten() {
            lane.clear();
        }
        st.len = 0;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn is_killed(&self) -> bool {
        self.state.lock().unwrap().killed
    }

    /// Open the gate of a held session (the `serve()` preload path).
    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.held = false;
        self.not_empty.notify_all();
    }

    /// A worker lane died during setup. When the last one goes, every
    /// queued ticket resolves with the lane's error and admission closes
    /// — nothing can execute the backlog.
    fn lane_down(&self, error: &anyhow::Error) {
        let mut st = self.state.lock().unwrap();
        st.alive = st.alive.saturating_sub(1);
        if st.alive == 0 {
            st.draining = true;
            for lane in st.lanes.iter_mut().flatten() {
                for a in lane.drain(..) {
                    let _ = a.tx.send(Err(anyhow!(
                        "request {} (ticket {}): serving lane failed during setup: {error:#}",
                        a.req.id(),
                        a.ticket
                    )));
                }
            }
            st.len = 0;
            self.not_empty.notify_all();
            self.not_full.notify_all();
        }
    }

    /// Requests waiting right now.
    fn depth_now(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Snapshot the admission counters plus the live queue depth.
    fn admission_stats(&self) -> AdmissionStats {
        AdmissionStats {
            offered: self.counters.offered.load(Ordering::Relaxed),
            admitted: self.counters.admitted.load(Ordering::Relaxed),
            rejected_queue_full: self.counters.rejected_queue_full.load(Ordering::Relaxed),
            rejected_deadline: self.counters.rejected_deadline.load(Ordering::Relaxed),
            rejected_shutdown: self.counters.rejected_shutdown.load(Ordering::Relaxed),
            expired: self.counters.expired.load(Ordering::Relaxed),
            queue_depth: self.depth_now(),
        }
    }

    /// Pop one expired entry: resolve its ticket and count it.
    fn expire(&self, a: Admitted) {
        self.counters.expired.fetch_add(1, Ordering::Relaxed);
        let _ = a.tx.send(Err(anyhow!(
            "request {} (ticket {}): deadline expired after {:.1} ms in queue",
            a.req.id(),
            a.ticket,
            a.admitted_at.elapsed().as_secs_f64() * 1e3
        )));
    }

    /// Resolve expired entries at the front of every priority lane,
    /// releasing their bounded-queue slots. Returns how many expired.
    /// (Entries buried behind a live same-lane front are caught when
    /// they surface, or by the in-group check during batch formation.)
    fn sweep_expired(&self, st: &mut QueueState, now: Instant) -> usize {
        let mut freed = 0;
        for lane in st.lanes.iter_mut().flatten() {
            while lane
                .front()
                .is_some_and(|a| a.deadline.is_some_and(|d| d <= now))
            {
                let a = lane.pop_front().unwrap();
                st.len -= 1;
                freed += 1;
                self.expire(a);
            }
        }
        freed
    }

    /// Take the next fair batch under the state lock, resolving expired
    /// entries as they surface at the front of *any* priority lane.
    /// `None` when nothing is currently runnable.
    fn take_batch(&self, st: &mut QueueState) -> Option<Vec<Admitted>> {
        let now = Instant::now();
        // Sweep every lane's front for expired entries before choosing a
        // batch: under a steady stream of urgent traffic the pop below
        // may never reach a lower-priority lane, and without this sweep
        // a stale entry there would neither resolve its ticket nor
        // release its bounded-queue slot.
        self.sweep_expired(st, now);
        let mut pri = 0;
        while pri < st.lanes.len() {
            // Among this priority's per-model sub-lanes, serve the one
            // whose front waited longest (smallest admission ticket) —
            // cross-model fairness without ever mixing models in a batch.
            let Some(sub) = st.lanes[pri]
                .iter()
                .enumerate()
                .filter_map(|(m, lane)| lane.front().map(|a| (a.ticket, m)))
                .min()
                .map(|(_, m)| m)
            else {
                pri += 1;
                continue;
            };
            let fair = st.len.div_ceil(self.workers).clamp(1, self.max_batch);
            let key0 = st.lanes[pri][sub].front().unwrap().req.batch_key();
            let mut batch = Vec::with_capacity(fair);
            while batch.len() < fair {
                match st.lanes[pri][sub].front() {
                    Some(a) if a.req.batch_key() == key0 => {
                        let a = st.lanes[pri][sub].pop_front().unwrap();
                        st.len -= 1;
                        if a.deadline.is_some_and(|d| d <= now) {
                            self.expire(a);
                        } else {
                            batch.push(a);
                        }
                    }
                    _ => break,
                }
            }
            if batch.is_empty() {
                // the whole group at the front had expired; the sub-lane
                // front changed, so retry this priority level
                continue;
            }
            return Some(batch);
        }
        None
    }

    /// Take the next fair batch, blocking while the queue is empty (or
    /// held). `None` once the session is draining and nothing is left —
    /// the lane's signal to exit. Every pass through the loop beats the
    /// session pulse, and the blocking wait is bounded by the heartbeat
    /// period, so an idle (but alive) lane still publishes heartbeats.
    fn next_batch(&self) -> Option<Vec<Admitted>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.killed {
                // hard death: abandon everything, beat nothing
                return None;
            }
            self.pulse.beat();
            if !st.held {
                let before = st.len;
                let batch = self.take_batch(&mut st);
                if st.len < before {
                    // space opened up (batch taken and/or entries expired)
                    self.not_full.notify_all();
                }
                if let Some(b) = batch {
                    return Some(b);
                }
                if st.draining && st.len == 0 {
                    return None;
                }
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(st, self.heartbeat)
                .unwrap();
            st = guard;
        }
    }
}

/// Serving-side classifier graph size: 32×32 inputs keep the synthetic
/// parameter sets and the co-sim graph runs cheap while preserving each
/// model's depth and the models' relative MAC cost.
const CLASSIFY_IMG: usize = 32;
const CLASSIFY_CLASSES: usize = 10;

/// One provisioned classification model (ISSUE 7): everything the lanes
/// need to serve ResNet-18 / VGG-16 requests. Built once per server from
/// the parsed `serve.model_mix` — only models actually named in the mix
/// are provisioned, because each synthetic parameter set costs tens of
/// megabytes; a classify request for an unprovisioned model fails with
/// an error naming the knob.
#[derive(Debug, Clone)]
struct ClassifyModel {
    model: ModelChoice,
    /// Registry name the surrogate engine answers under.
    artifact: String,
    /// `[c, h, w]` input shape.
    img_shape: Vec<usize>,
    classes: usize,
    /// Surrogate sweeps per request, derived from the graph's MAC count
    /// so a VGG-16 request costs proportionally more host work than a
    /// ResNet-18 request.
    passes: usize,
    params: Arc<UnetParams>,
}

impl ClassifyModel {
    fn build(model: ModelChoice, seed: u64) -> Result<Self> {
        let g = match model {
            ModelChoice::Resnet18 => resnet18(CLASSIFY_IMG, CLASSIFY_CLASSES),
            ModelChoice::Vgg16 => vgg16(CLASSIFY_IMG, CLASSIFY_CLASSES),
            ModelChoice::Unet => {
                bail!("the U-net serves denoise requests, not classification")
            }
        };
        let img_shape = vec![g.input.c, g.input.h, g.input.w];
        let pixels = img_shape.iter().product::<usize>().max(1) as u64;
        let passes = (g.total_macs() / pixels / 128).clamp(1, 1024) as usize;
        let classes = g
            .nodes
            .last()
            .map(|n| n.out_shape.c)
            .unwrap_or(CLASSIFY_CLASSES);
        Ok(Self {
            model,
            artifact: g.name.clone(),
            img_shape,
            classes,
            passes,
            params: Arc::new(UnetParams::synthetic_for_graph(&g, seed)),
        })
    }
}

/// Everything a worker lane needs, owned (moved into its thread).
struct WorkerCtx {
    worker: usize,
    backend: ServeBackend,
    artifact: String,
    artifact_path: Option<PathBuf>,
    params: Arc<UnetParams>,
    schedule: Arc<DdpmSchedule>,
    img_shape: Vec<usize>,
    time_dim: usize,
    fused: bool,
    batched: bool,
    pipeline: bool,
    chunk: usize,
    pooled: bool,
    /// Fused resident-x scan (ISSUE 9): execute each batch's whole
    /// timestep range in one engine call, the images staying hot in a
    /// single slab — no per-chunk noise re-gather or slab ping-pong.
    /// Bit-identical to the chunked loop; falls back to it when the
    /// executor cannot scan natively (compiled PJRT artifacts).
    resident: bool,
    /// Pin each lane thread to a NUMA node round-robin at startup
    /// (ISSUE 9, best-effort — see `util::affinity`).
    pin_lanes: bool,
    /// Fault-injection plane shared by this session's lanes (ISSUE 6).
    /// `None` in production sessions: the only per-batch cost is an
    /// `Option` check.
    faults: Option<Arc<FaultPlane>>,
    /// Session heartbeat, beaten per dispatched chunk while executing
    /// (the queue's wait loop covers idle periods).
    pulse: Arc<ShardPulse>,
    /// Classification models provisioned from `serve.model_mix`
    /// (ISSUE 7); empty for unet-only sessions.
    classify: Arc<Vec<ClassifyModel>>,
}

/// Per-batch metrics report from a worker lane (results themselves go
/// straight to their tickets).
struct WorkerMsg {
    worker: usize,
    requests: usize,
    steps_done: usize,
    /// Service latency per completed request (batch wall for batched).
    service_us: Vec<f64>,
    /// Admission → resolution latency per completed request.
    e2e_us: Vec<f64>,
    step_us: Vec<f64>,
    host_prep_us: f64,
    dispatches: usize,
    batch_items: usize,
    stalled: bool,
    /// Cumulative snapshot of this worker's buffer pool at send time; the
    /// collector keeps the latest per worker and sums them on read.
    pool: PoolStats,
    /// The model this batch ran on (per-model metrics rows, ISSUE 7).
    model: ModelChoice,
    /// True if the batch mixed models or step counts — the batcher
    /// invariant says this never happens; the collector counts
    /// violations so tests can assert zero.
    cross_model: bool,
    /// True if the batch mixed served-image shapes (ISSUE 9) — the batch
    /// key's shape component makes this impossible by construction; the
    /// collector counts violations so tests can assert zero.
    cross_shape: bool,
}

/// Lane → collector events.
enum LaneEvent {
    Batch(WorkerMsg),
    /// Tickets resolved with an error by the lane (bad step counts,
    /// dispatch failures).
    Failed { count: usize, model: ModelChoice },
    /// A lane died during setup.
    LaneDown,
}

/// A batch with all host-side tensors generated (stage 1 of the lane
/// pipeline). Noise draw order per request matches the step-at-a-time
/// loop exactly — initial x, then one map per step t = T-1..1, none at
/// t = 0 — so every execution mode produces the same images.
///
/// Every tensor's backing slab is leased from the lane's [`BufferPool`];
/// [`execute_batch`] reclaims them all once the batch completes.
struct PreparedBatch {
    reqs: Vec<Admitted>,
    steps: usize,
    /// The batch's model (the batcher never mixes models). Classify
    /// batches carry seeded input images in `x0` and leave the
    /// denoise-only tensors empty.
    model: ModelChoice,
    /// `[B, c, h, w]` initial noise (denoise) or input (classify) images.
    x0: TensorBuf,
    /// `[steps, time_dim]`, rows in descending-t order.
    t_embs: TensorBuf,
    /// `[steps, 3]` = (c1, c2, sigma) rows, descending-t order.
    coeffs: TensorBuf,
    /// `[B, steps, c, h, w]` per-request per-step noise draws.
    noises: TensorBuf,
    prep_us: f64,
}

/// Prepare a batch's host tensors. On failure the admitted requests come
/// back with the error so the caller can resolve their tickets.
fn prepare_host_batch(
    reqs: Vec<Admitted>,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    classify: &[ClassifyModel],
    pool: &BufferPool,
) -> std::result::Result<PreparedBatch, (Vec<Admitted>, anyhow::Error)> {
    let t0 = Instant::now();
    let model = reqs
        .first()
        .map(|a| a.req.model())
        .unwrap_or(ModelChoice::Unet);
    if model != ModelChoice::Unet {
        return prepare_classify_batch(reqs, model, classify, pool, t0);
    }
    let steps = reqs.first().map(|a| a.req.steps()).unwrap_or(0);
    if steps == 0 || steps > schedule.t_max() {
        let e = anyhow!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            reqs.first().map(|a| a.req.id()).unwrap_or(0),
            schedule.t_max()
        );
        return Err((reqs, e));
    }
    let n: usize = img_shape.iter().product();
    let b = reqs.len();
    // Every slab takes the no-memset dirty lease: each row below is
    // written exactly once — noise rows by `normal_fill` (the exact
    // stream `normal_vec` used to draw, keeping images bit-identical),
    // and the per-request t = 0 row (no noise is injected at the final
    // step) by an explicit zero fill.
    let mut x0 = pool.lease_dirty(b * n);
    let mut noises = pool.lease_dirty(b * steps * n);
    for (i, a) in reqs.iter().enumerate() {
        debug_assert_eq!(
            a.req.batch_key(),
            (model, steps, img_shape_hint(model)),
            "batcher groups by (model, steps, shape)"
        );
        let mut rng = Rng::new(a.req.seed());
        rng.normal_fill(&mut x0[i * n..(i + 1) * n]);
        for (r, t) in (0..steps).rev().enumerate() {
            let base = (i * steps + r) * n;
            if t > 0 {
                rng.normal_fill(&mut noises[base..base + n]);
            } else {
                noises[base..base + n].fill(0.0);
            }
        }
    }
    let mut t_embs = pool.lease_dirty(steps * time_dim);
    let mut coeffs = pool.lease_dirty(steps * 3);
    for (r, t) in (0..steps).rev().enumerate() {
        time_embedding_into(t as f32, &mut t_embs[r * time_dim..(r + 1) * time_dim]);
        let (c1, c2, sigma) = schedule.coefficients(t);
        coeffs[r * 3..(r + 1) * 3].copy_from_slice(&[c1, c2, sigma]);
    }
    let mut xshape = vec![b];
    xshape.extend_from_slice(img_shape);
    let mut nshape = vec![b, steps];
    nshape.extend_from_slice(img_shape);
    let x0 = match TensorBuf::new(xshape, x0) {
        Ok(t) => t,
        Err(e) => return Err((reqs, e)),
    };
    let t_embs = match TensorBuf::new(vec![steps, time_dim], t_embs) {
        Ok(t) => t,
        Err(e) => return Err((reqs, e)),
    };
    let coeffs = match TensorBuf::new(vec![steps, 3], coeffs) {
        Ok(t) => t,
        Err(e) => return Err((reqs, e)),
    };
    let noises = match TensorBuf::new(nshape, noises) {
        Ok(t) => t,
        Err(e) => return Err((reqs, e)),
    };
    Ok(PreparedBatch {
        steps,
        model,
        x0,
        t_embs,
        coeffs,
        noises,
        reqs,
        prep_us: t0.elapsed().as_micros() as f64,
    })
}

/// Classification host prep (ISSUE 7): one `[B, c, h, w]` input slab,
/// each row drawn from its request's seed — the same "a request is a
/// pure function of its fields" contract the denoise path has, which is
/// what keeps failover re-execution and batched ≡ per-request
/// bit-identical across modes.
fn prepare_classify_batch(
    reqs: Vec<Admitted>,
    model: ModelChoice,
    classify: &[ClassifyModel],
    pool: &BufferPool,
    t0: Instant,
) -> std::result::Result<PreparedBatch, (Vec<Admitted>, anyhow::Error)> {
    let Some(cm) = classify.iter().find(|c| c.model == model) else {
        let e = anyhow!(
            "request {}: model {} is not provisioned on this session — add it to \
             serve.model_mix (--model-mix)",
            reqs.first().map(|a| a.req.id()).unwrap_or(0),
            model.name()
        );
        return Err((reqs, e));
    };
    let n: usize = cm.img_shape.iter().product();
    let b = reqs.len();
    // fully overwritten below, so the slab takes the no-memset dirty
    // lease (the same stream `classify_one` draws with `normal_vec`)
    let mut x0 = pool.lease_dirty(b * n);
    for (i, a) in reqs.iter().enumerate() {
        debug_assert_eq!(a.req.model(), model, "batcher groups by model");
        let mut rng = Rng::new(a.req.seed());
        rng.normal_fill(&mut x0[i * n..(i + 1) * n]);
    }
    let mut xshape = vec![b];
    xshape.extend_from_slice(&cm.img_shape);
    let x0 = match TensorBuf::new(xshape, x0) {
        Ok(t) => t,
        Err(e) => return Err((reqs, e)),
    };
    Ok(PreparedBatch {
        reqs,
        steps: 1,
        model,
        x0,
        t_embs: TensorBuf::zeros(&[0]),
        coeffs: TensorBuf::zeros(&[0]),
        noises: TensorBuf::zeros(&[0]),
        prep_us: t0.elapsed().as_micros() as f64,
    })
}

/// Gather one timestep chunk's noise rows `[B, len, ...]` out of the
/// whole-request `[B, steps, ...]` tensor into a caller slab sized to
/// exactly `B * len` rows.
fn copy_noise_chunk_into(
    noises: &TensorBuf,
    b: usize,
    steps: usize,
    lo: usize,
    len: usize,
    out: &mut [f32],
) -> Result<()> {
    if noises.shape.len() < 2 || noises.shape[0] != b || noises.shape[1] != steps {
        bail!(
            "noise tensor shape {:?} != [B={b}, steps={steps}, ...]",
            noises.shape
        );
    }
    if lo + len > steps {
        bail!("noise chunk {lo}..{} out of {steps} steps", lo + len);
    }
    let n: usize = noises.shape[2..].iter().product();
    if out.len() != b * len * n {
        bail!(
            "noise chunk slab holds {} elements, chunk [B={b}, {len}, ...] needs {}",
            out.len(),
            b * len * n
        );
    }
    for i in 0..b {
        let src = (i * steps + lo) * n;
        out[i * len * n..(i + 1) * len * n]
            .copy_from_slice(&noises.data[src..src + len * n]);
    }
    Ok(())
}

/// Fused path (§Perf, L2): the whole reverse process in one device
/// dispatch per request. On the native backend the scan honors the
/// request's own step count; a PJRT scan artifact bakes T into its
/// signature, so a mismatching request is rejected with a clear error
/// instead of silently running the wrong number of steps.
#[allow(clippy::too_many_arguments)]
fn denoise_one_fused(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    native: bool,
    req: &DenoiseRequest,
    step_latency_us: &mut Vec<f64>,
) -> Result<DenoiseResult> {
    let t0 = Instant::now();
    let steps = req.steps;
    if steps == 0 || steps > schedule.t_max() {
        bail!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            req.id,
            schedule.t_max()
        );
    }
    if !native && steps != schedule.t_max() {
        bail!(
            "request {}: the fused scan artifact executes exactly {} steps but the \
             request asked for {steps} — send steps = {} or use the step-mode path",
            req.id,
            schedule.t_max(),
            schedule.t_max()
        );
    }
    let mut rng = Rng::new(req.seed);
    let n: usize = img_shape.iter().product();
    let x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
    let mut t_embs = Vec::with_capacity(steps * time_dim);
    let mut coeffs = Vec::with_capacity(steps * 3);
    let mut noises = Vec::with_capacity(steps * n);
    for t in (0..steps).rev() {
        t_embs.extend(time_embedding(t as f32, time_dim));
        let (c1, c2, sigma) = schedule.coefficients(t);
        coeffs.extend([c1, c2, sigma]);
        if t > 0 {
            noises.extend(rng.normal_vec(n));
        } else {
            noises.extend(std::iter::repeat_n(0.0f32, n));
        }
    }
    let mut full_shape = vec![steps];
    full_shape.extend_from_slice(img_shape);
    let dynamic = vec![
        x,
        TensorBuf::new(vec![steps, time_dim], t_embs)?,
        TensorBuf::new(vec![steps, 3], coeffs)?,
        TensorBuf::new(full_shape, noises)?,
    ];
    let out = exe.run_prepared(artifact, &dynamic, prepared)?;
    let image = out.into_iter().next().context("scan returned nothing")?;
    let total = t0.elapsed();
    // one sample per step (the fused dispatch's wall spread over its
    // steps), so histogram counts line up with `steps_done` across modes
    let per_step = total.as_micros() as f64 / steps as f64;
    for _ in 0..steps {
        step_latency_us.push(per_step);
    }
    Ok(DenoiseResult {
        id: req.id,
        image,
        latency: total,
        steps,
        model: ModelChoice::Unet,
    })
}

/// Run one de-noise request step-at-a-time on a prepared executor.
///
/// §Perf: the 33 weight tensors (~530 KB) are pre-converted once per
/// worker ([`Executor::prepare`]); each step only converts the six
/// small per-step tensors (~1.3 KB).
///
/// Beats the shard pulse per executed step (ISSUE 6), so a long request
/// never looks like a dead lane to the fleet's heartbeat monitor.
#[allow(clippy::too_many_arguments)]
fn denoise_one(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    schedule: &DdpmSchedule,
    img_shape: &[usize],
    time_dim: usize,
    pulse: &ShardPulse,
    req: &DenoiseRequest,
    step_latency_us: &mut Vec<f64>,
) -> Result<DenoiseResult> {
    let t0 = Instant::now();
    let steps = req.steps;
    if steps == 0 || steps > schedule.t_max() {
        bail!(
            "request {}: steps {steps} out of range 1..={} (server schedule)",
            req.id,
            schedule.t_max()
        );
    }
    let mut rng = Rng::new(req.seed);
    let n: usize = img_shape.iter().product();
    let mut x = TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?;
    let mut dynamic: Vec<TensorBuf> = vec![
        x.clone(),
        TensorBuf::zeros(&[time_dim]),
        TensorBuf::scalar(0.0),
        TensorBuf::scalar(0.0),
        TensorBuf::scalar(0.0),
        TensorBuf::zeros(img_shape),
    ];
    for t in (0..steps).rev() {
        let s0 = Instant::now();
        let (c1, c2, sigma) = schedule.coefficients(t);
        dynamic[0] = x;
        dynamic[1] = TensorBuf::new(vec![time_dim], time_embedding(t as f32, time_dim))?;
        dynamic[2] = TensorBuf::scalar(c1);
        dynamic[3] = TensorBuf::scalar(c2);
        dynamic[4] = TensorBuf::scalar(sigma);
        dynamic[5] = if t > 0 {
            TensorBuf::new(img_shape.to_vec(), rng.normal_vec(n))?
        } else {
            TensorBuf::zeros(img_shape)
        };
        let out = exe.run_prepared(artifact, &dynamic, prepared)?;
        x = out.into_iter().next().context("artifact returned nothing")?;
        pulse.beat();
        step_latency_us.push(s0.elapsed().as_micros() as f64);
    }
    Ok(DenoiseResult {
        id: req.id,
        image: x,
        latency: t0.elapsed(),
        steps,
        model: ModelChoice::Unet,
    })
}

/// One timestep-chunk dispatch, in place: the updated images overwrite
/// `out`'s slab. A whole-request chunk borrows the prepared tensors
/// directly; a partial chunk gathers its rows into pool-leased scratch
/// and returns it before reporting (on the error path the scratch is
/// simply dropped — an error fails the batch's tickets).
#[allow(clippy::too_many_arguments)]
fn dispatch_chunk(
    exe: &Executor,
    artifact: &str,
    prepared: &PreparedInputs,
    pool: &BufferPool,
    b: usize,
    steps: usize,
    t_embs: &TensorBuf,
    coeffs: &TensorBuf,
    noises: &TensorBuf,
    x: &TensorBuf,
    out: &mut TensorBuf,
    lo: usize,
    len: usize,
) -> Result<()> {
    if lo == 0 && len == steps {
        let d = BatchDispatch {
            batch: b,
            steps: len,
            x,
            t_embs,
            coeffs,
            noises,
        };
        return exe.run_batched_into(artifact, &d, prepared, out);
    }
    // gather scratch is fully overwritten by the exact-length copies, so
    // it takes the no-memset dirty lease
    let time_dim = t_embs.shape[1];
    let mut te = pool.lease_tensor_dirty(&[len, time_dim]);
    t_embs.copy_rows_into(lo, len, &mut te.data)?;
    let mut co = pool.lease_tensor_dirty(&[len, 3]);
    coeffs.copy_rows_into(lo, len, &mut co.data)?;
    let mut nshape = vec![b, len];
    nshape.extend_from_slice(&noises.shape[2..]);
    let mut no = pool.lease_tensor_dirty(&nshape);
    copy_noise_chunk_into(noises, b, steps, lo, len, &mut no.data)?;
    let d = BatchDispatch {
        batch: b,
        steps: len,
        x,
        t_embs: &te,
        coeffs: &co,
        noises: &no,
    };
    let r = exe.run_batched_into(artifact, &d, prepared, out);
    pool.reclaim(te);
    pool.reclaim(co);
    pool.reclaim(no);
    r
}

/// Extract a readable message from a caught panic payload.
fn panic_payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Stage 2 of a batched lane: run one prepared batch through the device
/// in timestep chunks — in place against two rotating pool-leased image
/// slabs — resolve every ticket, and report metrics. All leased slabs
/// (the prepared batch's and the rotating pair) go back to the pool on
/// completion.
///
/// The dispatch loop runs under `catch_unwind` (ISSUE 6): a panic —
/// whether injected by the fault plane (`inject_panic`) or real — fails
/// only this batch's tickets, with the panic message in the error; the
/// lane itself keeps serving. The batch's `Admitted` entries stay
/// outside the unwind region so their tickets can always be resolved.
#[allow(clippy::too_many_arguments)]
fn execute_batch(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    classify_prepared: &[(ModelChoice, PreparedInputs)],
    pool: &BufferPool,
    pb: PreparedBatch,
    stalled: bool,
    res_tx: &Sender<LaneEvent>,
    inject_panic: Option<String>,
    delay: Option<Duration>,
) {
    if pb.model != ModelChoice::Unet {
        execute_classify_batch(
            ctx,
            exe,
            classify_prepared,
            pool,
            pb,
            stalled,
            res_tx,
            inject_panic,
            delay,
        );
        return;
    }
    let t0 = Instant::now();
    let b = pb.reqs.len();
    let steps = pb.steps;
    // A PJRT scan artifact bakes its step count; reject mismatches with
    // the same clear error as the per-request fused path instead of
    // dispatching wrong-shaped literals into XLA.
    if ctx.backend == ServeBackend::Pjrt && steps != ctx.schedule.t_max() {
        let e = anyhow!(
            "request {}: the fused scan artifact executes exactly {} steps but the \
             request asked for {steps} — send steps = {} or use the native backend",
            pb.reqs[0].req.id(),
            ctx.schedule.t_max(),
            ctx.schedule.t_max()
        );
        resolve_batch_err(&pb.reqs, &e);
        let _ = res_tx.send(LaneEvent::Failed { count: b, model: ModelChoice::Unet });
        return;
    }
    let chunk = if ctx.chunk == 0 {
        steps
    } else {
        ctx.chunk.min(steps)
    };
    let PreparedBatch {
        reqs,
        x0,
        t_embs,
        coeffs,
        noises,
        prep_us,
        ..
    } = pb;
    let key0 = (ModelChoice::Unet, steps, img_shape_hint(ModelChoice::Unet));
    let cross_model = reqs.iter().any(|a| a.req.batch_key() != key0);
    let cross_shape = reqs
        .iter()
        .any(|a| img_shape_hint(a.req.model()) != key0.2);
    // Rotating image slabs, materialized lazily: each dispatch reads the
    // current images and writes a destination slab, then the old current
    // becomes the next destination — in-place ping-pong instead of a
    // fresh output allocation per chunk. The first chunk reads `x0`
    // directly, so a whole-request batch (chunk = 0, the default) leases
    // exactly one slab and a chunked batch exactly two.
    let unwound = catch_unwind(AssertUnwindSafe(
        || -> Result<(Vec<TensorBuf>, usize, usize)> {
            if let Some(msg) = &inject_panic {
                panic!("{}", msg);
            }
            let mut cur: Option<TensorBuf> = None;
            let mut spare: Option<TensorBuf> = None;
            let mut dispatches = 0usize;
            let mut batch_items = 0usize;
            let mut done = 0usize;
            // Fused resident-x scan (ISSUE 9): one engine call covers
            // every timestep, the images staying hot in a single slab —
            // no per-chunk noise re-gather, no slab ping-pong. The
            // engine beats the pulse per step (at least as often as the
            // chunked loop's per-chunk beat), and deadlines are
            // unchanged: they are only checked at batch formation, and
            // in-flight work always ran to completion. Ok(false) means
            // the executor cannot scan natively (a compiled PJRT
            // artifact answers for this name) — reclaim and fall
            // through to the chunked loop below, which is bit-identical.
            if ctx.resident {
                let mut dst = pool.lease_tensor_dirty(&x0.shape);
                let d = BatchDispatch {
                    batch: b,
                    steps,
                    x: &x0,
                    t_embs: &t_embs,
                    coeffs: &coeffs,
                    noises: &noises,
                };
                if exe.run_scan_resident(&ctx.artifact, &d, prepared, &mut dst, &|| {
                    ctx.pulse.beat()
                })? {
                    cur = Some(dst);
                    dispatches = 1;
                    batch_items = b;
                    done = steps;
                } else {
                    pool.reclaim(dst);
                }
            }
            while done < steps {
                let c = chunk.min(steps - done);
                // the dispatch fully overwrites its destination, so the
                // rotation slabs take the no-memset dirty lease
                let mut dst = spare
                    .take()
                    .unwrap_or_else(|| pool.lease_tensor_dirty(&x0.shape));
                let src = cur.as_ref().unwrap_or(&x0);
                dispatch_chunk(
                    exe,
                    &ctx.artifact,
                    prepared,
                    pool,
                    b,
                    steps,
                    &t_embs,
                    &coeffs,
                    &noises,
                    src,
                    &mut dst,
                    done,
                    c,
                )?;
                ctx.pulse.beat();
                spare = cur.replace(dst);
                dispatches += 1;
                batch_items += b;
                done += c;
            }
            // The result images escape to the caller, so they are the one
            // allocation this path keeps (sized exactly, filled by
            // unstack_into); every scratch slab goes back. `cur` is always
            // Some here: prepare guarantees steps >= 1, so at least one
            // chunk dispatched.
            let final_x = cur.ok_or_else(|| {
                anyhow!("batched dispatch loop executed no chunks for {steps} steps")
            })?;
            let n_inner: usize = x0.shape[1..].iter().product();
            // capacity-only construction: unstack_into rewrites shape and
            // data, so pre-zeroing the images would be a dead fill pass
            let mut images: Vec<TensorBuf> = (0..b)
                .map(|_| TensorBuf {
                    shape: vec![0],
                    data: Vec::with_capacity(n_inner),
                })
                .collect();
            final_x.unstack_into(&mut images)?;
            pool.reclaim(final_x);
            if let Some(s) = spare {
                pool.reclaim(s);
            }
            Ok((images, dispatches, batch_items))
        },
    ));
    let outcome = match unwound {
        Ok(r) => r,
        Err(payload) => Err(anyhow!(
            "panic in serving lane {}: {}",
            ctx.worker,
            panic_payload_msg(&payload)
        )),
    };
    let (images, dispatches, batch_items) = match outcome {
        Ok(v) => v,
        Err(e) => {
            // a failed (or panicked) batch fails exactly its own tickets;
            // the slabs it was holding simply drop (a missed recycle, not
            // a leak) and the lane keeps serving
            resolve_batch_err(&reqs, &e);
            let _ = res_tx.send(LaneEvent::Failed { count: b, model: ModelChoice::Unet });
            return;
        }
    };
    let latency = t0.elapsed();
    // per-step latency: each request experienced the batch's wall time,
    // spread over its steps — one sample per request-step, so the
    // histogram counts line up with `steps_done` across modes.
    let per_step = latency.as_micros() as f64 / steps as f64;
    let step_us = vec![per_step; steps * b];
    pool.reclaim(x0);
    pool.reclaim(t_embs);
    pool.reclaim(coeffs);
    pool.reclaim(noises);
    // fault plane: a delayed-delivery event holds the completed results
    // back before ticket resolution (a slow delivery path)
    if let Some(d) = delay {
        std::thread::sleep(d);
    }
    // resolve every ticket, measuring admission → resolution latency
    // (a dispatch that returned the wrong leading dim already failed
    // above: unstack_into rejects a row-count mismatch)
    let service_us = latency.as_micros() as f64;
    let mut e2e_us = Vec::with_capacity(b);
    for (adm, image) in reqs.iter().zip(images) {
        let res = DenoiseResult {
            id: adm.req.id(),
            image,
            latency,
            steps,
            model: ModelChoice::Unet,
        };
        e2e_us.push(adm.admitted_at.elapsed().as_micros() as f64);
        let _ = adm.tx.send(Ok(res));
    }
    let _ = res_tx.send(LaneEvent::Batch(WorkerMsg {
        worker: ctx.worker,
        requests: b,
        steps_done: steps * b,
        service_us: vec![service_us; b],
        e2e_us,
        step_us,
        host_prep_us: prep_us,
        dispatches,
        batch_items,
        stalled,
        pool: pool.stats(),
        model: ModelChoice::Unet,
        cross_model,
        cross_shape,
    }));
}

/// Classification analogue of [`execute_batch`] (ISSUE 7): one
/// `[B, c, h, w]` → `[B, classes]` dispatch through the registered
/// surrogate, every ticket resolved with its logits row. Runs under the
/// same `catch_unwind` panic isolation and fault-plane delay hook as the
/// denoise path.
#[allow(clippy::too_many_arguments)]
fn execute_classify_batch(
    ctx: &WorkerCtx,
    exe: &Executor,
    classify_prepared: &[(ModelChoice, PreparedInputs)],
    pool: &BufferPool,
    pb: PreparedBatch,
    stalled: bool,
    res_tx: &Sender<LaneEvent>,
    inject_panic: Option<String>,
    delay: Option<Duration>,
) {
    let t0 = Instant::now();
    let b = pb.reqs.len();
    let model = pb.model;
    let PreparedBatch {
        reqs, x0, prep_us, ..
    } = pb;
    let cross_model = reqs.iter().any(|a| a.req.model() != model);
    let cross_shape = reqs
        .iter()
        .any(|a| img_shape_hint(a.req.model()) != img_shape_hint(model));
    let unwound = catch_unwind(AssertUnwindSafe(|| -> Result<Vec<TensorBuf>> {
        if let Some(msg) = &inject_panic {
            panic!("{}", msg);
        }
        let (cm, prep) = classify_lookup(ctx, classify_prepared, model)?;
        let logits = exe.run_classifier(&cm.artifact, b, &x0, prep)?;
        ctx.pulse.beat();
        logits.unstack()
    }));
    let outcome = match unwound {
        Ok(r) => r,
        Err(payload) => Err(anyhow!(
            "panic in serving lane {}: {}",
            ctx.worker,
            panic_payload_msg(&payload)
        )),
    };
    let rows = match outcome {
        Ok(v) if v.len() == b => v,
        Ok(v) => {
            let e = anyhow!("classifier returned {} rows for a batch of {b}", v.len());
            resolve_batch_err(&reqs, &e);
            let _ = res_tx.send(LaneEvent::Failed { count: b, model });
            return;
        }
        Err(e) => {
            resolve_batch_err(&reqs, &e);
            let _ = res_tx.send(LaneEvent::Failed { count: b, model });
            return;
        }
    };
    let latency = t0.elapsed();
    pool.reclaim(x0);
    if let Some(d) = delay {
        std::thread::sleep(d);
    }
    let service_us = latency.as_micros() as f64;
    let mut e2e_us = Vec::with_capacity(b);
    for (adm, image) in reqs.iter().zip(rows) {
        let res = DenoiseResult {
            id: adm.req.id(),
            image,
            latency,
            steps: 1,
            model,
        };
        e2e_us.push(adm.admitted_at.elapsed().as_micros() as f64);
        let _ = adm.tx.send(Ok(res));
    }
    let _ = res_tx.send(LaneEvent::Batch(WorkerMsg {
        worker: ctx.worker,
        requests: b,
        steps_done: b,
        service_us: vec![service_us; b],
        e2e_us,
        // one forward pass per request: the batch wall, spread per item
        step_us: vec![service_us; b],
        host_prep_us: prep_us,
        dispatches: 1,
        batch_items: b,
        stalled,
        pool: pool.stats(),
        model,
        cross_model,
        cross_shape,
    }));
}

/// Find a provisioned model's descriptor + prepared parameter set.
fn classify_lookup<'a>(
    ctx: &'a WorkerCtx,
    classify_prepared: &'a [(ModelChoice, PreparedInputs)],
    model: ModelChoice,
) -> Result<(&'a ClassifyModel, &'a PreparedInputs)> {
    let cm = ctx.classify.iter().find(|c| c.model == model);
    let prep = classify_prepared
        .iter()
        .find(|(m, _)| *m == model)
        .map(|(_, p)| p);
    cm.zip(prep).ok_or_else(|| {
        anyhow!(
            "model {} is not provisioned on this session — add it to serve.model_mix \
             (--model-mix)",
            model.name()
        )
    })
}

/// Solo classification (the per-request comparison baseline): identical
/// math to the batched path at B = 1, so batched ≡ per-request holds
/// bit-for-bit for classification exactly as it does for denoise.
fn classify_one(
    ctx: &WorkerCtx,
    exe: &Executor,
    classify_prepared: &[(ModelChoice, PreparedInputs)],
    req: &ClassifyRequest,
    step_latency_us: &mut Vec<f64>,
) -> Result<DenoiseResult> {
    let t0 = Instant::now();
    let (cm, prep) = classify_lookup(ctx, classify_prepared, req.model)?;
    let n: usize = cm.img_shape.iter().product();
    let mut rng = Rng::new(req.seed);
    let mut xshape = vec![1];
    xshape.extend_from_slice(&cm.img_shape);
    let x = TensorBuf::new(xshape, rng.normal_vec(n))?;
    let out = exe.run_classifier(&cm.artifact, 1, &x, prep)?;
    let image = out
        .unstack()?
        .into_iter()
        .next()
        .context("classifier returned nothing")?;
    ctx.pulse.beat();
    let total = t0.elapsed();
    step_latency_us.push(total.as_micros() as f64);
    Ok(DenoiseResult {
        id: req.id,
        image,
        latency: total,
        steps: 1,
        model: req.model,
    })
}

/// Batched lane: host-prep stage (optionally on its own thread, double-
/// buffered through a capacity-1 channel) feeding the device stage.
fn run_batched_lane(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    classify_prepared: &[(ModelChoice, PreparedInputs)],
    queue: &Arc<AdmissionQueue>,
    res_tx: &Sender<LaneEvent>,
) {
    // One buffer pool per worker lane, shared by the host-prep stage and
    // the device stage (at most two threads contend, at batch
    // granularity). `pooled = false` swaps in the retain-nothing pool:
    // the identical code path, but every lease allocates and every
    // return frees — the per-batch-allocating baseline.
    let pool = Arc::new(if ctx.pooled {
        BufferPool::new()
    } else {
        BufferPool::disabled()
    });
    if ctx.pipeline {
        let (prep_tx, prep_rx) = sync_channel::<PreparedBatch>(1);
        let q2 = Arc::clone(queue);
        let schedule = Arc::clone(&ctx.schedule);
        let img_shape = ctx.img_shape.clone();
        let time_dim = ctx.time_dim;
        let classify = Arc::clone(&ctx.classify);
        let prep_pool = Arc::clone(&pool);
        let prep_res_tx = res_tx.clone();
        let prep = std::thread::Builder::new()
            .name(format!("sfmmcn-hostprep-{}", ctx.worker))
            .spawn(move || {
                while let Some(reqs) = q2.next_batch() {
                    match prepare_host_batch(
                        reqs, &schedule, &img_shape, time_dim, &classify, &prep_pool,
                    ) {
                        Ok(pb) => {
                            if prep_tx.send(pb).is_err() {
                                return;
                            }
                        }
                        Err((reqs, e)) => {
                            // a bad batch fails its own tickets; the lane
                            // keeps serving the stream
                            let model = reqs
                                .first()
                                .map(|a| a.req.model())
                                .unwrap_or(ModelChoice::Unet);
                            resolve_batch_err(&reqs, &e);
                            let _ = prep_res_tx
                                .send(LaneEvent::Failed { count: reqs.len(), model });
                        }
                    }
                }
            })
            .expect("spawn host-prep thread");
        // The first wait is the pipeline filling, not a stall. (On a
        // long-running session a wait can also be an empty queue — the
        // counter reads as "the device had nothing buffered".)
        let mut first = true;
        loop {
            let (pb, stalled) = match prep_rx.try_recv() {
                Ok(pb) => (pb, false),
                Err(TryRecvError::Empty) => match prep_rx.recv() {
                    Ok(pb) => (pb, !first),
                    Err(_) => break, // prep stage done: queue drained
                },
                Err(TryRecvError::Disconnected) => break,
            };
            first = false;
            // Fault plane (ISSUE 6): claim this batch's executed-request
            // window before dispatch. A kill drops the batch unresolved
            // (its tickets read as Lost) and stops the shard's lanes —
            // the software analogue of the host dying mid-flight.
            let action = lane_fault_action(ctx, pb.reqs.len());
            if action.kill {
                queue.kill_now();
                drop(pb);
                break;
            }
            if queue.is_killed() {
                // another lane's kill landed while this batch was buffered
                drop(pb);
                break;
            }
            if let Some(d) = action.stall {
                std::thread::sleep(d);
            }
            execute_batch(
                ctx,
                exe,
                prepared,
                classify_prepared,
                &pool,
                pb,
                stalled,
                res_tx,
                action.panic_msg,
                action.delay,
            );
        }
        let _ = prep.join();
    } else {
        while let Some(reqs) = queue.next_batch() {
            let action = lane_fault_action(ctx, reqs.len());
            if action.kill {
                queue.kill_now();
                drop(reqs);
                break;
            }
            if let Some(d) = action.stall {
                std::thread::sleep(d);
            }
            match prepare_host_batch(
                reqs,
                &ctx.schedule,
                &ctx.img_shape,
                ctx.time_dim,
                &ctx.classify,
                &pool,
            ) {
                Ok(pb) => execute_batch(
                    ctx,
                    exe,
                    prepared,
                    classify_prepared,
                    &pool,
                    pb,
                    false,
                    res_tx,
                    action.panic_msg,
                    action.delay,
                ),
                Err((reqs, e)) => {
                    let model = reqs
                        .first()
                        .map(|a| a.req.model())
                        .unwrap_or(ModelChoice::Unet);
                    resolve_batch_err(&reqs, &e);
                    let _ = res_tx.send(LaneEvent::Failed { count: reqs.len(), model });
                }
            }
        }
    }
}

/// Claim `n` executed requests on the session's fault plane (no-op
/// without one).
fn lane_fault_action(ctx: &WorkerCtx, n: usize) -> FaultAction {
    ctx.faults
        .as_ref()
        .map(|f| f.on_requests(n as u64))
        .unwrap_or_default()
}

/// Per-request lane (the pre-ISSUE-3 execution mode, kept as the
/// comparison baseline): requests still come through the fair batcher,
/// but each runs solo — per step, or one fused scan when `fused`.
fn run_request_lane(
    ctx: &WorkerCtx,
    exe: &Executor,
    prepared: &PreparedInputs,
    classify_prepared: &[(ModelChoice, PreparedInputs)],
    queue: &Arc<AdmissionQueue>,
    res_tx: &Sender<LaneEvent>,
) {
    'outer: while let Some(batch) = queue.next_batch() {
        for adm in batch {
            // Fault plane (ISSUE 6): one executed request per claim on
            // this path, so a panic event fails exactly one ticket.
            let action = lane_fault_action(ctx, 1);
            if action.kill {
                // the current entry and the rest of the grabbed batch
                // drop unresolved (Lost) — host death mid-flight
                queue.kill_now();
                break 'outer;
            }
            if let Some(d) = action.stall {
                std::thread::sleep(d);
            }
            let mut step_us = Vec::new();
            // Panic isolation: a panicking request (injected or real)
            // fails only its own ticket; the lane keeps serving.
            let unwound = catch_unwind(AssertUnwindSafe(|| {
                if let Some(msg) = &action.panic_msg {
                    panic!("{}", msg);
                }
                match &adm.req {
                    InferenceRequest::Classify(creq) => {
                        classify_one(ctx, exe, classify_prepared, creq, &mut step_us)
                    }
                    InferenceRequest::Denoise(dreq) if ctx.fused => denoise_one_fused(
                        exe,
                        &ctx.artifact,
                        prepared,
                        &ctx.schedule,
                        &ctx.img_shape,
                        ctx.time_dim,
                        ctx.backend == ServeBackend::Native,
                        dreq,
                        &mut step_us,
                    ),
                    InferenceRequest::Denoise(dreq) => denoise_one(
                        exe,
                        &ctx.artifact,
                        prepared,
                        &ctx.schedule,
                        &ctx.img_shape,
                        ctx.time_dim,
                        &ctx.pulse,
                        dreq,
                        &mut step_us,
                    ),
                }
            }));
            let r = match unwound {
                Ok(r) => r,
                Err(payload) => Err(anyhow!(
                    "panic in serving lane {}: {}",
                    ctx.worker,
                    panic_payload_msg(&payload)
                )),
            };
            if let Some(d) = action.delay {
                std::thread::sleep(d);
            }
            match r {
                Ok(res) => {
                    let dispatches = if ctx.fused { 1 } else { res.steps };
                    let steps_done = res.steps;
                    let model = res.model;
                    let service_us = res.latency.as_micros() as f64;
                    let e2e_us = adm.admitted_at.elapsed().as_micros() as f64;
                    let _ = adm.tx.send(Ok(res));
                    let _ = res_tx.send(LaneEvent::Batch(WorkerMsg {
                        worker: ctx.worker,
                        requests: 1,
                        steps_done,
                        service_us: vec![service_us],
                        e2e_us: vec![e2e_us],
                        step_us,
                        host_prep_us: 0.0,
                        dispatches,
                        batch_items: dispatches,
                        stalled: false,
                        // the per-request lane allocates per dispatch by
                        // design (it is the comparison baseline)
                        pool: PoolStats::default(),
                        model,
                        cross_model: false,
                        cross_shape: false,
                    }));
                }
                Err(e) => {
                    let model = adm.req.model();
                    let _ = adm.tx.send(Err(e));
                    let _ = res_tx.send(LaneEvent::Failed { count: 1, model });
                }
            }
        }
    }
}

/// Executor setup for one worker: create, compile/register the denoise
/// artifact, register every provisioned classifier (on BOTH backends —
/// no HLO lowering exists for the classifier graphs), and pre-convert
/// the parameter sets (§Perf).
fn worker_setup(
    ctx: &WorkerCtx,
) -> Result<(Executor, PreparedInputs, Vec<(ModelChoice, PreparedInputs)>)> {
    let mut exe = Executor::new()?;
    match ctx.backend {
        ServeBackend::Pjrt => {
            let path = ctx
                .artifact_path
                .as_ref()
                .expect("pjrt backend resolved an artifact path");
            exe.load_hlo_text(&ctx.artifact, path)?;
        }
        ServeBackend::Native => {
            exe.register_native(
                &ctx.artifact,
                NativeDenoise::new(ctx.img_shape.clone(), ctx.time_dim),
            );
        }
    }
    for cm in ctx.classify.iter() {
        exe.register_classifier(
            &cm.artifact,
            NativeClassify::new(cm.img_shape.clone(), cm.classes, cm.passes),
        );
    }
    let prepared = exe.prepare(&ctx.params.tensors)?;
    let mut classify_prepared = Vec::with_capacity(ctx.classify.len());
    for cm in ctx.classify.iter() {
        classify_prepared.push((cm.model, exe.prepare(&cm.params.tensors)?));
    }
    Ok((exe, prepared, classify_prepared))
}

fn worker_main(ctx: WorkerCtx, queue: Arc<AdmissionQueue>, res_tx: Sender<LaneEvent>) {
    // NUMA pinning (ISSUE 9, best-effort): pin this lane thread to a
    // node's full CPU set, lanes spread round-robin across nodes. The
    // mask is inherited by every thread the lane spawns afterwards —
    // the host-prep stage and the native engine's fanout children stay
    // on the lane's node, next to the slabs they touch. A refused mask
    // (non-Linux, sandbox) leaves the lane unpinned; bits never change.
    if ctx.pin_lanes {
        let _ = crate::util::affinity::CoreMap::detect().pin_to_node(ctx.worker);
    }
    // Setup (PJRT compilation can take seconds and varies per thread)
    // happens BEFORE the barrier; every worker then reaches the line
    // exactly once, success or not, so the barrier cannot deadlock and
    // the fair queue division starts from a simultaneous standing start.
    let setup = worker_setup(&ctx);
    queue.ready_wait();
    let (exe, prepared, classify_prepared) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = res_tx.send(LaneEvent::LaneDown);
            queue.lane_down(&e);
            return;
        }
    };
    if ctx.batched {
        run_batched_lane(&ctx, &exe, &prepared, &classify_prepared, &queue, &res_tx);
    } else {
        run_request_lane(&ctx, &exe, &prepared, &classify_prepared, &queue, &res_tx);
    }
}

/// Live metrics accumulated by the collector thread.
struct SessionLive {
    metrics: ServeMetrics,
    /// Latest cumulative pool snapshot per worker lane (summed on read).
    worker_pools: Vec<PoolStats>,
}

fn collector_main(rx: Receiver<LaneEvent>, live: Arc<Mutex<SessionLive>>) {
    for ev in rx {
        let mut l = live.lock().unwrap();
        match ev {
            LaneEvent::Batch(m) => {
                for us in m.service_us {
                    l.metrics.request_latency.record_us(us);
                }
                for &us in &m.e2e_us {
                    l.metrics.e2e_latency.record_us(us);
                }
                for us in m.step_us {
                    l.metrics.step_latency.record_us(us);
                }
                if m.host_prep_us > 0.0 {
                    l.metrics.host_prep.record_us(m.host_prep_us);
                }
                l.metrics.requests_done += m.requests;
                l.metrics.steps_done += m.steps_done;
                if let Some(c) = l.metrics.per_worker_requests.get_mut(m.worker) {
                    *c += m.requests;
                }
                l.metrics.dispatches += m.dispatches;
                l.metrics.batch_items += m.batch_items;
                if m.stalled {
                    l.metrics.pipeline_stalls += 1;
                }
                // per-model rows (ISSUE 7)
                let row = &mut l.metrics.per_model[m.model.index()];
                row.requests_done += m.requests;
                row.steps_done += m.steps_done;
                for &us in &m.e2e_us {
                    row.e2e_latency.record_us(us);
                }
                if m.cross_model {
                    l.metrics.cross_model_batches += 1;
                }
                if m.cross_shape {
                    l.metrics.cross_shape_batches += 1;
                }
                if let Some(p) = l.worker_pools.get_mut(m.worker) {
                    *p = m.pool;
                }
            }
            LaneEvent::Failed { count, model } => {
                l.metrics.requests_failed += count;
                l.metrics.per_model[model.index()].requests_failed += count;
            }
            LaneEvent::LaneDown => {
                l.metrics.lanes_down += 1;
            }
        }
    }
}

/// A running serving session: owns the worker lanes, the bounded
/// admission queue, and the metrics collector. Obtained from
/// [`DiffusionServer::start`]; ends with [`ServerHandle::shutdown`]
/// (dropping the handle also drains and joins).
pub struct ServerHandle {
    queue: Arc<AdmissionQueue>,
    live: Arc<Mutex<SessionLive>>,
    t0: Instant,
    workers: Vec<std::thread::JoinHandle<()>>,
    collector: Option<std::thread::JoinHandle<()>>,
    cfg: ServeConfig,
    time_dim: usize,
    pulse: Arc<ShardPulse>,
}

impl ServerHandle {
    /// Admit a request ([`DenoiseRequest`], [`ClassifyRequest`], or a
    /// pre-wrapped [`InferenceRequest`]), blocking while the bounded
    /// queue is full. Returns the ticket that will deliver this
    /// request's result, or why admission refused it
    /// ([`AdmissionError::QueueFull`] never occurs on this path).
    pub fn submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<Ticket, AdmissionError> {
        self.queue.admit(req, true)
    }

    /// Admit a request without blocking: a full queue returns
    /// [`AdmissionError::QueueFull`] immediately (load shedding).
    pub fn try_submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<Ticket, AdmissionError> {
        self.queue.admit(req, false)
    }

    /// Stop admission now (subsequent submits see `ShuttingDown`)
    /// without waiting for the drain. Call [`ServerHandle::shutdown`] to
    /// wait and join.
    pub fn begin_shutdown(&self) {
        self.queue.begin_drain();
    }

    /// Hard-kill the session (ISSUE 6): simulate the host dying. The
    /// queued backlog drops *unresolved* — undelivered tickets read as
    /// [`TicketPoll::Lost`] — lanes exit at their next grab without
    /// resolving in-flight work, and heartbeats stop. The operational /
    /// test analogue of the fault plane's `kill` event; contrast the
    /// graceful `begin_shutdown`, where every ticket resolves.
    pub fn kill(&self) {
        self.queue.kill_now();
    }

    /// This session's heartbeat pulse (ISSUE 6). Lanes beat it at least
    /// once per `serve.heartbeat_ms` while alive; a fleet monitor that
    /// samples a frozen sequence for `serve.heartbeat_misses` periods
    /// declares the shard dead and fails its work over.
    pub fn pulse(&self) -> Arc<ShardPulse> {
        Arc::clone(&self.pulse)
    }

    /// Requests waiting in the admission queue right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth_now()
    }

    /// Snapshot the live session counters without disturbing the lanes:
    /// queue depth, admitted/rejected/expired, throughput counters, and
    /// fixed-memory latency percentiles. `wall` is the session age, so
    /// rates read as "so far". Co-simulation totals are only attached by
    /// the final [`ServerHandle::shutdown`] metrics.
    pub fn metrics_snapshot(&self) -> ServeMetrics {
        let mut m = {
            let l = self.live.lock().unwrap();
            let mut m = l.metrics.clone();
            let mut pool_total = PoolStats::default();
            for s in &l.worker_pools {
                pool_total.absorb(s);
            }
            m.pool_hits = pool_total.hits;
            m.pool_misses = pool_total.misses;
            m.pool_bytes_leased = pool_total.bytes_leased;
            m
        };
        m.admission = self.queue.admission_stats();
        m.wall = self.t0.elapsed();
        m
    }

    /// Graceful drain: close admission, let the lanes finish everything
    /// already admitted (every outstanding ticket resolves — with a
    /// result, an execution error, or a deadline expiry), join all
    /// threads, and return the final session metrics (co-simulation
    /// included when configured).
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        self.join_lanes();
        let mut metrics = self.metrics_snapshot();

        // Co-simulation: the SF-MMCN accelerator's counts for the same
        // work, per mode (ISSUE 7) — one U-net pass per executed denoise
        // step, one classifier-graph pass per classification request.
        // Batched traffic goes through the cycle-accurate flat micro
        // simulator (ISSUE 3: it is cheap since the §Perf rewrite, and
        // its fixed-point numerics and event counts are real); the
        // per-request path keeps the fast analytic model.
        if self.cfg.cosim {
            let acfg = AcceleratorConfig::default();
            let mut totals = EventCounts {
                total_pes: acfg.total_pes(),
                ..Default::default()
            };
            let unet_steps = metrics.per_model[ModelChoice::Unet.index()].steps_done;
            if unet_steps > 0 {
                let g = unet(UnetConfig::default());
                let mut mt = EventCounts {
                    total_pes: acfg.total_pes(),
                    ..Default::default()
                };
                if self.cfg.batched {
                    let ws = WeightStore::random(&g, self.cfg.seed);
                    let mut rng = Rng::new(self.cfg.seed ^ 0xc0_51);
                    let x = Tensor::from_fn(&[g.input.c, g.input.h, g.input.w], |_| {
                        rng.normal() * 0.5
                    });
                    let emb: Vec<f32> =
                        (0..self.time_dim).map(|_| rng.normal() * 0.5).collect();
                    let mut acc = Accelerator::new(acfg);
                    let run = acc.run_graph(&g, &x, &ws, Some(&emb))?;
                    for _ in 0..unet_steps {
                        mt.merge_run(&run.totals);
                        totals.merge_run(&run.totals);
                    }
                } else {
                    let a = crate::compiler::analyze_graph(&acfg, &g, 0.0);
                    for _ in 0..unet_steps {
                        mt.merge_run(&a.totals);
                        totals.merge_run(&a.totals);
                    }
                }
                metrics.per_model[ModelChoice::Unet.index()].sim_counts = Some(mt);
            }
            for model in [ModelChoice::Resnet18, ModelChoice::Vgg16] {
                let done = metrics.per_model[model.index()].requests_done;
                if done == 0 {
                    continue;
                }
                let g = match model {
                    ModelChoice::Resnet18 => resnet18(CLASSIFY_IMG, CLASSIFY_CLASSES),
                    _ => vgg16(CLASSIFY_IMG, CLASSIFY_CLASSES),
                };
                let mut mt = EventCounts {
                    total_pes: acfg.total_pes(),
                    ..Default::default()
                };
                if self.cfg.batched {
                    let ws = WeightStore::random(&g, self.cfg.seed);
                    let mut rng = Rng::new(self.cfg.seed ^ 0xc1_a5);
                    let x = Tensor::from_fn(&[g.input.c, g.input.h, g.input.w], |_| {
                        rng.normal() * 0.5
                    });
                    let mut acc = Accelerator::new(acfg);
                    let run = acc.run_graph(&g, &x, &ws, None)?;
                    for _ in 0..done {
                        mt.merge_run(&run.totals);
                        totals.merge_run(&run.totals);
                    }
                } else {
                    let a = crate::compiler::analyze_graph(&acfg, &g, 0.0);
                    for _ in 0..done {
                        mt.merge_run(&a.totals);
                        totals.merge_run(&a.totals);
                    }
                }
                metrics.per_model[model.index()].sim_counts = Some(mt);
            }
            metrics.sim_counts = Some(totals);
        }
        Ok(metrics)
    }

    /// Open the gate of a held session (see `start_session`).
    fn release(&self) {
        self.queue.release();
    }

    fn join_lanes(&mut self) {
        self.queue.begin_drain();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
    }
}

impl Drop for ServerHandle {
    /// A dropped handle still drains gracefully: admission closes, the
    /// lanes finish every admitted request (outstanding tickets remain
    /// waitable), and the threads join. No-op after `shutdown()`.
    fn drop(&mut self) {
        self.join_lanes();
    }
}

/// Serving coordinator.
#[derive(Clone)]
pub struct DiffusionServer {
    cfg: ServeConfig,
    artifact: String,
    artifact_path: Option<PathBuf>,
    params: Arc<UnetParams>,
    schedule: Arc<DdpmSchedule>,
    img_shape: Vec<usize>,
    time_dim: usize,
    /// Classification models provisioned for this server (ISSUE 7): one
    /// entry per non-U-net model named in `serve.model_mix`. Empty for a
    /// pure-diffusion server, so the U-net-only path pays nothing.
    classify: Arc<Vec<ClassifyModel>>,
}

impl DiffusionServer {
    /// Build a server for the given config. The PJRT backend resolves the
    /// artifact and loads the weight blob (deferring PJRT setup to the
    /// workers); the native backend synthesizes deterministic parameters
    /// and needs no artifacts at all.
    pub fn new(cfg: ServeConfig, store: &ArtifactStore) -> Result<Self> {
        // degenerate configs (zero workers/depth/priorities) error here
        // instead of panicking or hanging a session later
        cfg.validate()?;
        let ucfg = UnetConfig::default();
        let schedule = DdpmSchedule::standard(cfg.steps);
        // the fused artifact bakes T into its name and signature
        let artifact = if cfg.fused && cfg.backend == ServeBackend::Pjrt {
            format!("unet_denoise_scan{}_16", cfg.steps)
        } else {
            cfg.artifact.clone()
        };
        let (artifact_path, params) = match cfg.backend {
            ServeBackend::Pjrt => {
                let spec = store.resolve(&artifact)?;
                let params = UnetParams::load(store.root(), "unet_params")
                    .context("loading unet params blob")?;
                (Some(spec.path), params)
            }
            ServeBackend::Native => (None, UnetParams::synthetic(&ucfg, cfg.seed)),
        };
        if cfg.batched && cfg.backend == ServeBackend::Pjrt {
            if !cfg.fused {
                bail!(
                    "batched serving on the PJRT backend dispatches through the fused \
                     scan artifact — enable serve.fused (--fused), or use the native backend"
                );
            }
            if cfg.chunk != 0 && cfg.chunk != cfg.steps {
                bail!(
                    "serve.chunk = {} is only supported on the native backend — a PJRT \
                     scan artifact bakes its step count, so use chunk = 0 (whole request)",
                    cfg.chunk
                );
            }
        }
        // Provision classification models lazily (ISSUE 7): synthetic
        // parameter sets are tens of MB, so only the models named in
        // serve.model_mix are built. A classify request for a model not
        // listed there errors at prepare time, naming the knob.
        let mix = cfg.parsed_model_mix()?;
        let mut classify = Vec::new();
        for m in mix.models() {
            if m == ModelChoice::Unet {
                continue;
            }
            classify.push(ClassifyModel::build(m, cfg.seed)?);
        }
        Ok(Self {
            cfg,
            artifact,
            artifact_path,
            params: Arc::new(params),
            schedule: Arc::new(schedule),
            img_shape: vec![ucfg.img_channels, ucfg.img, ucfg.img],
            time_dim: ucfg.time_dim,
            classify: Arc::new(classify),
        })
    }

    /// Start a long-running serving session: spawn the worker lanes and
    /// the metrics collector, and hand back the [`ServerHandle`] that
    /// owns them. Requests enter through `submit`/`try_submit`; the
    /// session ends with `shutdown` (graceful drain).
    pub fn start(self) -> ServerHandle {
        self.start_session(None, false, None)
    }

    /// Start a session with a fault-injection plane attached (ISSUE 6):
    /// the lanes claim executed-request windows on the plane and act out
    /// whatever it schedules (kill / stall / panic / delayed delivery).
    /// `None` behaves exactly like [`DiffusionServer::start`]. The fleet
    /// uses this to give each shard its slice of a [`crate::coordinator::
    /// faults::FaultSpec`].
    pub fn start_with_faults(self, faults: Option<Arc<FaultPlane>>) -> ServerHandle {
        self.start_session(None, false, faults)
    }

    /// Start with an optional queue-depth override and an optional held
    /// gate (workers wait to grab until `release()` — the legacy
    /// `serve()` uses this to reproduce the standing-start fair division
    /// over a preloaded workload).
    fn start_session(
        self,
        depth_override: Option<usize>,
        held: bool,
        faults: Option<Arc<FaultPlane>>,
    ) -> ServerHandle {
        let cfg = self.cfg.clone();
        let depth = depth_override.unwrap_or(cfg.queue_depth).max(1);
        let default_deadline = (cfg.default_deadline_ms > 0)
            .then(|| Duration::from_millis(cfg.default_deadline_ms));
        let pulse = Arc::new(ShardPulse::new());
        let queue = Arc::new(AdmissionQueue::new(
            depth,
            cfg.priorities,
            default_deadline,
            cfg.workers,
            cfg.max_batch,
            held,
            Arc::clone(&pulse),
            Duration::from_millis(cfg.heartbeat_ms.max(1)),
        ));
        let live = Arc::new(Mutex::new(SessionLive {
            metrics: {
                let mut m = ServeMetrics::new();
                m.per_worker_requests = vec![0; cfg.workers];
                m
            },
            worker_pools: vec![PoolStats::default(); cfg.workers],
        }));
        let (res_tx, res_rx) = channel::<LaneEvent>();
        let live2 = Arc::clone(&live);
        let collector = std::thread::Builder::new()
            .name("sfmmcn-collector".into())
            .spawn(move || collector_main(res_rx, live2))
            .expect("spawn collector");
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let ctx = WorkerCtx {
                worker: w,
                backend: cfg.backend,
                artifact: self.artifact.clone(),
                artifact_path: self.artifact_path.clone(),
                params: Arc::clone(&self.params),
                schedule: Arc::clone(&self.schedule),
                img_shape: self.img_shape.clone(),
                time_dim: self.time_dim,
                fused: cfg.fused,
                batched: cfg.batched,
                pipeline: cfg.pipeline,
                chunk: cfg.chunk,
                pooled: cfg.pooled,
                resident: cfg.resident,
                pin_lanes: cfg.pin_lanes,
                faults: faults.clone(),
                pulse: Arc::clone(&pulse),
                classify: Arc::clone(&self.classify),
            };
            let queue = Arc::clone(&queue);
            let res_tx = res_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sfmmcn-serve-{w}"))
                    .spawn(move || worker_main(ctx, queue, res_tx))
                    .expect("spawn worker"),
            );
        }
        drop(res_tx);
        ServerHandle {
            queue,
            live,
            t0: Instant::now(),
            workers,
            collector: Some(collector),
            cfg,
            time_dim: self.time_dim,
            pulse,
        }
    }

    /// Serve a batch of requests across `cfg.workers` threads; returns
    /// the results (in submission order) and aggregated metrics.
    ///
    /// This is the legacy one-shot drain, now a thin wrapper over the
    /// session API: start a held session wide enough for the whole
    /// workload, submit everything, release the lanes (so the fair
    /// division sees the full queue at a standing start, exactly like
    /// the historical batcher), wait every ticket, shut down. Outputs
    /// are bit-identical to the pre-session implementation.
    pub fn serve<R: Into<InferenceRequest>>(
        &self,
        requests: Vec<R>,
    ) -> Result<(Vec<DenoiseResult>, ServeMetrics)> {
        let n = requests.len();
        let depth = self.cfg.queue_depth.max(n).max(1);
        let handle = self.clone().start_session(Some(depth), true, None);
        let mut tickets = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        for req in requests {
            match handle.submit(req) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    first_err.get_or_insert_with(|| anyhow!(e));
                }
            }
        }
        handle.release();
        let mut results = Vec::with_capacity(tickets.len());
        for t in tickets {
            match t.wait() {
                Ok(r) => results.push(r),
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        let metrics = handle.shutdown()?;
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok((results, metrics))
    }
}

/// Generate the `[range]` slice of a deterministic workload: request `i`
/// is a pure function of `(cfg.steps, cfg.model_mix, seed, i)`, so
/// open-loop clients and shards can regenerate disjoint slices of the
/// same workload without coordination (shard k of S takes
/// `(k * n / S)..((k + 1) * n / S)`). With a non-empty `serve.model_mix`
/// the weighted pattern assigns each index its model (ISSUE 7) — an
/// unparsable mix degrades to all-U-net rather than panicking, since
/// `ServeConfig::validate` already rejects it on every serving path.
pub fn workload(
    cfg: &ServeConfig,
    seed: u64,
    range: std::ops::Range<usize>,
) -> Vec<InferenceRequest> {
    let mix = cfg
        .parsed_model_mix()
        .unwrap_or_else(|_| ModelMix::all_unet());
    range
        .map(|i| {
            let s = seed.wrapping_add((i as u64).wrapping_mul(7919));
            match mix.model_for(i as u64) {
                ModelChoice::Unet => DenoiseRequest::new(i as u64, s, cfg.steps).into(),
                m => ClassifyRequest::new(i as u64, s, m).into(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, steps: usize) -> DenoiseRequest {
        DenoiseRequest::new(id, id, steps)
    }

    /// Test queue with explicit depth/levels/held (new signature's
    /// pulse + heartbeat filled with defaults).
    fn raw_queue(
        depth: usize,
        levels: usize,
        workers: usize,
        max_batch: usize,
        held: bool,
    ) -> AdmissionQueue {
        AdmissionQueue::new(
            depth,
            levels,
            None,
            workers,
            max_batch,
            held,
            Arc::new(ShardPulse::new()),
            Duration::from_millis(25),
        )
    }

    /// Queue with no default deadline, ungated, depth 64.
    fn queue(workers: usize, max_batch: usize, levels: usize) -> AdmissionQueue {
        raw_queue(64, levels, workers, max_batch, false)
    }

    /// Admit a request through the real admission path, discarding the
    /// ticket (tests that only look at batch formation).
    fn admit(q: &AdmissionQueue, r: DenoiseRequest) {
        q.admit(r, false).expect("queue has room");
    }

    #[test]
    fn queue_fair_division_prevents_starvation() {
        // 8 pending, 2 workers, max_batch 8: the first grab may take at
        // most ceil(8/2) = 4 — the greedy drain that let one worker
        // swallow everything is gone.
        let q = queue(2, 8, 1);
        for i in 0..8 {
            admit(&q, req(i, 3));
        }
        q.begin_drain();
        let sizes: Vec<usize> = std::iter::from_fn(|| q.next_batch().map(|v| v.len())).collect();
        assert_eq!(sizes, vec![4, 2, 1, 1]);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn queue_respects_max_batch() {
        let q = queue(1, 4, 1);
        for i in 0..12 {
            admit(&q, req(i, 3));
        }
        q.begin_drain();
        let sizes: Vec<usize> = std::iter::from_fn(|| q.next_batch().map(|v| v.len())).collect();
        assert_eq!(sizes, vec![4, 4, 4]);
    }

    #[test]
    fn queue_groups_by_step_count() {
        // mixed steps: a batch never mixes step counts, so the batched
        // dispatch can honor per-request steps.
        let q = queue(1, 8, 1);
        for r in [req(0, 5), req(1, 5), req(2, 3), req(3, 3)] {
            admit(&q, r);
        }
        q.begin_drain();
        let first = q.next_batch().unwrap();
        assert_eq!(first.len(), 2);
        assert!(first.iter().all(|a| a.req.steps() == 5));
        let second = q.next_batch().unwrap();
        assert_eq!(second.len(), 2);
        assert!(second.iter().all(|a| a.req.steps() == 3));
    }

    #[test]
    fn queue_never_mixes_models_and_serves_oldest_lane_first() {
        // ISSUE 7: interleaved U-net / ResNet-18 / VGG-16 admissions. A
        // batch never mixes models, and among the per-model sub-lanes of
        // a priority level the one whose FRONT entry is oldest goes
        // first — so no model starves behind a busier one.
        let q = queue(1, 8, 1);
        admit(&q, req(0, 3)); // unet, oldest
        q.admit(ClassifyRequest::new(1, 1, ModelChoice::Resnet18), false)
            .unwrap();
        admit(&q, req(2, 3));
        q.admit(ClassifyRequest::new(3, 3, ModelChoice::Vgg16), false)
            .unwrap();
        q.admit(ClassifyRequest::new(4, 4, ModelChoice::Resnet18), false)
            .unwrap();
        q.begin_drain();
        let mut batches = Vec::new();
        while let Some(b) = q.next_batch() {
            assert_eq!(
                b.iter()
                    .map(|a| a.req.batch_key())
                    .collect::<std::collections::HashSet<_>>()
                    .len(),
                1,
                "a batch must hold exactly one (model, steps, shape) key"
            );
            batches.push((
                b[0].req.model(),
                b.iter().map(|a| a.req.id()).collect::<Vec<_>>(),
            ));
        }
        assert_eq!(
            batches,
            vec![
                (ModelChoice::Unet, vec![0, 2]),
                (ModelChoice::Resnet18, vec![1, 4]),
                (ModelChoice::Vgg16, vec![3]),
            ],
            "oldest front ticket picks the lane; same-model requests coalesce"
        );
    }

    #[test]
    fn batch_key_includes_image_shape() {
        // ISSUE 9: the batch key is (model, steps, shape). The shape
        // component is the canonical served [c, h, w] per model, so the
        // U-net's diffusion images can never share a batch slab with
        // the classifiers' RGB inputs even if the model/steps ever
        // collided.
        let unet: InferenceRequest = req(0, 3).into();
        let resnet: InferenceRequest =
            ClassifyRequest::new(1, 1, ModelChoice::Resnet18).into();
        let vgg: InferenceRequest = ClassifyRequest::new(2, 2, ModelChoice::Vgg16).into();
        let u = UnetConfig::default();
        let (_, _, unet_shape) = unet.batch_key();
        assert_eq!(unet_shape, (u.img_channels, u.img, u.img));
        let (_, _, r_shape) = resnet.batch_key();
        let (_, _, v_shape) = vgg.batch_key();
        assert_eq!(r_shape, (3, CLASSIFY_IMG, CLASSIFY_IMG));
        assert_eq!(r_shape, v_shape, "both classifiers serve the same input shape");
        assert_ne!(
            unet_shape, r_shape,
            "the U-net and the classifiers serve different shapes"
        );
    }

    #[test]
    fn collector_counts_cross_shape_batches() {
        // Mirrors the cross_model_batches regression (ISSUE 7 → 9): a
        // WorkerMsg flagged cross_shape must surface in the session
        // metrics, and unflagged ones must not.
        let live = Arc::new(Mutex::new(SessionLive {
            metrics: {
                let mut m = ServeMetrics::new();
                m.per_worker_requests = vec![0; 1];
                m
            },
            worker_pools: vec![PoolStats::default(); 1],
        }));
        let (tx, rx) = channel::<LaneEvent>();
        let live2 = Arc::clone(&live);
        let collector = std::thread::spawn(move || collector_main(rx, live2));
        for cross_shape in [false, true, true] {
            tx.send(LaneEvent::Batch(WorkerMsg {
                worker: 0,
                requests: 1,
                steps_done: 1,
                service_us: vec![1.0],
                e2e_us: vec![1.0],
                step_us: vec![1.0],
                host_prep_us: 0.0,
                dispatches: 1,
                batch_items: 1,
                stalled: false,
                pool: PoolStats::default(),
                model: ModelChoice::Unet,
                cross_model: false,
                cross_shape,
            }))
            .unwrap();
        }
        drop(tx);
        collector.join().unwrap();
        let l = live.lock().unwrap();
        assert_eq!(l.metrics.cross_shape_batches, 2);
        assert_eq!(l.metrics.cross_model_batches, 0);
        assert_eq!(l.metrics.requests_done, 3);
    }

    #[test]
    fn queue_drains_priorities_most_urgent_first() {
        let q = queue(1, 8, 3);
        let mut low = req(0, 3);
        low.priority = 2;
        let mut high = req(1, 3);
        high.priority = 0;
        let mut over = req(2, 3);
        over.priority = 9; // clamps to the lowest level (2)
        admit(&q, low);
        admit(&q, high);
        admit(&q, over);
        q.begin_drain();
        let first = q.next_batch().unwrap();
        assert_eq!(first.len(), 1, "priority lanes never mix in one batch");
        assert_eq!(first[0].req.id(), 1, "priority 0 drains first");
        let second = q.next_batch().unwrap();
        let ids: Vec<u64> = second.iter().map(|a| a.req.id()).collect();
        assert_eq!(ids, vec![0, 2], "same-level FIFO, clamped priority joins it");
    }

    #[test]
    fn queue_bounded_admission_and_shutdown_rejections() {
        let q = raw_queue(2, 1, 1, 4, false);
        let _t0 = q.admit(req(0, 3), false).unwrap();
        let _t1 = q.admit(req(1, 3), false).unwrap();
        assert_eq!(
            q.admit(req(2, 3), false).unwrap_err(),
            AdmissionError::QueueFull
        );
        q.begin_drain();
        assert_eq!(
            q.admit(req(3, 3), false).unwrap_err(),
            AdmissionError::ShuttingDown
        );
        let s = q.admission_stats();
        assert_eq!(s.offered, 4);
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected_queue_full, 1);
        assert_eq!(s.rejected_shutdown, 1);
        assert_eq!(s.queue_depth, 2);
    }

    #[test]
    fn queue_rejects_unmeetable_deadline_at_admission() {
        let q = queue(1, 4, 1);
        let mut r = req(0, 3);
        r.deadline = Some(Duration::ZERO);
        assert_eq!(q.admit(r, false).unwrap_err(), AdmissionError::Deadline);
        assert_eq!(q.admission_stats().rejected_deadline, 1);
    }

    #[test]
    fn queue_expires_stale_entries_at_batch_formation() {
        let q = queue(1, 4, 1);
        let mut stale = req(0, 3);
        // long enough to survive the admission-time expiry check, far
        // shorter than the sleep before the pop
        stale.deadline = Some(Duration::from_millis(2));
        let t_stale = q.admit(stale, false).unwrap();
        let t_live = q.admit(req(1, 3), false).unwrap();
        std::thread::sleep(Duration::from_millis(25));
        q.begin_drain();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].req.id(), 1, "only the live request executes");
        assert!(q.next_batch().is_none());
        let err = t_stale.wait().unwrap_err().to_string();
        assert!(err.contains("expired"), "{err}");
        assert_eq!(q.admission_stats().expired, 1);
        // the live ticket is still pending (nothing executed it here)
        drop(t_live);
        drop(batch);
    }

    #[test]
    fn queue_expires_low_priority_entries_while_popping_urgent_lane() {
        // Liveness: the front-of-lane expiry sweep must cover EVERY
        // priority lane on each batch formation — a stale low-priority
        // entry resolves (and frees its bounded-queue slot) even though
        // the batch itself comes from the urgent lane.
        let q = raw_queue(3, 3, 1, 8, false);
        let mut stale_low = req(0, 3);
        stale_low.priority = 2;
        stale_low.deadline = Some(Duration::from_millis(2));
        let t_stale = q.admit(stale_low, false).unwrap();
        admit(&q, req(1, 3)); // urgent (priority 0)
        std::thread::sleep(Duration::from_millis(25));
        let batch = q.next_batch().unwrap();
        assert_eq!(batch[0].req.id(), 1, "batch comes from the urgent lane");
        // the stale low-priority ticket resolved during that same pop
        let err = t_stale.wait().unwrap_err().to_string();
        assert!(err.contains("expired"), "{err}");
        let s = q.admission_stats();
        assert_eq!(s.expired, 1);
        assert_eq!(s.queue_depth, 0, "the dead entry released its slot");
        // and the freed slot is admissible again (depth 3, 0 queued)
        q.admit(req(2, 3), false).unwrap();
    }

    #[test]
    fn queue_held_gate_blocks_grabs_until_release() {
        let q = Arc::new(raw_queue(8, 1, 1, 4, true));
        admit(&q, req(0, 3));
        let (tx, rx) = channel();
        let q2 = Arc::clone(&q);
        let grabber = std::thread::spawn(move || {
            let b = q2.next_batch();
            let _ = tx.send(b.map(|v| v.len()));
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "held queue must not hand out batches"
        );
        q.release();
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Some(1),
            "released queue serves the waiting grab"
        );
        grabber.join().unwrap();
    }

    #[test]
    fn ticket_try_wait_polls_and_fuses() {
        let q = queue(1, 4, 1);
        let mut t = q.admit(req(0, 3), false).unwrap();
        assert!(t.try_wait().is_none(), "unresolved ticket polls None");
        q.begin_drain();
        let batch = q.next_batch().unwrap();
        let _ = batch[0].tx.send(Err(anyhow!("boom")));
        let r = t.try_wait().expect("resolved now");
        assert!(r.unwrap_err().to_string().contains("boom"));
        let again = t.try_wait().expect("fused");
        assert!(again.unwrap_err().to_string().contains("already consumed"));
    }

    /// Wrap plain requests as Admitted entries (prepare-stage tests).
    fn admitted(reqs: Vec<DenoiseRequest>) -> Vec<Admitted> {
        reqs.into_iter()
            .enumerate()
            .map(|(i, req)| {
                let (tx, _rx) = channel();
                Admitted {
                    req: req.into(),
                    ticket: i as u64,
                    admitted_at: Instant::now(),
                    deadline: None,
                    tx,
                }
            })
            .collect()
    }

    #[test]
    fn prepared_batch_layout_and_noise_order() {
        let schedule = DdpmSchedule::standard(4);
        let reqs = admitted(vec![req(0, 4), req(1, 4)]);
        let pool = BufferPool::disabled();
        let pb = prepare_host_batch(reqs, &schedule, &[1, 2, 2], 8, &[], &pool).unwrap();
        assert_eq!(pb.x0.shape, vec![2, 1, 2, 2]);
        assert_eq!(pb.t_embs.shape, vec![4, 8]);
        assert_eq!(pb.coeffs.shape, vec![4, 3]);
        assert_eq!(pb.noises.shape, vec![2, 4, 1, 2, 2]);
        // the t = 0 row (last chunk row) injects no noise
        let n = 4;
        for i in 0..2 {
            let last = &pb.noises.data[(i * 4 + 3) * n..(i * 4 + 4) * n];
            assert!(last.iter().all(|&v| v == 0.0), "sigma row at t=0 must be zero");
        }
        // draw order matches denoise_one: x first, then per-step noise
        let mut rng = Rng::new(0);
        let x_expect = rng.normal_vec(n);
        assert_eq!(&pb.x0.data[..n], &x_expect[..]);
        let first_noise = rng.normal_vec(n);
        assert_eq!(&pb.noises.data[..n], &first_noise[..]);
    }

    #[test]
    fn noise_chunk_gather() {
        let schedule = DdpmSchedule::standard(3);
        let pool = BufferPool::disabled();
        let pb = prepare_host_batch(
            admitted(vec![req(0, 3), req(1, 3)]),
            &schedule,
            &[1, 2, 2],
            4,
            &[],
            &pool,
        )
        .unwrap();
        let mut chunk = vec![0.0f32; 2 * 2 * 4];
        copy_noise_chunk_into(&pb.noises, 2, 3, 1, 2, &mut chunk).unwrap();
        // row 1 of request 0 lands at the front of the chunk
        assert_eq!(chunk[..4], pb.noises.data[4..8]);
        // row 1 of request 1 follows
        assert_eq!(chunk[8..12], pb.noises.data[16..20]);
        // out-of-range chunks and wrong-sized slabs rejected
        assert!(copy_noise_chunk_into(&pb.noises, 2, 3, 2, 2, &mut chunk).is_err());
        let mut short = vec![0.0f32; 3];
        assert!(copy_noise_chunk_into(&pb.noises, 2, 3, 1, 2, &mut short).is_err());
    }

    #[test]
    fn prepare_rejects_bad_step_counts_and_returns_the_batch() {
        let schedule = DdpmSchedule::standard(4);
        let pool = BufferPool::disabled();
        let (reqs, e) = prepare_host_batch(
            admitted(vec![req(0, 0)]),
            &schedule,
            &[1, 2, 2],
            4,
            &[],
            &pool,
        )
        .unwrap_err();
        assert_eq!(reqs.len(), 1, "the batch comes back for ticket resolution");
        assert!(e.to_string().contains("out of range"), "{e}");
        assert!(prepare_host_batch(
            admitted(vec![req(0, 9)]),
            &schedule,
            &[1, 2, 2],
            4,
            &[],
            &pool
        )
        .is_err());
    }

    #[test]
    fn prepared_batch_identical_on_recycled_slabs() {
        // The pooled prepare must produce the same bits whether its slabs
        // are freshly allocated or recycled: the noise slab's zeroed
        // lease keeps the t = 0 rows correct, and the dirty-leased slabs
        // (x0/t_embs/coeffs) are fully overwritten — this test is the
        // guard that they really are.
        let schedule = DdpmSchedule::standard(4);
        let mk = |pool: &BufferPool| {
            prepare_host_batch(
                admitted(vec![req(0, 4), req(1, 4)]),
                &schedule,
                &[1, 2, 2],
                8,
                &[],
                pool,
            )
            .unwrap()
        };
        let cold = mk(&BufferPool::disabled());
        let pool = BufferPool::new();
        let warm = mk(&pool);
        // return every slab dirty, then prepare again from the free list
        pool.reclaim(warm.x0);
        pool.reclaim(warm.t_embs);
        pool.reclaim(warm.coeffs);
        pool.reclaim(warm.noises);
        let recycled = mk(&pool);
        assert!(pool.stats().hits >= 1, "second prepare must reuse slabs");
        assert_eq!(recycled.x0, cold.x0);
        assert_eq!(recycled.t_embs, cold.t_embs);
        assert_eq!(recycled.coeffs, cold.coeffs);
        assert_eq!(recycled.noises, cold.noises);
    }

    #[test]
    fn kill_drops_backlog_unresolved_and_stops_grabs() {
        let q = queue(1, 4, 1);
        let mut t = q.admit(req(0, 3), false).unwrap();
        q.kill_now();
        // the lane's next grab sees death immediately, even with work queued
        assert!(q.next_batch().is_none(), "killed queue hands out nothing");
        // the queued entry was dropped unresolved: its ticket reads Lost
        match t.poll() {
            TicketPoll::Lost => {}
            other => panic!("expected Lost after kill, got {other:?}"),
        }
        // admission is closed
        assert_eq!(
            q.admit(req(1, 3), false).unwrap_err(),
            AdmissionError::ShuttingDown
        );
    }

    #[test]
    fn ticket_poll_distinguishes_ready_from_lost() {
        let q = queue(1, 4, 1);
        let mut t = q.admit(req(0, 3), false).unwrap();
        assert!(matches!(t.poll(), TicketPoll::Pending));
        q.begin_drain();
        let batch = q.next_batch().unwrap();
        let _ = batch[0].tx.send(Err(anyhow!("boom")));
        match t.poll() {
            TicketPoll::Ready(r) => {
                assert!(r.unwrap_err().to_string().contains("boom"));
            }
            other => panic!("expected Ready, got {other:?}"),
        }
        // a second ticket whose lane drops it reads Lost, not Ready
        let q2 = queue(1, 4, 1);
        let mut t2 = q2.admit(req(1, 3), false).unwrap();
        q2.kill_now();
        assert!(matches!(t2.poll(), TicketPoll::Lost));
    }

    #[test]
    fn idle_lanes_beat_the_pulse() {
        let pulse = Arc::new(ShardPulse::new());
        let q = Arc::new(AdmissionQueue::new(
            8,
            1,
            None,
            1,
            4,
            false,
            Arc::clone(&pulse),
            Duration::from_millis(5),
        ));
        let q2 = Arc::clone(&q);
        let lane = std::thread::spawn(move || q2.next_batch());
        // an empty queue still beats: the wait loop wakes per heartbeat
        let t0 = Instant::now();
        let s0 = pulse.seq();
        while pulse.seq() < s0 + 3 {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "idle lane never beat the pulse"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        q.kill_now();
        assert!(lane.join().unwrap().is_none());
        // after death the pulse freezes
        let s1 = pulse.seq();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(pulse.seq(), s1, "dead lanes must not beat");
    }

    #[test]
    fn workload_is_deterministic_per_index() {
        let cfg = ServeConfig {
            steps: 7,
            ..ServeConfig::default()
        };
        let whole = workload(&cfg, 42, 0..8);
        assert_eq!(whole.len(), 8);
        // two disjoint shards reproduce exactly the same requests
        let lo = workload(&cfg, 42, 0..4);
        let hi = workload(&cfg, 42, 4..8);
        for (a, b) in whole.iter().zip(lo.iter().chain(hi.iter())) {
            assert_eq!(a.id(), b.id());
            assert_eq!(a.seed(), b.seed());
            assert_eq!(a.steps(), b.steps());
        }
        assert!(whole.iter().all(|r| r.steps() == 7));
        assert!(whole
            .iter()
            .all(|r| r.deadline().is_none() && r.priority() == 0));
        assert!(
            whole.iter().all(|r| r.model() == ModelChoice::Unet),
            "an empty serve.model_mix stays pure-diffusion"
        );
    }

    #[test]
    fn workload_applies_the_model_mix_pattern() {
        let cfg = ServeConfig {
            steps: 5,
            model_mix: "unet:2,resnet18:1,vgg16:1".into(),
            ..ServeConfig::default()
        };
        let reqs = workload(&cfg, 42, 0..8);
        let models: Vec<ModelChoice> = reqs.iter().map(|r| r.model()).collect();
        assert_eq!(
            models,
            vec![
                ModelChoice::Unet,
                ModelChoice::Unet,
                ModelChoice::Resnet18,
                ModelChoice::Vgg16,
                ModelChoice::Unet,
                ModelChoice::Unet,
                ModelChoice::Resnet18,
                ModelChoice::Vgg16,
            ]
        );
        // classification requests keep the same per-index seed stream and
        // carry one logical step each
        assert_eq!(reqs[2].seed(), 42u64.wrapping_add(2 * 7919));
        assert_eq!(reqs[2].steps(), 1);
        // shard slices reproduce the same mixed workload
        let hi = workload(&cfg, 42, 4..8);
        for (a, b) in reqs[4..].iter().zip(hi.iter()) {
            assert_eq!(a.id(), b.id());
            assert_eq!(a.seed(), b.seed());
            assert_eq!(a.model(), b.model());
        }
    }
}
