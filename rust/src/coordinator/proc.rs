//! Process supervision for cluster serving (ISSUE 10).
//!
//! Two halves of the same boundary:
//!
//! * **Worker side** — [`run_worker`] is the body of the hidden
//!   `shard-worker` CLI subcommand: bind a Unix socket, accept exactly
//!   one front-door connection, handshake (version-checked, refusals
//!   answered with [`WireMsg::Reject`]), then wrap one in-process
//!   serving session ([`ServerHandle`]) behind the wire — submits map
//!   to `try_submit`, resolved tickets stream back as `TicketResult`
//!   frames, and a heartbeat frame carrying the lane-pulse sequence and
//!   queue depth goes out every `serve.heartbeat_ms`.
//! * **Supervisor side** — [`WorkerProc`] spawns one `shard-worker`
//!   child on the `sf-mmcn` binary, connects, handshakes, and pumps
//!   every inbound frame into a shared [`WorkerEvent`] channel that the
//!   `ClusterFleet` monitor drains. Killing the child (or the child
//!   dying) surfaces as [`WorkerEvent::Gone`] via socket EOF.
//!
//! The worker process runs exactly one session: its config is the fleet
//! config with `cluster`/`shards` forced to a single session and the
//! fault/preempt planes cleared (those belong to the front door).

use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::server::{DiffusionServer, Ticket, TicketPoll};
use crate::coordinator::wire::{
    write_frame, FrameReader, WireMetrics, WireMsg, WIRE_VERSION,
};
use crate::runtime::ArtifactStore;

/// How long the supervisor waits for a fresh child to bind its socket
/// and complete the handshake. Generous: debug-build workers pay
/// process startup plus session construction.
pub const SPAWN_TIMEOUT: Duration = Duration::from_secs(30);

/// How long an accepted worker waits for the front door to connect
/// before concluding it was orphaned and exiting.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Write timeout on both halves of the worker socket. A healthy peer
/// drains its socket within milliseconds, so a frame write that blocks
/// this long means the peer stopped reading (wedged process, SIGSTOP) —
/// the write errors out and the sender treats the connection as dead.
/// Without it, `ClusterFleet` frame writes (issued under the cluster
/// state lock) could block indefinitely on a full socket buffer and
/// freeze admission, metrics, and the heartbeat monitor itself.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// One frame (or loss) from one worker connection, tagged with the
/// worker slot and spawn generation so the monitor can ignore stale
/// events from a connection it already replaced.
#[derive(Debug)]
pub enum WorkerEvent {
    /// A frame arrived from worker `worker` (spawn generation `gen`).
    Msg {
        /// Worker slot index.
        worker: usize,
        /// Spawn generation of the connection the frame arrived on.
        gen: u64,
        /// The frame.
        msg: WireMsg,
    },
    /// The connection reached EOF or a wire error: the worker process
    /// died or went unreadable.
    Gone {
        /// Worker slot index.
        worker: usize,
        /// Spawn generation of the lost connection.
        gen: u64,
    },
}

// ---------------------------------------------------------------------
// Supervisor side
// ---------------------------------------------------------------------

/// Supervisor handle on one spawned `shard-worker` process: the child,
/// the write half of its socket, and the reader thread feeding
/// [`WorkerEvent`]s to the fleet monitor.
#[derive(Debug)]
pub struct WorkerProc {
    /// Worker slot index.
    pub worker: usize,
    /// Spawn generation (0 for the original spawn, +1 per respawn).
    pub gen: u64,
    /// Child process id, as reported by the handshake.
    pub pid: u64,
    child: Child,
    writer: UnixStream,
    reader: Option<JoinHandle<()>>,
    socket: PathBuf,
}

impl WorkerProc {
    /// Spawn one `shard-worker` child of `exe`, connect to its socket,
    /// and complete the version handshake. `cfg_path` is the worker
    /// config TOML written by the cluster; `dir` hosts the per-cluster
    /// sockets; every inbound frame is forwarded to `events`.
    pub fn spawn(
        exe: &Path,
        cfg_path: &Path,
        dir: &Path,
        worker: usize,
        gen: u64,
        events: Sender<WorkerEvent>,
    ) -> Result<WorkerProc> {
        let socket = dir.join(format!("w{worker}-g{gen}.sock"));
        let _ = std::fs::remove_file(&socket);
        let mut child = Command::new(exe)
            .arg("shard-worker")
            .arg("--config")
            .arg(cfg_path)
            .arg("--socket")
            .arg(&socket)
            .arg("--worker")
            .arg(worker.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning shard-worker {worker} from {}", exe.display()))?;

        let deadline = Instant::now() + SPAWN_TIMEOUT;
        let stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(e) => {
                    if let Ok(Some(status)) = child.try_wait() {
                        bail!("shard-worker {worker} exited during startup ({status})");
                    }
                    if Instant::now() >= deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        bail!(
                            "shard-worker {worker}: socket {} never came up ({e})",
                            socket.display()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        };

        // Writes carry a permanent timeout (see WRITE_TIMEOUT): a worker
        // that stops reading must surface as a send error, not a front
        // door blocked inside the state lock.
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        // Handshake under a read timeout; the timeout is a property of
        // the shared socket description, so clear it before the reader
        // thread takes over with blocking reads.
        let mut writer = stream.try_clone().context("cloning worker socket")?;
        let mut reader = FrameReader::new(stream.try_clone().context("cloning worker socket")?);
        write_frame(
            &mut writer,
            &WireMsg::Hello {
                version: WIRE_VERSION,
                worker,
            },
        )
        .context("sending hello")?;
        stream.set_read_timeout(Some(SPAWN_TIMEOUT))?;
        let pid = match reader.next_msg() {
            Ok(Some(WireMsg::HelloAck {
                version,
                worker: w,
                pid,
            })) => {
                if version != WIRE_VERSION || w != worker {
                    let _ = child.kill();
                    let _ = child.wait();
                    bail!(
                        "shard-worker {worker}: bad hello_ack (version {version}, worker {w})"
                    );
                }
                pid
            }
            Ok(Some(WireMsg::Reject { reason })) => {
                let _ = child.wait();
                bail!("shard-worker {worker} refused the handshake: {reason}");
            }
            Ok(other) => {
                let _ = child.kill();
                let _ = child.wait();
                bail!("shard-worker {worker}: unexpected handshake frame {other:?}");
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e.context(format!("shard-worker {worker}: handshake read")));
            }
        };
        stream.set_read_timeout(None)?;

        let reader_thread = std::thread::Builder::new()
            .name(format!("cluster-w{worker}-g{gen}-reader"))
            .spawn(move || {
                let mut reader = reader;
                loop {
                    match reader.next_msg() {
                        Ok(Some(msg)) => {
                            if events.send(WorkerEvent::Msg { worker, gen, msg }).is_err() {
                                break; // monitor gone; stop reading
                            }
                        }
                        Ok(None) | Err(_) => {
                            let _ = events.send(WorkerEvent::Gone { worker, gen });
                            break;
                        }
                    }
                }
            })
            .expect("spawn worker reader thread");

        Ok(WorkerProc {
            worker,
            gen,
            pid,
            child,
            writer,
            reader: Some(reader_thread),
            socket,
        })
    }

    /// Send one frame to the worker. An error means the connection is
    /// down — the caller treats the worker as dead.
    pub fn send(&mut self, msg: &WireMsg) -> Result<()> {
        write_frame(&mut self.writer, msg)
    }

    /// Hard-kill the child process (SIGKILL) and reap it. The reader
    /// thread sees EOF and emits [`WorkerEvent::Gone`].
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Reap a worker expected to exit on its own (after `Shutdown`):
    /// wait for the child, join the reader, remove the socket file.
    /// Falls back to a kill if the child outlives `grace`.
    pub fn reap(mut self, grace: Duration) {
        let deadline = Instant::now() + grace;
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                _ => {
                    let _ = self.child.kill();
                    let _ = self.child.wait();
                    break;
                }
            }
        }
        if let Some(jh) = self.reader.take() {
            let _ = jh.join();
        }
        let _ = std::fs::remove_file(&self.socket);
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        // never leak a child process: anything still running when the
        // handle drops gets killed and reaped
        if let Ok(None) = self.child.try_wait() {
            let _ = self.child.kill();
        }
        let _ = self.child.wait();
        if let Some(jh) = self.reader.take() {
            let _ = jh.join();
        }
        let _ = std::fs::remove_file(&self.socket);
    }
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// The config one worker process actually runs: a single in-process
/// session, with the cluster/fault/preempt planes stripped (they belong
/// to the front door, not the worker).
pub fn worker_session_config(cfg: &ServeConfig) -> ServeConfig {
    let mut wcfg = cfg.clone();
    wcfg.cluster = 0;
    wcfg.shards = 1;
    wcfg.cosim = false;
    wcfg.fault_spec = String::new();
    wcfg.preempt_file = String::new();
    wcfg
}

/// Body of the hidden `shard-worker` subcommand: serve one session
/// behind `socket` until the front door shuts the connection down.
/// Exits cleanly after sending the final `Metrics { last: true }`
/// frame; an orphaned worker (front door vanished) also exits instead
/// of lingering.
pub fn run_worker(cfg: &ServeConfig, socket: &Path, worker: usize) -> Result<()> {
    let listener =
        UnixListener::bind(socket).with_context(|| format!("binding {}", socket.display()))?;
    listener.set_nonblocking(true)?;
    let accept_deadline = Instant::now() + ACCEPT_TIMEOUT;
    let stream = loop {
        match listener.accept() {
            Ok((s, _)) => break s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= accept_deadline {
                    bail!("shard-worker {worker}: front door never connected");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accepting front-door connection"),
        }
    };
    stream.set_nonblocking(false)?;
    // Same write timeout as the supervisor side: a front door that
    // stops reading turns the next frame write into an error, and the
    // worker exits instead of blocking forever on a full socket buffer.
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = FrameReader::new(stream.try_clone()?);

    // Handshake: require a version-matching Hello for this slot before
    // starting the session; refuse anything else with a Reject frame.
    match reader.next_msg() {
        Ok(Some(WireMsg::Hello { version, worker: w })) => {
            if version != WIRE_VERSION {
                let reason = format!(
                    "version mismatch: front door speaks {version}, worker speaks {WIRE_VERSION}"
                );
                let _ = write_frame(&mut writer, &WireMsg::Reject { reason: reason.clone() });
                bail!("shard-worker {worker}: {reason}");
            }
            if w != worker {
                let reason = format!("worker slot mismatch: addressed {w}, running as {worker}");
                let _ = write_frame(&mut writer, &WireMsg::Reject { reason: reason.clone() });
                bail!("shard-worker {worker}: {reason}");
            }
            write_frame(
                &mut writer,
                &WireMsg::HelloAck {
                    version: WIRE_VERSION,
                    worker,
                    pid: std::process::id() as u64,
                },
            )?;
        }
        Ok(other) => {
            let _ = write_frame(
                &mut writer,
                &WireMsg::Reject {
                    reason: "expected hello as the first frame".into(),
                },
            );
            bail!("shard-worker {worker}: bad handshake opener {other:?}");
        }
        Err(e) => return Err(e.context("reading handshake")),
    }

    let wcfg = worker_session_config(cfg);
    let store = ArtifactStore::new("artifacts");
    let handle = DiffusionServer::new(wcfg.clone(), &store)
        .with_context(|| format!("starting shard-worker {worker} session"))?
        .start();
    let pulse = handle.pulse();

    // Reader thread: frames -> channel, so the serve loop never blocks
    // on the socket.
    let (tx, rx) = std::sync::mpsc::channel::<WireMsg>();
    let reader_thread = std::thread::Builder::new()
        .name(format!("shard-worker-{worker}-reader"))
        .spawn(move || {
            let mut reader = reader;
            loop {
                match reader.next_msg() {
                    Ok(Some(msg)) => {
                        if tx.send(msg).is_err() {
                            break;
                        }
                    }
                    Ok(None) | Err(_) => break, // EOF / wire error: channel drops
                }
            }
        })
        .expect("spawn shard-worker reader");

    let result = worker_serve_loop(&wcfg, &handle, &mut writer, &rx, &pulse);
    let orphaned = matches!(result, Ok(true));
    if orphaned {
        // front door vanished mid-session: drop the backlog and exit
        handle.kill();
        let _ = handle.shutdown();
    } else {
        let metrics = handle.shutdown()?;
        let _ = write_frame(
            &mut writer,
            &WireMsg::Metrics {
                last: true,
                snapshot: WireMetrics::from_metrics(&metrics),
            },
        );
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = reader_thread.join();
    result.map(|_| ())
}

/// The worker pump: apply control frames, flush resolved tickets,
/// heartbeat. Returns `Ok(true)` if the front door disappeared
/// (orphaned) and `Ok(false)` on an orderly `Shutdown`.
fn worker_serve_loop(
    cfg: &ServeConfig,
    handle: &crate::coordinator::server::ServerHandle,
    writer: &mut UnixStream,
    rx: &Receiver<WireMsg>,
    pulse: &crate::coordinator::server::ShardPulse,
) -> Result<bool> {
    let pump = Duration::from_micros(cfg.monitor_pump_us.max(1));
    let hb_period = Duration::from_millis(cfg.heartbeat_ms.max(1));
    let mut pending: Vec<(u64, Ticket)> = Vec::new();
    let mut last_hb: Option<Instant> = None;
    let mut shutdown_req = false;
    loop {
        // 1) control frames
        loop {
            match rx.try_recv() {
                Ok(WireMsg::Submit { ticket, req }) => match handle.try_submit(req) {
                    Ok(t) => pending.push((ticket, t)),
                    Err(error) => {
                        write_frame(writer, &WireMsg::SubmitErr { ticket, error })?
                    }
                },
                Ok(WireMsg::Drain) => handle.begin_shutdown(),
                Ok(WireMsg::MetricsReq) => write_frame(
                    writer,
                    &WireMsg::Metrics {
                        last: false,
                        snapshot: WireMetrics::from_metrics(&handle.metrics_snapshot()),
                    },
                )?,
                Ok(WireMsg::Shutdown) => {
                    handle.begin_shutdown();
                    shutdown_req = true;
                }
                Ok(_) => {} // front-door-only frames: ignore
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return Ok(true), // orphaned
            }
        }
        // 2) resolved tickets
        let mut i = 0;
        while i < pending.len() {
            match pending[i].1.poll() {
                TicketPoll::Pending => i += 1,
                TicketPoll::Ready(r) => {
                    let (ticket, _) = pending.swap_remove(i);
                    write_frame(
                        writer,
                        &WireMsg::TicketResult {
                            ticket,
                            result: r.map_err(|e| format!("{e:#}")),
                        },
                    )?;
                }
                TicketPoll::Lost => {
                    let (ticket, _) = pending.swap_remove(i);
                    write_frame(
                        writer,
                        &WireMsg::TicketResult {
                            ticket,
                            result: Err("worker lane dropped the ticket".into()),
                        },
                    )?;
                }
            }
        }
        // 3) heartbeat
        if last_hb.map_or(true, |t| t.elapsed() >= hb_period) {
            last_hb = Some(Instant::now());
            write_frame(
                writer,
                &WireMsg::Heartbeat {
                    seq: pulse.seq(),
                    queue_depth: handle.queue_depth() as u64,
                },
            )?;
        }
        // 4) orderly exit: drain finished, everything flushed
        if shutdown_req && pending.is_empty() {
            return Ok(false);
        }
        std::thread::sleep(pump);
    }
}
