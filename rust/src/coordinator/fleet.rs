//! Fault-tolerant sharded serving: the [`ShardFleet`] front door
//! (ISSUE 6).
//!
//! A fleet owns N independent serving sessions ("shards" — each a full
//! [`DiffusionServer`] session with its own lanes and bounded admission
//! queue) and presents one submit surface. Three mechanisms make it
//! robust:
//!
//! * **Routing** — power-of-two-choices on *live* queue depth: sample two
//!   live shards, admit to the shallower queue. If the p2c winner sheds
//!   (`QueueFull`), the remaining live shards are tried before the fleet
//!   itself reports full. This keeps load near-balanced without a global
//!   scheduler — the operational analogue of the paper's Server Flow
//!   principle of keeping heterogeneous units saturated behind one front
//!   door.
//! * **Health** — each shard's lanes publish a heartbeat sequence
//!   ([`ShardPulse`]): at least one beat per `serve.heartbeat_ms` while
//!   alive (idle lanes use a timed condvar wait, so an empty queue still
//!   beats) plus one per dispatched chunk. The fleet monitor samples every
//!   period; a sequence frozen for `serve.heartbeat_misses` consecutive
//!   samples declares the shard dead. A shard killed outright is detected
//!   faster, through the ticket channel: its undelivered tickets read
//!   [`TicketPoll::Lost`].
//! * **Failover** — a dead shard's undelivered requests are re-admitted
//!   onto survivors. This is lossless *and* bit-identical because request
//!   execution is a pure function of `(model, seed, steps)` (the
//!   per-index-deterministic `workload()` contract — classification
//!   requests included, ISSUE 7): a recovery run
//!   delivers exactly the images the no-fault run would have. Duplicate
//!   execution (shard died after computing but before the fleet saw the
//!   result) is harmless for the same reason — fleet delivery is
//!   single-shot per ticket.
//!
//! Preemption is the graceful third path: [`ShardFleet::begin_preempt`]
//! stops routing to a shard and drains it (every admitted ticket
//! resolves), modelling a preemption notice rather than a crash. After
//! the drain the shard parks as `Drained`.
//!
//! Failure injection comes from [`FaultSpec`] (`serve.fault_spec` /
//! `--fault-spec`): each shard's lanes consult their own `FaultPlane`, so
//! every kill/stall/panic/delay scenario in tests and benches replays
//! exactly from a spec string or seed.
//!
//! Semantics worth knowing:
//!
//! * A request's relative deadline restarts when failover re-admits it —
//!   the budget is per-admission, not per-fleet-lifetime.
//! * [`ShardFleet::submit`] never sheds: when every live shard's queue is
//!   full it parks the request fleet-side and the monitor admits it as
//!   soon as a queue has room. [`ShardFleet::try_submit`] sheds
//!   (`QueueFull`) like the single-session API.
//! * Shard sessions run with co-simulation off (fleet metrics are about
//!   delivery robustness; PPA co-sim belongs to single-session runs).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::faults::FaultSpec;
use crate::coordinator::metrics::{FleetMetrics, FleetStats, ModelMetrics, ServeMetrics};
use crate::coordinator::server::{
    AdmissionError, DenoiseRequest, DenoiseResult, DiffusionServer, InferenceRequest,
    ServerHandle, ShardPulse, Ticket, TicketPoll,
};
use crate::runtime::ArtifactStore;
use crate::util::stats::StreamingPercentiles;
use crate::util::Rng;

// The monitor pump interval (how often pending tickets are polled,
// distinct from and much shorter than the heartbeat sampling period)
// comes from `serve.monitor_pump_us` — see `ServeConfig::monitor_pump_us`
// and the `SF_MMCN_MONITOR_PUMP_US` default override.

/// Lifecycle of one shard inside the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    /// Routable: accepting new and failed-over work.
    Live,
    /// Preemption notice received: draining admitted work, not routable.
    Preempting,
    /// Declared dead (missed heartbeats or lost tickets); its undelivered
    /// work was re-admitted to survivors.
    Dead,
    /// Finished a preemption drain; session joined, final metrics kept.
    Drained,
}

/// One shard slot: the session handle (until joined), its heartbeat
/// pulse, and the monitor's last heartbeat observation.
struct Shard {
    handle: Option<ServerHandle>,
    pulse: Arc<ShardPulse>,
    state: ShardState,
    last_seq: u64,
    misses: u64,
    final_metrics: Option<ServeMetrics>,
}

/// One fleet-admitted request in flight. `ticket` is the claim on the
/// currently-assigned shard; `None` means the request is waiting for
/// (re-)admission — either parked by `submit` while every queue was full,
/// or stripped from a dead shard and awaiting a survivor.
struct Pending {
    req: InferenceRequest,
    shard: usize,
    ticket: Option<Ticket>,
    tx: Sender<Result<DenoiseResult>>,
    submitted_at: Instant,
}

struct FleetState {
    shards: Vec<Shard>,
    pending: Vec<Pending>,
    rng: Rng,
    stats: FleetStats,
    e2e: StreamingPercentiles,
    /// Fleet-level per-model rows (ISSUE 7): delivered/failed counts and
    /// e2e percentiles recorded at delivery; steps are summed over the
    /// shards at snapshot time.
    per_model: Vec<ModelMetrics>,
    draining: bool,
}

/// Claim on one fleet-admitted request. Same single-shot semantics as the
/// per-session [`Ticket`], but it survives shard death: the fleet monitor
/// re-admits lost work transparently, so the ticket resolves with the
/// (deterministic) result unless no live shard remains.
#[derive(Debug)]
pub struct FleetTicket {
    id: u64,
    rx: Receiver<Result<DenoiseResult>>,
    done: bool,
}

impl FleetTicket {
    /// Front-door constructor, shared with the multi-process
    /// [`crate::coordinator::cluster::ClusterFleet`] (same single-shot
    /// delivery contract; the receiver is fed by whichever monitor owns
    /// the request).
    pub(crate) fn new(id: u64, rx: Receiver<Result<DenoiseResult>>) -> Self {
        FleetTicket { id, rx, done: false }
    }

    /// Fleet-unique ticket id (monotonic front-door admission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request resolves (possibly after failover).
    pub fn wait(self) -> Result<DenoiseResult> {
        if self.done {
            bail!("fleet ticket {}: already consumed by try_wait", self.id);
        }
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => bail!("fleet ticket {}: fleet dropped without resolving it", self.id),
        }
    }

    /// Non-blocking poll: `None` while in flight, `Some(result)` exactly
    /// once on resolution; spent tickets report an error.
    pub fn try_wait(&mut self) -> Option<Result<DenoiseResult>> {
        if self.done {
            return Some(Err(anyhow!(
                "fleet ticket {}: already consumed by try_wait",
                self.id
            )));
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = true;
                Some(r)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(anyhow!(
                    "fleet ticket {}: fleet dropped without resolving it",
                    self.id
                )))
            }
        }
    }
}

/// The fault-tolerant sharded front door. See the module docs for the
/// failure model; see [`ShardFleet::start`] for construction.
pub struct ShardFleet {
    state: Arc<Mutex<FleetState>>,
    monitor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    t0: Instant,
    next_id: AtomicU64,
}

impl ShardFleet {
    /// Start `cfg.shards` independent serving sessions behind one front
    /// door, with the fault schedule parsed from `cfg.fault_spec` (empty
    /// = no injected faults).
    pub fn start(cfg: ServeConfig, store: &ArtifactStore) -> Result<ShardFleet> {
        let spec = FaultSpec::parse(&cfg.fault_spec)
            .context("parsing serve.fault_spec for the fleet")?;
        Self::start_with_spec(cfg, store, spec)
    }

    /// Start with an explicit fault schedule (tests and seeded bench
    /// scenarios construct the spec directly).
    pub fn start_with_spec(
        cfg: ServeConfig,
        store: &ArtifactStore,
        spec: FaultSpec,
    ) -> Result<ShardFleet> {
        cfg.validate()?;
        let n = cfg.shards;
        let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(1));
        let misses_allowed = cfg.heartbeat_misses.max(1);
        let pump_interval = Duration::from_micros(cfg.monitor_pump_us.max(1));
        let preempt_file = (!cfg.preempt_file.trim().is_empty())
            .then(|| PathBuf::from(cfg.preempt_file.trim()));
        let mut shards = Vec::with_capacity(n);
        for s in 0..n {
            let mut shard_cfg = cfg.clone();
            shard_cfg.shards = 1;
            shard_cfg.cosim = false;
            shard_cfg.fault_spec = String::new();
            let server = DiffusionServer::new(shard_cfg, store)
                .with_context(|| format!("starting fleet shard {s}"))?;
            let plane = (!spec.is_empty()).then(|| Arc::new(spec.plane_for(s)));
            let handle = server.start_with_faults(plane);
            let pulse = handle.pulse();
            shards.push(Shard {
                handle: Some(handle),
                pulse,
                state: ShardState::Live,
                last_seq: 0,
                misses: 0,
                final_metrics: None,
            });
        }
        let state = Arc::new(Mutex::new(FleetState {
            shards,
            pending: Vec::new(),
            rng: Rng::new(cfg.seed ^ 0xf1ee_7),
            stats: FleetStats::default(),
            e2e: StreamingPercentiles::new(),
            per_model: ModelMetrics::rows(),
            draining: false,
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let monitor = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("fleet-monitor".into())
                .spawn(move || {
                    Self::monitor_main(
                        state,
                        stop,
                        heartbeat,
                        misses_allowed,
                        pump_interval,
                        preempt_file,
                    )
                })
                .expect("spawn fleet monitor")
        };
        Ok(ShardFleet {
            state,
            monitor: Some(monitor),
            stop,
            t0: Instant::now(),
            next_id: AtomicU64::new(0),
        })
    }

    /// Shards the fleet was started with (slots, regardless of state).
    pub fn shards(&self) -> usize {
        self.state.lock().unwrap().shards.len()
    }

    /// Instantaneous per-shard lifecycle states, in shard order.
    pub fn shard_states(&self) -> Vec<ShardState> {
        let st = self.state.lock().unwrap();
        st.shards.iter().map(|s| s.state).collect()
    }

    /// Fleet counters plus the instantaneous shard census.
    pub fn stats(&self) -> FleetStats {
        Self::census(&self.state.lock().unwrap())
    }

    /// Admit a request; never sheds. If every live shard's queue is full
    /// the request parks fleet-side and the monitor admits it when room
    /// frees up. Fails only when no live shard exists (or the fleet is
    /// shutting down).
    pub fn submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        self.admit(req.into(), true)
    }

    /// Admit without parking: a fleet where every live shard sheds
    /// returns [`AdmissionError::QueueFull`] immediately.
    pub fn try_submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        self.admit(req.into(), false)
    }

    fn admit(
        &self,
        req: InferenceRequest,
        park: bool,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        let mut st = self.state.lock().unwrap();
        if st.draining {
            return Err(AdmissionError::ShuttingDown);
        }
        let (tx, rx) = channel();
        let now = Instant::now();
        let entry = match Self::assign(&mut st, &req) {
            Ok((shard, ticket)) => Pending {
                req,
                shard,
                ticket: Some(ticket),
                tx,
                submitted_at: now,
            },
            // QueueFull: park until room frees. ShuttingDown: a shard the
            // fault plane just killed but the monitor has not yet marked
            // dead — park; the monitor re-admits once it catches up.
            Err(AdmissionError::QueueFull | AdmissionError::ShuttingDown) if park => Pending {
                req,
                shard: 0,
                ticket: None,
                tx,
                submitted_at: now,
            },
            Err(e) => return Err(e),
        };
        st.pending.push(entry);
        st.stats.submitted += 1;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(FleetTicket { id, rx, done: false })
    }

    /// Power-of-two-choices admission: sample two live shards, try the
    /// one with the shallower queue first, then fall through the rest of
    /// the live set before reporting the fleet full.
    fn assign(
        st: &mut FleetState,
        req: &InferenceRequest,
    ) -> std::result::Result<(usize, Ticket), AdmissionError> {
        let live: Vec<usize> = st
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state == ShardState::Live && s.handle.is_some())
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            return Err(AdmissionError::NoLiveShards);
        }
        let (ai, bi) = Self::p2c_candidates(&mut st.rng, live.len());
        let (a, b) = (live[ai], live[bi]);
        let depth_of = |st: &FleetState, i: usize| {
            st.shards[i].handle.as_ref().map_or(usize::MAX, |h| h.queue_depth())
        };
        let first = if depth_of(st, a) <= depth_of(st, b) { a } else { b };
        let mut last = AdmissionError::QueueFull;
        let order = std::iter::once(first).chain(live.into_iter().filter(|&i| i != first));
        for i in order {
            let Some(h) = st.shards[i].handle.as_ref() else {
                continue;
            };
            match h.try_submit(req.clone()) {
                Ok(t) => return Ok((i, t)),
                // a genuinely expired deadline is terminal, not routable
                Err(AdmissionError::Deadline) => return Err(AdmissionError::Deadline),
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// The two power-of-two-choices candidate slots out of `n`. The draws
    /// are *distinct* whenever `n >= 2`: the second samples the remaining
    /// `n - 1` slots and skips past the first. (Two independent draws
    /// would collide with probability `1/n` and silently degrade that
    /// admission to single-choice routing.)
    fn p2c_candidates(rng: &mut Rng, n: usize) -> (usize, usize) {
        let a = rng.below(n as u64) as usize;
        if n < 2 {
            return (a, a);
        }
        let mut b = rng.below(n as u64 - 1) as usize;
        if b >= a {
            b += 1;
        }
        (a, b)
    }

    /// Operational hard kill (the test/ops analogue of a `kill` fault
    /// event): declare the shard dead now and fail its work over.
    pub fn kill_shard(&self, shard: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let n = st.shards.len();
        if shard >= n {
            bail!("kill_shard: shard {shard} out of range ({n} shards)");
        }
        Self::declare_dead(&mut st, shard);
        Ok(())
    }

    /// Preemption notice: stop routing to `shard` and drain it — every
    /// already-admitted ticket resolves normally, then the session joins
    /// and the shard parks as [`ShardState::Drained`]. Nothing is lost
    /// and nothing re-executes; contrast the hard-kill failover path.
    pub fn begin_preempt(&self, shard: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let n = st.shards.len();
        if shard >= n {
            bail!("begin_preempt: shard {shard} out of range ({n} shards)");
        }
        match st.shards[shard].state {
            ShardState::Live => {
                st.shards[shard].state = ShardState::Preempting;
                if let Some(h) = st.shards[shard].handle.as_ref() {
                    h.begin_shutdown();
                }
                Ok(())
            }
            other => bail!("begin_preempt: shard {shard} is {other:?}, not Live"),
        }
    }

    /// Live snapshot of fleet counters, per-shard metrics, and the
    /// fleet-level e2e percentiles.
    pub fn metrics_snapshot(&self) -> FleetMetrics {
        let st = self.state.lock().unwrap();
        let per_shard = Self::per_shard_metrics(&st);
        let per_model = Self::fleet_per_model(&st, &per_shard);
        FleetMetrics {
            stats: Self::census(&st),
            per_shard,
            e2e_latency: st.e2e.clone(),
            per_model,
            wall: self.t0.elapsed(),
        }
    }

    /// Graceful fleet shutdown: close the front door, let the monitor
    /// resolve every outstanding fleet ticket (draining live shards,
    /// failing over any shard that dies on the way out), join every
    /// session, and return the final fleet metrics.
    pub fn shutdown(mut self) -> Result<FleetMetrics> {
        self.close();
        let mut st = self.state.lock().unwrap();
        for s in st.shards.iter_mut() {
            if let Some(h) = s.handle.take() {
                let m = h.shutdown()?;
                if s.final_metrics.is_none() {
                    s.final_metrics = Some(m);
                }
            }
        }
        let per_shard = Self::per_shard_metrics(&st);
        let per_model = Self::fleet_per_model(&st, &per_shard);
        let metrics = FleetMetrics {
            stats: Self::census(&st),
            per_shard,
            e2e_latency: st.e2e.clone(),
            per_model,
            wall: self.t0.elapsed(),
        };
        drop(st);
        Ok(metrics)
    }

    /// Close admission, start draining every live shard, and join the
    /// monitor (which exits only once no fleet ticket is outstanding).
    fn close(&mut self) {
        {
            let mut st = self.state.lock().unwrap();
            st.draining = true;
            for s in st.shards.iter() {
                if s.state == ShardState::Live {
                    if let Some(h) = s.handle.as_ref() {
                        h.begin_shutdown();
                    }
                }
            }
        }
        self.stop.store(true, Ordering::Relaxed);
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
    }

    fn census(st: &FleetState) -> FleetStats {
        let mut s = st.stats;
        s.shards = st.shards.len();
        for sh in &st.shards {
            match sh.state {
                ShardState::Live => s.live += 1,
                ShardState::Preempting => s.preempting += 1,
                ShardState::Dead => s.dead += 1,
                ShardState::Drained => s.drained += 1,
            }
        }
        s
    }

    /// Fleet per-model rows: front-door delivered/failed counts and e2e
    /// percentiles (recorded by [`Self::deliver`], failover included)
    /// plus executed steps summed over the shards — retries count, so a
    /// failed-over request's duplicate steps are visible here.
    fn fleet_per_model(st: &FleetState, per_shard: &[ServeMetrics]) -> Vec<ModelMetrics> {
        let mut rows = st.per_model.clone();
        for m in per_shard {
            for (row, sm) in rows.iter_mut().zip(&m.per_model) {
                row.steps_done += sm.steps_done;
            }
        }
        rows
    }

    fn per_shard_metrics(st: &FleetState) -> Vec<ServeMetrics> {
        st.shards
            .iter()
            .map(|sh| match (&sh.handle, &sh.final_metrics) {
                (_, Some(m)) => m.clone(),
                (Some(h), None) => h.metrics_snapshot(),
                (None, None) => ServeMetrics::new(),
            })
            .collect()
    }

    // ------------------------------------------------------------ monitor

    fn monitor_main(
        state: Arc<Mutex<FleetState>>,
        stop: Arc<AtomicBool>,
        heartbeat: Duration,
        misses_allowed: u64,
        pump_interval: Duration,
        preempt_file: Option<PathBuf>,
    ) {
        let mut last_hb = Instant::now();
        // the sentinel fires at most once per fleet lifetime
        let mut preempt_armed = preempt_file.is_some();
        loop {
            let done = {
                let mut st = state.lock().unwrap();
                if last_hb.elapsed() >= heartbeat {
                    last_hb = Instant::now();
                    Self::sample_heartbeats(&mut st, misses_allowed);
                    if preempt_armed {
                        if let Some(path) = preempt_file.as_deref() {
                            if Self::poll_preempt_sentinel(&mut st, path) {
                                preempt_armed = false;
                            }
                        }
                    }
                }
                let draining = st.draining;
                Self::pump(&mut st, draining);
                Self::finish_drained(&mut st);
                stop.load(Ordering::Relaxed) && st.pending.is_empty()
            };
            if done {
                break;
            }
            std::thread::sleep(pump_interval);
        }
    }

    /// Spot-interruption sentinel (ISSUE 10): when `serve.preempt_file`
    /// appears, read the target shard index from its contents (an empty
    /// or whitespace file means shard 0) and begin a preemption drain on
    /// it — the file-based analogue of a cloud instance reclaim notice.
    /// Returns true once the sentinel has been consumed (the file fires
    /// at most once; malformed contents or an out-of-range / non-Live
    /// shard consume it without action).
    fn poll_preempt_sentinel(st: &mut FleetState, path: &std::path::Path) -> bool {
        let Ok(text) = std::fs::read_to_string(path) else {
            return false; // not present (or unreadable) yet
        };
        let trimmed = text.trim();
        let shard = if trimmed.is_empty() {
            0
        } else {
            match trimmed.parse::<usize>() {
                Ok(s) => s,
                Err(_) => return true, // malformed: consume, no action
            }
        };
        if shard < st.shards.len() && st.shards[shard].state == ShardState::Live {
            st.shards[shard].state = ShardState::Preempting;
            if let Some(h) = st.shards[shard].handle.as_ref() {
                h.begin_shutdown();
            }
        }
        true
    }

    /// One monitor pass over the pending set: deliver resolved tickets,
    /// turn lost tickets into dead-shard declarations (which strip and
    /// requeue), and (re-)admit unassigned requests onto live shards.
    fn pump(st: &mut FleetState, draining: bool) {
        // 1) Poll assigned tickets.
        let mut dead: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < st.pending.len() {
            let poll = match st.pending[i].ticket.as_mut() {
                Some(t) => t.poll(),
                None => {
                    i += 1;
                    continue;
                }
            };
            match poll {
                TicketPoll::Pending => i += 1,
                TicketPoll::Ready(r) => {
                    let p = st.pending.swap_remove(i);
                    Self::deliver(st, p, r);
                }
                TicketPoll::Lost => {
                    // the assigned shard dropped this ticket unresolved —
                    // the shard is dead; declare_dead strips the rest
                    if !dead.contains(&st.pending[i].shard) {
                        dead.push(st.pending[i].shard);
                    }
                    i += 1;
                }
            }
        }
        for s in dead {
            Self::declare_dead(st, s);
        }
        // 2) (Re-)admit unassigned requests.
        let mut i = 0;
        while i < st.pending.len() {
            if st.pending[i].ticket.is_some() {
                i += 1;
                continue;
            }
            let req = st.pending[i].req.clone();
            match Self::assign(st, &req) {
                Ok((shard, ticket)) => {
                    st.pending[i].shard = shard;
                    st.pending[i].ticket = Some(ticket);
                    i += 1;
                }
                Err(AdmissionError::QueueFull) | Err(AdmissionError::ShuttingDown)
                    if !draining =>
                {
                    // transient: a queue will free up, or the heartbeat
                    // monitor will soon retire the shard; retry next pump
                    i += 1;
                }
                Err(e) => {
                    let p = st.pending.swap_remove(i);
                    let req_id = p.req.id();
                    Self::deliver(
                        st,
                        p,
                        Err(anyhow!("request {req_id}: not re-admittable after failover ({e})")),
                    );
                }
            }
        }
    }

    /// Resolve one fleet ticket (single-shot) and account for it, on the
    /// fleet aggregate and on the request's per-model row.
    fn deliver(st: &mut FleetState, p: Pending, r: Result<DenoiseResult>) {
        let row = &mut st.per_model[p.req.model().index()];
        match r {
            Ok(res) => {
                st.stats.delivered += 1;
                row.requests_done += 1;
                let us = p.submitted_at.elapsed().as_micros() as f64;
                row.e2e_latency.record_us(us);
                st.e2e.record_us(us);
                let _ = p.tx.send(Ok(res));
            }
            Err(e) => {
                st.stats.failed += 1;
                row.requests_failed += 1;
                let _ = p.tx.send(Err(e));
            }
        }
    }

    /// Declare a shard dead: hard-close its queue, salvage any results it
    /// already delivered, and mark everything else for re-admission.
    fn declare_dead(st: &mut FleetState, shard: usize) {
        if !matches!(
            st.shards[shard].state,
            ShardState::Live | ShardState::Preempting
        ) {
            return;
        }
        st.shards[shard].state = ShardState::Dead;
        st.stats.failovers += 1;
        if let Some(h) = st.shards[shard].handle.as_ref() {
            h.kill();
        }
        let mut i = 0;
        while i < st.pending.len() {
            if st.pending[i].shard != shard || st.pending[i].ticket.is_none() {
                i += 1;
                continue;
            }
            // a result the dying shard already sent still counts — keep
            // it instead of re-running
            if let Some(TicketPoll::Ready(r)) = st.pending[i].ticket.as_mut().map(Ticket::poll) {
                let p = st.pending.swap_remove(i);
                Self::deliver(st, p, r);
                continue;
            }
            st.pending[i].ticket = None;
            st.stats.requeued += 1;
            i += 1;
        }
    }

    /// A `Preempting` shard with no assigned pending work has finished
    /// its drain: join the session and park it as `Drained`.
    fn finish_drained(st: &mut FleetState) {
        for idx in 0..st.shards.len() {
            if st.shards[idx].state != ShardState::Preempting {
                continue;
            }
            let busy = st
                .pending
                .iter()
                .any(|p| p.ticket.is_some() && p.shard == idx);
            if busy {
                continue;
            }
            st.shards[idx].state = ShardState::Drained;
            if let Some(h) = st.shards[idx].handle.take() {
                if let Ok(m) = h.shutdown() {
                    st.shards[idx].final_metrics = Some(m);
                }
            }
        }
    }

    /// Sample every routable shard's heartbeat sequence; a sequence
    /// frozen for `allowed` consecutive samples retires the shard. With
    /// lanes beating at least once per period and `allowed >= 2`, a live
    /// idle shard can never be falsely retired by sampling phase alone.
    fn sample_heartbeats(st: &mut FleetState, allowed: u64) {
        let mut retire: Vec<usize> = Vec::new();
        for (i, s) in st.shards.iter_mut().enumerate() {
            if !matches!(s.state, ShardState::Live | ShardState::Preempting) {
                continue;
            }
            let seq = s.pulse.seq();
            if seq == s.last_seq {
                s.misses += 1;
                if s.misses >= allowed {
                    retire.push(i);
                }
            } else {
                s.last_seq = seq;
                s.misses = 0;
            }
        }
        for i in retire {
            Self::declare_dead(st, i);
        }
    }
}

impl Drop for ShardFleet {
    fn drop(&mut self) {
        if self.monitor.is_some() {
            self.close();
        }
        let mut st = self.state.lock().unwrap();
        for s in st.shards.iter_mut() {
            // dropping a ServerHandle drains and joins the session
            drop(s.handle.take());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeBackend;
    use crate::coordinator::server::workload;

    fn fleet_cfg(shards: usize) -> ServeConfig {
        ServeConfig {
            steps: 2,
            requests: 0,
            workers: 1,
            max_batch: 2,
            seed: 11,
            artifact: "unet_denoise_16".into(),
            cosim: false,
            fused: false,
            backend: ServeBackend::Native,
            batched: true,
            pipeline: false,
            // per-step dispatches keep the heartbeat gap to one step
            chunk: 1,
            pooled: true,
            queue_depth: 64,
            priorities: 2,
            shards,
            heartbeat_ms: 10,
            heartbeat_misses: 8,
            ..ServeConfig::default()
        }
    }

    fn store() -> ArtifactStore {
        ArtifactStore::new("artifacts")
    }

    #[test]
    fn fleet_serves_everything_with_no_faults() {
        let cfg = fleet_cfg(2);
        let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
        let tickets: Vec<FleetTicket> = workload(&cfg, cfg.seed, 0..6)
            .into_iter()
            .map(|r| fleet.submit(r).unwrap())
            .collect();
        let mut ids: Vec<u64> = tickets.into_iter().map(|t| t.wait().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.stats.submitted, 6);
        assert_eq!(m.stats.delivered, 6);
        assert_eq!(m.stats.failed, 0);
        assert_eq!(m.stats.failovers, 0);
        assert_eq!(m.stats.requeued, 0);
        assert_eq!(m.stats.shards, 2);
        assert_eq!(m.e2e_latency.count(), 6);
        // both shards produced final (joined) metrics
        assert_eq!(m.per_shard.len(), 2);
        let done: usize = m.per_shard.iter().map(|s| s.requests_done).sum();
        assert_eq!(done, 6);
    }

    #[test]
    fn kill_shard_fails_over_without_losing_tickets() {
        let cfg = fleet_cfg(2);
        let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
        let tickets: Vec<FleetTicket> = workload(&cfg, cfg.seed, 0..8)
            .into_iter()
            .map(|r| fleet.submit(r).unwrap())
            .collect();
        fleet.kill_shard(0).unwrap();
        for t in tickets {
            t.wait().unwrap();
        }
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.stats.delivered, 8);
        assert_eq!(m.stats.failed, 0);
        assert_eq!(m.stats.failovers, 1);
        assert_eq!(m.stats.dead, 1);
    }

    #[test]
    fn all_shards_dead_reports_no_live_shards() {
        let cfg = fleet_cfg(2);
        let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
        fleet.kill_shard(0).unwrap();
        fleet.kill_shard(1).unwrap();
        let err = fleet.submit(DenoiseRequest::new(0, 1, 2)).unwrap_err();
        assert_eq!(err, AdmissionError::NoLiveShards);
        assert_eq!(
            fleet.shard_states(),
            vec![ShardState::Dead, ShardState::Dead]
        );
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.stats.dead, 2);
        assert_eq!(m.stats.live, 0);
    }

    #[test]
    fn preempt_drains_to_drained_state() {
        let cfg = fleet_cfg(2);
        let fleet = ShardFleet::start(cfg.clone(), &store()).unwrap();
        let tickets: Vec<FleetTicket> = workload(&cfg, cfg.seed, 0..4)
            .into_iter()
            .map(|r| fleet.submit(r).unwrap())
            .collect();
        fleet.begin_preempt(0).unwrap();
        // double preemption of the same shard is an error
        assert!(fleet.begin_preempt(0).is_err());
        for t in tickets {
            t.wait().unwrap();
        }
        // the monitor parks the drained shard asynchronously
        let deadline = Instant::now() + Duration::from_secs(10);
        while fleet.shard_states()[0] != ShardState::Drained {
            assert!(Instant::now() < deadline, "shard 0 never drained");
            std::thread::sleep(Duration::from_millis(1));
        }
        // the survivor still serves
        let t = fleet.submit(DenoiseRequest::new(99, 99, 2)).unwrap();
        assert_eq!(t.wait().unwrap().id, 99);
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.stats.drained, 1);
        assert_eq!(m.stats.live, 1);
        assert_eq!(m.stats.delivered, 5);
        assert_eq!(m.stats.failed, 0);
    }

    #[test]
    fn fleet_ticket_try_wait_is_single_shot() {
        let cfg = fleet_cfg(1);
        let fleet = ShardFleet::start(cfg, &store()).unwrap();
        let mut t = fleet.submit(DenoiseRequest::new(7, 7, 2)).unwrap();
        let r = loop {
            if let Some(r) = t.try_wait() {
                break r;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(r.unwrap().id, 7);
        // spent: second poll reports the consumed error
        let again = t.try_wait().expect("spent ticket must resolve");
        assert!(again.unwrap_err().to_string().contains("already consumed"));
        fleet.shutdown().unwrap();
    }

    #[test]
    fn p2c_candidates_are_distinct_and_uniform() {
        // Regression (ISSUE 7): both candidates used to be independent
        // draws over the live set, so a == b with probability 1/n. The
        // distinct-draw property must hold on every draw, and the second
        // candidate must still reach every slot other than the first.
        for n in 2..=8usize {
            let mut rng = Rng::new(0xdead ^ n as u64);
            let mut pair_seen = vec![vec![false; n]; n];
            for _ in 0..2_000 {
                let (a, b) = ShardFleet::p2c_candidates(&mut rng, n);
                assert_ne!(a, b, "n = {n}: p2c drew the same shard twice");
                assert!(a < n && b < n);
                pair_seen[a][b] = true;
            }
            for a in 0..n {
                for b in 0..n {
                    if a == b {
                        assert!(!pair_seen[a][b]);
                    } else {
                        assert!(
                            pair_seen[a][b],
                            "n = {n}: ordered pair ({a}, {b}) never drawn"
                        );
                    }
                }
            }
        }
        // the degenerate single-shard fleet keeps returning the only slot
        let mut rng = Rng::new(1);
        assert_eq!(ShardFleet::p2c_candidates(&mut rng, 1), (0, 0));
    }

    #[test]
    fn out_of_range_shard_ops_error() {
        let fleet = ShardFleet::start(fleet_cfg(1), &store()).unwrap();
        assert!(fleet.kill_shard(5).is_err());
        assert!(fleet.begin_preempt(5).is_err());
        fleet.shutdown().unwrap();
    }
}
