//! DDPM (Ho et al. 2020) reverse-process schedule.
//!
//! The coordinator owns the schedule; each reverse step feeds the AOT
//! artifact three scalars:
//!
//! * `c1 = 1 / sqrt(alpha_t)`
//! * `c2 = beta_t / sqrt(1 - alpha_bar_t)`
//! * `sigma_t = sqrt(beta_t)` (posterior-variance choice), 0 at t = 0
//!
//! so `x_{t-1} = c1 * (x_t - c2 * eps_theta(x_t, t)) + sigma_t * z`.

/// Precomputed schedule for `t_max` steps.
#[derive(Debug, Clone)]
pub struct DdpmSchedule {
    /// Per-step noise variances `beta_t`.
    pub betas: Vec<f64>,
    /// `alpha_t = 1 - beta_t`.
    pub alphas: Vec<f64>,
    /// Cumulative products `alpha_bar_t = prod(alpha_0..=alpha_t)`.
    pub alpha_bars: Vec<f64>,
}

impl DdpmSchedule {
    /// Linear beta schedule from `beta_lo` to `beta_hi` (DDPM defaults:
    /// 1e-4 .. 0.02 over 1000 steps; scaled ranges work for fewer steps).
    pub fn linear(t_max: usize, beta_lo: f64, beta_hi: f64) -> Self {
        assert!(t_max >= 1);
        assert!(0.0 < beta_lo && beta_lo <= beta_hi && beta_hi < 1.0);
        let betas: Vec<f64> = (0..t_max)
            .map(|t| {
                if t_max == 1 {
                    beta_lo
                } else {
                    beta_lo + (beta_hi - beta_lo) * t as f64 / (t_max - 1) as f64
                }
            })
            .collect();
        let alphas: Vec<f64> = betas.iter().map(|b| 1.0 - b).collect();
        let mut alpha_bars = Vec::with_capacity(t_max);
        let mut acc = 1.0;
        for a in &alphas {
            acc *= a;
            alpha_bars.push(acc);
        }
        Self {
            betas,
            alphas,
            alpha_bars,
        }
    }

    /// Standard schedule for `t_max` steps.
    pub fn standard(t_max: usize) -> Self {
        Self::linear(t_max, 1e-4, 0.02)
    }

    /// Number of steps in the schedule.
    pub fn t_max(&self) -> usize {
        self.betas.len()
    }

    /// Reverse-step coefficients `(c1, c2, sigma)` for step `t`.
    pub fn coefficients(&self, t: usize) -> (f32, f32, f32) {
        assert!(t < self.t_max());
        let c1 = 1.0 / self.alphas[t].sqrt();
        let c2 = self.betas[t] / (1.0 - self.alpha_bars[t]).sqrt();
        let sigma = if t == 0 { 0.0 } else { self.betas[t].sqrt() };
        (c1 as f32, c2 as f32, sigma as f32)
    }

    /// Forward-process factors for adding noise at level `t`:
    /// `x_t = sqrt(alpha_bar_t) * x_0 + sqrt(1 - alpha_bar_t) * eps`.
    pub fn forward_factors(&self, t: usize) -> (f32, f32) {
        let ab = self.alpha_bars[t];
        (ab.sqrt() as f32, (1.0 - ab).sqrt() as f32)
    }
}

/// Sinusoidal time embedding — must match `python/compile/model.py::
/// time_embedding` exactly (the artifact was lowered against it).
pub fn time_embedding(t: f32, dim: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; dim];
    time_embedding_into(t, &mut out);
    out
}

/// [`time_embedding`] into a caller slab (`out.len()` is the embedding
/// dimension) — the allocation-free variant the pooled serving lane
/// uses; identical values.
pub fn time_embedding_into(t: f32, out: &mut [f32]) {
    let dim = out.len();
    // dim == 2 would make half - 1 == 0 and the frequency expression
    // 0/0 = NaN, so fail fast instead of denoising with NaN embeddings
    assert!(
        dim >= 4 && dim % 2 == 0,
        "time embedding dim must be even and >= 4, got {dim}"
    );
    let half = dim / 2;
    for i in 0..half {
        let freq = (-(10000.0f64.ln()) * i as f64 / (half - 1) as f64).exp();
        let ang = t as f64 * freq;
        out[i] = ang.sin() as f32;
        out[half + i] = ang.cos() as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_monotone() {
        let s = DdpmSchedule::standard(100);
        assert_eq!(s.t_max(), 100);
        for t in 1..100 {
            assert!(s.betas[t] >= s.betas[t - 1]);
            assert!(s.alpha_bars[t] < s.alpha_bars[t - 1]);
        }
        assert!(s.alpha_bars[99] > 0.0 && s.alpha_bars[99] < 1.0);
    }

    #[test]
    fn coefficients_sane() {
        let s = DdpmSchedule::standard(50);
        let (c1, c2, sigma0) = s.coefficients(0);
        assert!(c1 >= 1.0 && c1 < 1.1);
        assert!(c2 > 0.0);
        assert_eq!(sigma0, 0.0, "no noise injected at the last step");
        let (_, _, sigma_mid) = s.coefficients(25);
        assert!(sigma_mid > 0.0);
    }

    #[test]
    fn forward_factors_interpolate() {
        let s = DdpmSchedule::standard(100);
        let (a0, b0) = s.forward_factors(0);
        let (a99, b99) = s.forward_factors(99);
        assert!(a0 > a99, "signal decays with t");
        assert!(b0 < b99, "noise grows with t");
        for t in 0..100 {
            let (a, b) = s.forward_factors(t);
            assert!((a * a + b * b - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn time_embedding_matches_python_formula() {
        // spot-check against numpy-computed values for t=3, dim=8:
        // freqs = exp(-ln(1e4) * [0,1,2,3] / 3)
        let e = time_embedding(3.0, 8);
        let freqs: Vec<f64> = (0..4)
            .map(|i| (-(10000.0f64.ln()) * i as f64 / 3.0).exp())
            .collect();
        for i in 0..4 {
            let ang = 3.0 * freqs[i];
            assert!((e[i] as f64 - ang.sin()).abs() < 1e-6, "sin {i}");
            assert!((e[4 + i] as f64 - ang.cos()).abs() < 1e-6, "cos {i}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_bad_beta_range() {
        let _ = DdpmSchedule::linear(10, 0.5, 0.2);
    }
}
