//! L3 coordinator: the diffusion-model *serving* layer.
//!
//! The paper pitches SF-MMCN as a diffusion accelerator: "the accelerator
//! has to conduct thousands ... of times to get the output figure" (§II).
//! This module is the system around that loop:
//!
//! * [`ddpm`] — the DDPM beta schedule and per-step coefficients (owned by
//!   rust; the AOT artifact takes them as scalar inputs, so the python
//!   side never needs re-lowering to change schedules).
//! * [`params`] — loads `artifacts/unet_params.{bin,manifest}` into the
//!   input layout the artifact expects.
//! * [`server`] — request queue → batcher → worker threads, each owning a
//!   PJRT executor; per-request de-noise loops; co-simulation of the
//!   SF-MMCN accelerator for cycles/energy alongside the functional run.
//! * [`metrics`] — latency histograms + simulated PPA aggregation.
//!
//! Python never runs here: workers execute `artifacts/*.hlo.txt` through
//! the PJRT C API only.

pub mod ddpm;
pub mod metrics;
pub mod params;
pub mod server;

pub use ddpm::DdpmSchedule;
pub use metrics::ServeMetrics;
pub use params::UnetParams;
pub use server::{DenoiseRequest, DenoiseResult, DiffusionServer};
