//! L3 coordinator: the diffusion-model *serving* layer.
//!
//! The paper pitches SF-MMCN as a diffusion accelerator: "the accelerator
//! has to conduct thousands ... of times to get the output figure" (§II).
//! This module is the system around that loop:
//!
//! * [`ddpm`] — the DDPM beta schedule and per-step coefficients (owned by
//!   rust; the AOT artifact takes them as scalar inputs, so the python
//!   side never needs re-lowering to change schedules).
//! * [`params`] — loads `artifacts/unet_params.{bin,manifest}` into the
//!   input layout the artifact expects.
//! * [`server`] — the streaming session API (ISSUE 5): bounded admission
//!   queue with priorities and deadlines → fair batcher → worker lanes,
//!   each a two-stage pipeline (host prep ∥ device execute) owning its
//!   executor; batched `[B, ...]` fused dispatch across the queue;
//!   ticket-based result delivery; graceful drain; co-simulation of the
//!   SF-MMCN accelerator for cycles/energy alongside the functional run
//!   (micro-sim for batched traffic, analytic otherwise). Since ISSUE 7
//!   the request path is multi-mode — [`server::InferenceRequest`] covers
//!   U-net denoise plus ResNet-18 / VGG-16 classification, batches never
//!   mix models, and metrics carry per-model rows — mirroring the paper's
//!   multi-mode CNN operation of one engine serving U-net, ResNet-18 and
//!   VGG-16.
//! * [`fleet`] — the fault-tolerant sharded front door (ISSUE 6): a
//!   [`fleet::ShardFleet`] owns N independent serving sessions (shards),
//!   routes with power-of-two-choices on live queue depth, watches shard
//!   health via heartbeat sequence numbers, and on a dead shard re-admits
//!   every undelivered ticket onto survivors. Request execution is a pure
//!   function of `(model, seed, steps)`, so a failover run is
//!   bit-identical to a no-fault run.
//! * [`faults`] — the seeded, schedulable fault-injection plane that
//!   drives every recovery scenario reproducibly (kill-shard-at-request,
//!   stall-lane, panic-in-step, delayed delivery).
//! * [`traffic`] — arrival-process realism (ISSUE 8): seeded
//!   Ornstein–Uhlenbeck / burst / ramp / sinusoid rate profiles behind
//!   the `serve.traffic` grammar, plus the JSON-lines trace
//!   record/replay format that makes any open-loop incident reproduce
//!   bit-for-bit from a seed or a trace file.
//! * [`wire`] / [`proc`] / [`cluster`] — multi-process cluster serving
//!   (ISSUE 10): a length-prefixed, versioned frame protocol over Unix
//!   domain sockets ([`wire`]), a process supervisor that spawns and
//!   health-checks `shard-worker` child processes ([`proc`]), and the
//!   [`cluster::ClusterFleet`] front door that mirrors the in-process
//!   [`fleet::ShardFleet`] API across process boundaries — p2c routing
//!   on reported queue depth, wire heartbeat monitoring,
//!   respawn-or-retire on worker death, and deterministic failover
//!   re-admission (same bit-identical contract as the fleet).
//! * [`metrics`] — latency histograms, fixed-memory streaming
//!   percentiles, admission/batching/pipeline counters, fleet-level
//!   failover counters, and simulated PPA aggregation.
//!
//! Python never runs here: workers execute `artifacts/*.hlo.txt` through
//! the PJRT C API (or the offline native surrogate — see
//! `crate::runtime::NativeDenoise`).

#[cfg(unix)]
pub mod cluster;
pub mod ddpm;
pub mod faults;
pub mod fleet;
pub mod metrics;
pub mod params;
#[cfg(unix)]
pub mod proc;
pub mod server;
pub mod traffic;
pub mod wire;

#[cfg(unix)]
pub use cluster::ClusterFleet;
pub use ddpm::DdpmSchedule;
pub use faults::{FaultAction, FaultEvent, FaultKind, FaultPlane, FaultSpec};
pub use fleet::{FleetTicket, ShardFleet, ShardState};
pub use metrics::{AdmissionStats, FleetMetrics, FleetStats, ModelMetrics, ServeMetrics};
pub use params::UnetParams;
pub use server::{
    workload, AdmissionError, ClassifyRequest, DenoiseRequest, DenoiseResult, DiffusionServer,
    InferenceRequest, ServerHandle, ShardPulse, Ticket, TicketPoll,
};
pub use traffic::{
    parse_trace, read_trace, recorded_workload, render_trace, write_trace, TraceRecord,
    TrafficProfile,
};
