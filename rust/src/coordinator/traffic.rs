//! Traffic realism (ISSUE 8): seeded arrival-process generators beyond
//! constant-rate Poisson, plus a trace record/replay format so any
//! incident reproduces bit-for-bit from a seed or a trace file.
//!
//! Production serving traffic is not a fixed-rate drip: it has diurnal
//! cycles, flash crowds, and slow drifts. This module models those as a
//! time-varying arrival *intensity* `rate(t)` (requests per second) and
//! generates arrival offsets by the intensity time-change method:
//! integrate `rate(t)` and emit an arrival each time the cumulative
//! intensity crosses a threshold — unit-spaced thresholds for the
//! deterministic profiles, Exp(1)-spaced thresholds for the stochastic
//! ones (which makes them inhomogeneous Poisson processes).
//!
//! Profiles ([`TrafficProfile`]), spelled in a colon grammar mirroring
//! `--fault-spec`:
//!
//! * `uniform:RATE` — fixed inter-arrival gap `1/RATE`, first arrival at
//!   t = 0. Matches the historical `serve --open-loop --rate` schedule.
//! * `poisson:RATE` — homogeneous Poisson: i.i.d. exponential gaps.
//! * `ou:MEAN:THETA:SIGMA` — the rate itself follows a mean-reverting
//!   Ornstein–Uhlenbeck process (Euler–Maruyama on a fixed
//!   [`OU_GRID_S`] grid, clamped to the band reported by
//!   [`TrafficProfile::ou_bounds`]); arrivals are Poisson at the
//!   current rate. `THETA` is the reversion rate (1/s), `SIGMA` the
//!   volatility (req/s per √s). This is the load analogue of the
//!   OU spot-price models used for preemption studies.
//! * `burst:BASE:PEAK:PERIOD_MS:BURST_MS` — deterministic square wave:
//!   `PEAK` req/s for the first `BURST_MS` of every `PERIOD_MS`, `BASE`
//!   otherwise. Flash-crowd shape.
//! * `ramp:FROM:TO:RAMP_MS` — linear ramp from `FROM` to `TO` over
//!   `RAMP_MS`, then steady at `TO`. Launch-day shape.
//! * `sine:BASE:AMP:PERIOD_MS` — `BASE + AMP·sin(2πt/PERIOD)`, the
//!   diurnal cycle compressed to a benchable period.
//!
//! Everything is deterministic given `(spec, seed)`: the same spec
//! string and seed always yield the same arrival schedule, and
//! [`TrafficProfile::rate_trace`] exposes the exact OU rate path the
//! schedule integrated. Parsing and rendering are inverses
//! (`parse(render(p)) == p`), so a spec survives a round trip through
//! config files, CLI flags, and `BENCH_scale.json` cells.
//!
//! The trace format ([`TraceRecord`]) is one compact JSON object per
//! line: `(arrival_ns, request)` via `util/json_lite`. Request seeds are
//! serialized as decimal *strings* because the JSON parser reads numbers
//! through `f64` (exact only to 2^53) and workload seeds span the full
//! `u64` range; `arrival_ns` / `id` / `deadline_ns` stay plain numbers
//! and are validated against the 2^53 exactness bound (2^53 ns ≈ 104
//! days of arrival offset). Replaying a trace re-submits the identical
//! request sequence, and because request execution is a pure function of
//! `(model, seed, steps)`, the replayed results are bit-identical to the
//! recorded run's.

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelChoice, ServeConfig};
use crate::coordinator::server::{workload, ClassifyRequest, DenoiseRequest, InferenceRequest};
use crate::util::json_lite::Json;
use crate::util::Rng;

/// Spacing (seconds) of the Ornstein–Uhlenbeck rate grid: the OU rate
/// path advances one Euler–Maruyama step per grid cell and is held
/// constant within a cell, so per-cell intensity integration is exact.
pub const OU_GRID_S: f64 = 0.01;

/// Integration step (seconds) for the deterministic time-varying
/// profiles (burst / ramp / sine): the rate is treated as constant over
/// each step and arrival instants are linearly interpolated within it.
const INTEGRATE_DT_S: f64 = 1e-3;

/// Stream-splitting constant: the arrival-threshold RNG is seeded with
/// `seed ^ ARRIVAL_STREAM` so it never shares draws with the rate-path
/// RNG (seeded with `seed`), keeping [`TrafficProfile::rate_trace`]
/// exactly the path [`TrafficProfile::schedule`] integrates.
const ARRIVAL_STREAM: u64 = 0x9E37_79B9_7F4A_7C15;

/// Largest integer exactly representable in an `f64` (2^53): the bound
/// for numeric fields in the JSON trace format.
const MAX_EXACT: f64 = 9_007_199_254_740_992.0;

/// A seeded arrival-rate profile: how request arrival times are spread
/// over wall-clock time. Parsed from the `serve.traffic` config key or
/// the `--traffic` CLI flag; see the module docs for the grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum TrafficProfile {
    /// `uniform:RATE` — fixed inter-arrival gap, first arrival at t = 0.
    Uniform {
        /// Arrival rate, requests per second.
        rate: f64,
    },
    /// `poisson:RATE` — homogeneous Poisson (i.i.d. exponential gaps).
    Poisson {
        /// Mean arrival rate, requests per second.
        rate: f64,
    },
    /// `ou:MEAN:THETA:SIGMA` — mean-reverting Ornstein–Uhlenbeck rate
    /// modulation driving an inhomogeneous Poisson arrival process.
    Ou {
        /// Long-run mean rate, requests per second.
        mean: f64,
        /// Mean-reversion rate, 1/seconds (larger = snappier reversion).
        theta: f64,
        /// Volatility, requests per second per √second.
        sigma: f64,
    },
    /// `burst:BASE:PEAK:PERIOD_MS:BURST_MS` — deterministic square-wave
    /// flash crowds.
    Burst {
        /// Off-burst rate, requests per second.
        base: f64,
        /// In-burst rate, requests per second (≥ `base`).
        peak: f64,
        /// Full cycle length, milliseconds.
        period_ms: f64,
        /// Burst duration at the start of each cycle, milliseconds
        /// (in `(0, period_ms]`).
        burst_ms: f64,
    },
    /// `ramp:FROM:TO:RAMP_MS` — linear ramp, then steady at `TO`.
    Ramp {
        /// Rate at t = 0, requests per second.
        from: f64,
        /// Rate from `ramp_ms` onward, requests per second.
        to: f64,
        /// Ramp duration, milliseconds.
        ramp_ms: f64,
    },
    /// `sine:BASE:AMP:PERIOD_MS` — sinusoidal (diurnal) modulation
    /// `BASE + AMP·sin(2πt/PERIOD)`.
    Sine {
        /// Mean rate, requests per second.
        base: f64,
        /// Modulation amplitude, requests per second (in `[0, base]` so
        /// the rate never goes negative).
        amp: f64,
        /// Cycle length, milliseconds.
        period_ms: f64,
    },
}

impl TrafficProfile {
    /// Parse a traffic spec string (see the module docs for the
    /// grammar). Errors name the offending key — `bad theta`, `unknown
    /// profile`, … — and always quote the full spec.
    pub fn parse(spec: &str) -> Result<Self> {
        let s = spec.trim();
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        let kind = parts[0];
        let (arity, usage) = match kind {
            "uniform" => (1, "uniform:RATE"),
            "poisson" => (1, "poisson:RATE"),
            "ou" => (3, "ou:MEAN:THETA:SIGMA"),
            "burst" => (4, "burst:BASE:PEAK:PERIOD_MS:BURST_MS"),
            "ramp" => (3, "ramp:FROM:TO:RAMP_MS"),
            "sine" => (3, "sine:BASE:AMP:PERIOD_MS"),
            other => bail!(
                "traffic spec `{s}`: unknown profile `{other}` \
                 (expected uniform | poisson | ou | burst | ramp | sine)"
            ),
        };
        if parts.len() - 1 != arity {
            bail!(
                "traffic spec `{s}`: expected `{usage}`, got {} arg(s)",
                parts.len() - 1
            );
        }
        let field = |i: usize, key: &str| -> Result<f64> {
            let raw = parts[i];
            let v: f64 = raw.parse().map_err(|_| {
                anyhow!("traffic spec `{s}`: bad {key} `{raw}` (expected a number)")
            })?;
            if !v.is_finite() {
                bail!("traffic spec `{s}`: bad {key} `{raw}` (must be finite)");
            }
            Ok(v)
        };
        let check = |ok: bool, msg: &str| -> Result<()> {
            if ok {
                Ok(())
            } else {
                bail!("traffic spec `{s}`: {msg}")
            }
        };
        let profile = match kind {
            "uniform" => {
                let rate = field(1, "rate")?;
                check(rate > 0.0, "rate must be positive")?;
                TrafficProfile::Uniform { rate }
            }
            "poisson" => {
                let rate = field(1, "rate")?;
                check(rate > 0.0, "rate must be positive")?;
                TrafficProfile::Poisson { rate }
            }
            "ou" => {
                let mean = field(1, "mean")?;
                let theta = field(2, "theta")?;
                let sigma = field(3, "sigma")?;
                check(mean > 0.0, "mean must be positive")?;
                check(theta > 0.0, "theta must be positive")?;
                check(sigma >= 0.0, "sigma must be >= 0")?;
                TrafficProfile::Ou { mean, theta, sigma }
            }
            "burst" => {
                let base = field(1, "base")?;
                let peak = field(2, "peak")?;
                let period_ms = field(3, "period_ms")?;
                let burst_ms = field(4, "burst_ms")?;
                check(base > 0.0, "base must be positive")?;
                check(peak >= base, "peak must be >= base")?;
                check(period_ms > 0.0, "period_ms must be positive")?;
                check(
                    burst_ms > 0.0 && burst_ms <= period_ms,
                    "burst_ms must be in (0, period_ms]",
                )?;
                TrafficProfile::Burst {
                    base,
                    peak,
                    period_ms,
                    burst_ms,
                }
            }
            "ramp" => {
                let from = field(1, "from")?;
                let to = field(2, "to")?;
                let ramp_ms = field(3, "ramp_ms")?;
                check(from > 0.0, "from must be positive")?;
                check(to > 0.0, "to must be positive")?;
                check(ramp_ms > 0.0, "ramp_ms must be positive")?;
                TrafficProfile::Ramp { from, to, ramp_ms }
            }
            "sine" => {
                let base = field(1, "base")?;
                let amp = field(2, "amp")?;
                let period_ms = field(3, "period_ms")?;
                check(base > 0.0, "base must be positive")?;
                check(
                    (0.0..=base).contains(&amp),
                    "amp must be in [0, base] (the rate may not go negative)",
                )?;
                check(period_ms > 0.0, "period_ms must be positive")?;
                TrafficProfile::Sine {
                    base,
                    amp,
                    period_ms,
                }
            }
            _ => unreachable!("kind was validated above"),
        };
        Ok(profile)
    }

    /// Render the canonical spec string: `parse(render(p)) == p` (f64
    /// `Display` is shortest-round-trip, so values survive exactly).
    pub fn render(&self) -> String {
        match self {
            TrafficProfile::Uniform { rate } => format!("uniform:{rate}"),
            TrafficProfile::Poisson { rate } => format!("poisson:{rate}"),
            TrafficProfile::Ou { mean, theta, sigma } => format!("ou:{mean}:{theta}:{sigma}"),
            TrafficProfile::Burst {
                base,
                peak,
                period_ms,
                burst_ms,
            } => format!("burst:{base}:{peak}:{period_ms}:{burst_ms}"),
            TrafficProfile::Ramp { from, to, ramp_ms } => format!("ramp:{from}:{to}:{ramp_ms}"),
            TrafficProfile::Sine {
                base,
                amp,
                period_ms,
            } => format!("sine:{base}:{amp}:{period_ms}"),
        }
    }

    /// Long-run mean arrival rate (req/s): the duty-cycle-weighted rate
    /// for `burst`, the steady-state `to` for `ramp`, the centerline for
    /// `sine`/`ou`. Used to size bench cells against measured capacity.
    pub fn mean_rate(&self) -> f64 {
        match self {
            TrafficProfile::Uniform { rate } | TrafficProfile::Poisson { rate } => *rate,
            TrafficProfile::Ou { mean, .. } => *mean,
            TrafficProfile::Burst {
                base,
                peak,
                period_ms,
                burst_ms,
            } => base + (peak - base) * burst_ms / period_ms,
            TrafficProfile::Ramp { to, .. } => *to,
            TrafficProfile::Sine { base, .. } => *base,
        }
    }

    /// Peak instantaneous target rate (req/s): what the fleet must
    /// absorb at the worst moment. For `ou` this is the upper clamp
    /// bound from [`TrafficProfile::ou_bounds`].
    pub fn peak_rate(&self) -> f64 {
        match self {
            TrafficProfile::Uniform { rate } | TrafficProfile::Poisson { rate } => *rate,
            TrafficProfile::Ou { .. } => self.ou_bounds().expect("ou has bounds").1,
            TrafficProfile::Burst { peak, .. } => *peak,
            TrafficProfile::Ramp { from, to, .. } => from.max(*to),
            TrafficProfile::Sine { base, amp, .. } => base + amp,
        }
    }

    /// Instantaneous target rate (req/s) at `t` seconds for the
    /// deterministic profiles. The stochastic profiles (`poisson`, `ou`)
    /// return their long-run mean level — use
    /// [`TrafficProfile::rate_trace`] for the seeded OU path.
    pub fn rate_at(&self, t: f64) -> f64 {
        match self {
            TrafficProfile::Uniform { rate } | TrafficProfile::Poisson { rate } => *rate,
            TrafficProfile::Ou { mean, .. } => *mean,
            TrafficProfile::Burst {
                base,
                peak,
                period_ms,
                burst_ms,
            } => {
                let phase = t.rem_euclid(period_ms / 1e3);
                if phase < burst_ms / 1e3 {
                    *peak
                } else {
                    *base
                }
            }
            TrafficProfile::Ramp { from, to, ramp_ms } => {
                let ramp_s = ramp_ms / 1e3;
                if t >= ramp_s {
                    *to
                } else {
                    from + (to - from) * (t / ramp_s)
                }
            }
            TrafficProfile::Sine {
                base,
                amp,
                period_ms,
            } => base + amp * (2.0 * std::f64::consts::PI * t / (period_ms / 1e3)).sin(),
        }
    }

    /// Clamp band for the OU rate path: `[0.05·mean, mean + 8·σ/√(2θ)]`
    /// (8 stationary standard deviations above the mean, floored at 5%
    /// of the mean so the rate can neither go negative nor collapse).
    /// `None` for non-OU profiles.
    pub fn ou_bounds(&self) -> Option<(f64, f64)> {
        match self {
            TrafficProfile::Ou { mean, theta, sigma } => Some(ou_bounds(*mean, *theta, *sigma)),
            _ => None,
        }
    }

    /// Sample the modulated rate on the [`OU_GRID_S`] grid. For the OU
    /// profile this is the *exact* seeded path that
    /// [`TrafficProfile::schedule`] integrates (same RNG stream); for
    /// deterministic profiles it samples [`TrafficProfile::rate_at`].
    pub fn rate_trace(&self, seed: u64, points: usize) -> Vec<f64> {
        match self {
            TrafficProfile::Ou { mean, theta, sigma } => {
                let mut rng = Rng::new(seed);
                let (lo, hi) = ou_bounds(*mean, *theta, *sigma);
                let mut x = *mean;
                (0..points)
                    .map(|_| {
                        let cur = x;
                        x = ou_step(x, *mean, *theta, *sigma, lo, hi, &mut rng);
                        cur
                    })
                    .collect()
            }
            _ => (0..points)
                .map(|k| self.rate_at(k as f64 * OU_GRID_S))
                .collect(),
        }
    }

    /// Generate `n` arrival offsets (nanoseconds from session start,
    /// nondecreasing), deterministic in `(self, seed)`.
    pub fn schedule(&self, seed: u64, n: usize) -> Vec<u64> {
        match self {
            TrafficProfile::Uniform { rate } => (0..n)
                .map(|i| (i as f64 / rate * 1e9).round() as u64)
                .collect(),
            TrafficProfile::Poisson { rate } => {
                let mut arr_rng = Rng::new(seed ^ ARRIVAL_STREAM);
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        t += exp1(&mut arr_rng) / rate;
                        (t * 1e9).round() as u64
                    })
                    .collect()
            }
            _ => self.schedule_time_change(seed, n),
        }
    }

    /// Intensity time-change generator for the time-varying profiles:
    /// hold the rate constant over each integration step, accumulate
    /// intensity, and emit an arrival (linearly interpolated within the
    /// step) at every threshold crossing.
    fn schedule_time_change(&self, seed: u64, n: usize) -> Vec<u64> {
        let stochastic = matches!(self, TrafficProfile::Ou { .. });
        let mut rate_rng = Rng::new(seed);
        let mut arr_rng = Rng::new(seed ^ ARRIVAL_STREAM);

        let (mut ou_x, ou_lo, ou_hi) = match self {
            TrafficProfile::Ou { mean, theta, sigma } => {
                let (lo, hi) = ou_bounds(*mean, *theta, *sigma);
                (*mean, lo, hi)
            }
            _ => (0.0, 0.0, 0.0),
        };

        let dt = if stochastic { OU_GRID_S } else { INTEGRATE_DT_S };
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64; // segment start, seconds
        let mut acc = 0.0f64; // cumulative intensity at segment start
        // Deterministic profiles place thresholds at 0, 1, 2, … so the
        // first arrival lands at t = 0 (matching `uniform`); stochastic
        // ones draw Exp(1)-spaced thresholds.
        let mut target = if stochastic { exp1(&mut arr_rng) } else { 0.0 };

        while out.len() < n {
            let seg_rate = if let TrafficProfile::Ou { mean, theta, sigma } = self {
                let cur = ou_x;
                ou_x = ou_step(ou_x, *mean, *theta, *sigma, ou_lo, ou_hi, &mut rate_rng);
                cur
            } else {
                self.rate_at(t)
            };
            let seg_end_acc = acc + seg_rate * dt;
            while out.len() < n && seg_rate > 0.0 && target <= seg_end_acc {
                let cross = t + (target - acc) / seg_rate;
                out.push((cross * 1e9).round() as u64);
                target += if stochastic { exp1(&mut arr_rng) } else { 1.0 };
            }
            acc = seg_end_acc;
            t += dt;
        }
        out
    }
}

/// Standard exponential draw (mean 1). `f64()` is in `[0, 1)` so the
/// argument to `ln` stays in `(0, 1]` — never a NaN/∞.
fn exp1(rng: &mut Rng) -> f64 {
    -(1.0 - rng.f64()).ln()
}

/// One Euler–Maruyama step of the clamped OU rate process on the
/// [`OU_GRID_S`] grid.
fn ou_step(x: f64, mean: f64, theta: f64, sigma: f64, lo: f64, hi: f64, rng: &mut Rng) -> f64 {
    let h = OU_GRID_S;
    let z = rng.normal() as f64;
    (x + theta * (mean - x) * h + sigma * h.sqrt() * z).clamp(lo, hi)
}

fn ou_bounds(mean: f64, theta: f64, sigma: f64) -> (f64, f64) {
    let stationary_sd = if sigma == 0.0 {
        0.0
    } else {
        sigma / (2.0 * theta).sqrt()
    };
    (0.05 * mean, mean + 8.0 * stationary_sd)
}

/// One recorded arrival: when a request hit the front door (nanoseconds
/// from session start) and the request itself. One JSON object per line
/// in a trace file; see the module docs for the field encoding rules.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRecord {
    /// Arrival offset from session start, nanoseconds.
    pub arrival_ns: u64,
    /// The request as submitted (id, seed, model, steps, priority,
    /// deadline) — everything replay needs for bit-identical results.
    pub request: InferenceRequest,
}

impl TraceRecord {
    /// Render one compact JSON line (no trailing newline).
    pub fn render(&self) -> String {
        match &self.request {
            InferenceRequest::Denoise(r) => format!(
                "{{\"arrival_ns\":{},\"kind\":\"denoise\",\"id\":{},\"seed\":\"{}\",\
                 \"steps\":{},\"priority\":{},\"deadline_ns\":{}}}",
                self.arrival_ns,
                r.id,
                r.seed,
                r.steps,
                r.priority,
                deadline_json(r.deadline)
            ),
            InferenceRequest::Classify(r) => format!(
                "{{\"arrival_ns\":{},\"kind\":\"classify\",\"id\":{},\"seed\":\"{}\",\
                 \"model\":\"{}\",\"priority\":{},\"deadline_ns\":{}}}",
                self.arrival_ns,
                r.id,
                r.seed,
                r.model.name(),
                r.priority,
                deadline_json(r.deadline)
            ),
        }
    }

    /// Parse one JSON trace line. Errors name the bad or missing field.
    pub fn parse(line: &str) -> Result<Self> {
        let v = Json::parse(line).context("not a JSON object")?;
        let arrival_ns = field_u64(&v, "arrival_ns")?;
        let id = field_u64(&v, "id")?;
        let seed: u64 = field_str(&v, "seed")?
            .parse()
            .map_err(|_| anyhow!("bad `seed` (expected a decimal u64 string)"))?;
        let priority_raw = field_u64(&v, "priority")?;
        if priority_raw > u8::MAX as u64 {
            bail!("`priority` out of range: {priority_raw}");
        }
        let priority = priority_raw as u8;
        let deadline = match v.get("deadline_ns") {
            None | Some(Json::Null) => None,
            Some(_) => Some(Duration::from_nanos(field_u64(&v, "deadline_ns")?)),
        };
        let request = match field_str(&v, "kind")? {
            "denoise" => {
                let steps = field_u64(&v, "steps")? as usize;
                if steps == 0 {
                    bail!("`steps` must be >= 1");
                }
                InferenceRequest::Denoise(DenoiseRequest {
                    id,
                    seed,
                    steps,
                    priority,
                    deadline,
                })
            }
            "classify" => {
                let model = ModelChoice::parse(field_str(&v, "model")?)
                    .context("bad `model`")?;
                InferenceRequest::Classify(ClassifyRequest {
                    id,
                    seed,
                    model,
                    priority,
                    deadline,
                })
            }
            other => bail!("unknown `kind` `{other}` (expected denoise | classify)"),
        };
        Ok(TraceRecord {
            arrival_ns,
            request,
        })
    }
}

fn deadline_json(d: Option<Duration>) -> String {
    match d {
        Some(d) => format!("{}", d.as_nanos()),
        None => "null".into(),
    }
}

/// Exact-integer numeric field: rejects negatives, fractions, and
/// values beyond 2^53 (where `f64` stops being exact).
fn field_u64(v: &Json, key: &str) -> Result<u64> {
    let f = v
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !(0.0..=MAX_EXACT).contains(&f) || f.fract() != 0.0 {
        bail!("`{key}` out of exact-integer range: {f}");
    }
    Ok(f as u64)
}

fn field_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing or non-string `{key}`"))
}

/// Render a full trace: one JSON line per record, trailing newline.
pub fn render_trace(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.render());
        out.push('\n');
    }
    out
}

/// Parse a trace back. Blank lines are skipped; errors carry the
/// 1-based line number; arrivals must be nondecreasing.
pub fn parse_trace(text: &str) -> Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    let mut last = 0u64;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let rec = TraceRecord::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        if rec.arrival_ns < last {
            bail!(
                "trace line {}: arrivals must be nondecreasing ({} < {})",
                i + 1,
                rec.arrival_ns,
                last
            );
        }
        last = rec.arrival_ns;
        out.push(rec);
    }
    Ok(out)
}

/// Write a trace file (JSON lines).
pub fn write_trace(path: &Path, records: &[TraceRecord]) -> Result<()> {
    std::fs::write(path, render_trace(records))
        .with_context(|| format!("writing trace {}", path.display()))
}

/// Read a trace file written by [`write_trace`] (or by `serve
/// --trace-out`).
pub fn read_trace(path: &Path) -> Result<Vec<TraceRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_trace(&text).with_context(|| format!("parsing trace {}", path.display()))
}

/// The record half of record/replay: pair the first `n` requests of the
/// deterministic workload with arrival offsets from `profile`. Both
/// halves derive from the same `(cfg, seed)`, so the whole trace is
/// reproducible from the config alone — the trace *file* exists so an
/// incident can be replayed after the fact or hand-edited.
pub fn recorded_workload(
    cfg: &ServeConfig,
    profile: &TrafficProfile,
    seed: u64,
    n: usize,
) -> Vec<TraceRecord> {
    let requests = workload(cfg, seed, 0..n);
    let arrivals = profile.schedule(seed, n);
    arrivals
        .into_iter()
        .zip(requests)
        .map(|(arrival_ns, request)| TraceRecord {
            arrival_ns,
            request,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: &[&str] = &[
        "uniform:120",
        "poisson:80.5",
        "ou:60:2:15",
        "burst:40:200:1000:100",
        "ramp:10:90:500",
        "sine:50:25:2000",
    ];

    #[test]
    fn grammar_round_trips() {
        for spec in SPECS {
            let p = TrafficProfile::parse(spec).unwrap();
            let rendered = p.render();
            assert_eq!(rendered, *spec, "canonical render");
            assert_eq!(TrafficProfile::parse(&rendered).unwrap(), p);
        }
    }

    #[test]
    fn parse_errors_name_the_bad_key() {
        let cases: &[(&str, &str)] = &[
            ("ou:8:x:2", "bad theta"),
            ("ou:oops:1:2", "bad mean"),
            ("burst:40:200:1000:zzz", "bad burst_ms"),
            ("sine:50:abc:2000", "bad amp"),
            ("warp:9", "unknown profile `warp`"),
            ("ou:8:1", "expected `ou:MEAN:THETA:SIGMA`"),
            ("uniform:0", "rate must be positive"),
            ("burst:40:10:1000:100", "peak must be >= base"),
            ("sine:50:60:2000", "amp must be in [0, base]"),
        ];
        for (spec, needle) in cases {
            let err = TrafficProfile::parse(spec).unwrap_err().to_string();
            assert!(
                err.contains(needle) && err.contains(spec),
                "spec `{spec}`: error `{err}` should contain `{needle}` and the spec"
            );
        }
    }

    #[test]
    fn uniform_matches_closed_form_and_all_profiles_are_monotone() {
        let uni = TrafficProfile::parse("uniform:100").unwrap();
        let s = uni.schedule(7, 5);
        assert_eq!(s, vec![0, 10_000_000, 20_000_000, 30_000_000, 40_000_000]);

        for spec in SPECS {
            let p = TrafficProfile::parse(spec).unwrap();
            let s = p.schedule(42, 300);
            assert_eq!(s.len(), 300);
            assert!(s.windows(2).all(|w| w[0] <= w[1]), "{spec}: nondecreasing");
            let s2 = p.schedule(42, 300);
            assert_eq!(s, s2, "{spec}: deterministic in (spec, seed)");
        }
    }

    #[test]
    fn ou_path_stays_in_bounds_and_schedule_uses_it() {
        let p = TrafficProfile::parse("ou:60:2:15").unwrap();
        let (lo, hi) = p.ou_bounds().unwrap();
        let trace = p.rate_trace(11, 5000);
        assert!(trace.iter().all(|&r| (lo..=hi).contains(&r)));
        // the path actually moves (sigma > 0)
        assert!(trace.iter().any(|&r| (r - 60.0).abs() > 1.0));
        // different seeds → different schedules; same seed → identical
        assert_ne!(p.schedule(1, 200), p.schedule(2, 200));
    }

    #[test]
    fn burst_profile_is_denser_inside_the_burst_window() {
        // 100 ms peak @ 200/s then 900 ms base @ 40/s
        let p = TrafficProfile::parse("burst:40:200:1000:100").unwrap();
        let s = p.schedule(0, 56); // exactly one period: 20 peak + 36 base
        let in_burst = s.iter().filter(|&&ns| ns < 100_000_000).count();
        assert!(
            in_burst >= 18,
            "expected ~20 arrivals in the 100 ms burst, got {in_burst}"
        );
    }

    #[test]
    fn trace_record_round_trips_both_kinds() {
        let recs = vec![
            TraceRecord {
                arrival_ns: 0,
                request: InferenceRequest::Denoise(DenoiseRequest {
                    id: 3,
                    seed: u64::MAX - 1,
                    steps: 8,
                    priority: 1,
                    deadline: Some(Duration::from_millis(250)),
                }),
            },
            TraceRecord {
                arrival_ns: 12_345,
                request: InferenceRequest::Classify(ClassifyRequest {
                    id: 4,
                    seed: 9,
                    model: ModelChoice::Resnet18,
                    priority: 0,
                    deadline: None,
                }),
            },
        ];
        let text = render_trace(&recs);
        let back = parse_trace(&text).unwrap();
        assert_eq!(back, recs);
    }

    #[test]
    fn trace_parse_errors_carry_line_numbers() {
        let bad = "{\"arrival_ns\":0,\"kind\":\"denoise\",\"id\":1,\"seed\":\"2\",\
                   \"steps\":4,\"priority\":0,\"deadline_ns\":null}\n{\"nope\":1}\n";
        let err = parse_trace(bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("trace line 2"), "got: {msg}");
        let unordered = "{\"arrival_ns\":50,\"kind\":\"denoise\",\"id\":1,\"seed\":\"2\",\
                         \"steps\":4,\"priority\":0,\"deadline_ns\":null}\n\
                         {\"arrival_ns\":10,\"kind\":\"denoise\",\"id\":2,\"seed\":\"3\",\
                         \"steps\":4,\"priority\":0,\"deadline_ns\":null}\n";
        let err = parse_trace(unordered).unwrap_err().to_string();
        assert!(err.contains("nondecreasing"), "got: {err}");
    }
}
