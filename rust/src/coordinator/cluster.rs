//! Multi-process cluster serving front door (ISSUE 10).
//!
//! [`ClusterFleet`] is the process-level sibling of the in-process
//! [`ShardFleet`](crate::coordinator::fleet::ShardFleet): N `shard-worker`
//! child processes (spawned and supervised by [`crate::coordinator::proc`]),
//! each wrapping one serving session behind a Unix-socket wire protocol
//! ([`crate::coordinator::wire`]), behind one front door with the same
//! API shape — `submit`/`try_submit` returning a
//! [`FleetTicket`], power-of-two-choices routing, heartbeat-driven death
//! declaration, failover re-admission, and a merged [`FleetMetrics`] at
//! shutdown.
//!
//! Same determinism contract as the fleet: request execution is a pure
//! function of `(model, seed, steps)`, so work stripped from a killed
//! worker process and re-admitted to a survivor resolves with the
//! bit-identical result the dead worker would have produced. On top of
//! the fleet's failure model the cluster adds *respawn*: a dead worker
//! slot is re-spawned (fresh process, bumped generation) with a bounded
//! budget, after which the slot retires as `Dead`.
//!
//! Differences from the in-process fleet, both inherent to the process
//! boundary:
//!
//! * Queue depths used for routing are *reported* (carried by heartbeat
//!   frames) plus the front door's own count of in-flight work per
//!   worker, rather than sampled live.
//! * A request whose deadline has already expired is refused by the
//!   *worker* (a `submit_err` frame), so the ticket resolves with the
//!   deadline error instead of `submit` returning it synchronously.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::fleet::{FleetTicket, ShardState};
use crate::coordinator::metrics::{FleetMetrics, FleetStats, ModelMetrics, ServeMetrics};
use crate::coordinator::proc::{WorkerEvent, WorkerProc};
use crate::coordinator::server::{AdmissionError, DenoiseResult, InferenceRequest};
use crate::coordinator::wire::WireMsg;
use crate::util::stats::StreamingPercentiles;
use crate::util::Rng;

/// Spawns allowed per worker slot (the initial spawn plus respawns
/// after a death). A slot that burns the whole budget retires as
/// [`ShardState::Dead`]; its in-flight work fails over to survivors.
pub const SPAWNS_PER_SLOT: u32 = 3;

/// How long shutdown waits for a worker to flush its final metrics
/// frame and exit after the `shutdown` frame, before killing it.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(20);

/// Monotonic disambiguator for cluster socket directories (several
/// clusters can coexist in one process, e.g. under `cargo test`).
static CLUSTER_SEQ: AtomicU64 = AtomicU64::new(0);

/// One worker slot: the supervised child process (until reaped), its
/// lifecycle state, and the monitor's view of its health.
struct WorkerSlot {
    proc: Option<WorkerProc>,
    state: ShardState,
    gen: u64,
    spawns: u32,
    /// Latest pulse sequence carried by a heartbeat frame.
    cur_seq: u64,
    /// Sequence at the last monitor sample (`u64::MAX` = never sampled,
    /// so a fresh worker gets a full period before its first miss).
    last_seq: u64,
    misses: u64,
    /// Queue depth the worker last reported.
    reported_depth: u64,
    /// Whether the final `shutdown` frame went out (preempt drain).
    shutdown_sent: bool,
    /// Most recent mid-flight metrics frame.
    last_metrics: Option<ServeMetrics>,
    /// The worker's final metrics (sent just before it exits).
    final_metrics: Option<ServeMetrics>,
}

impl WorkerSlot {
    fn routable(&self) -> bool {
        self.state == ShardState::Live && self.proc.is_some()
    }
}

/// One cluster-admitted request in flight. `worker` is the slot the
/// request currently lives on; `None` means it awaits (re-)admission —
/// parked by `submit` while every worker was full, or stripped from a
/// dead worker.
struct CPending {
    req: InferenceRequest,
    ticket: u64,
    worker: Option<usize>,
    tx: Sender<Result<DenoiseResult>>,
    submitted_at: Instant,
}

struct ClusterState {
    workers: Vec<WorkerSlot>,
    pending: Vec<CPending>,
    rng: Rng,
    stats: FleetStats,
    e2e: StreamingPercentiles,
    per_model: Vec<ModelMetrics>,
    queue_depth: usize,
    draining: bool,
}

/// What the monitor needs to spawn a replacement worker.
struct SpawnCtx {
    exe: PathBuf,
    cfg_path: PathBuf,
    dir: PathBuf,
    events: Sender<WorkerEvent>,
}

/// The multi-process cluster front door. See the module docs for the
/// failure model; see [`ClusterFleet::start`] for construction.
pub struct ClusterFleet {
    state: Arc<Mutex<ClusterState>>,
    monitor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    t0: Instant,
    next_id: AtomicU64,
    dir: PathBuf,
}

impl ClusterFleet {
    /// Spawn `cfg.cluster` worker processes of the binary at `exe`
    /// (normally `std::env::current_exe()`; tests use
    /// `env!("CARGO_BIN_EXE_sf-mmcn")`) and start the front door.
    /// Sockets and the worker config file live in a per-cluster temp
    /// directory removed at shutdown.
    pub fn start(cfg: ServeConfig, exe: &Path) -> Result<ClusterFleet> {
        cfg.validate()?;
        let n = cfg.cluster;
        if n == 0 {
            bail!("ClusterFleet::start needs serve.cluster >= 1 worker processes");
        }
        let dir = std::env::temp_dir().join(format!(
            "sf-mmcn-cluster-{}-{}",
            std::process::id(),
            CLUSTER_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cluster dir {}", dir.display()))?;
        let cfg_path = dir.join("worker.toml");
        std::fs::write(&cfg_path, cfg.to_toml())
            .with_context(|| format!("writing {}", cfg_path.display()))?;

        let (events_tx, events_rx) = channel::<WorkerEvent>();
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let proc = WorkerProc::spawn(exe, &cfg_path, &dir, w, 0, events_tx.clone())
                .with_context(|| format!("starting cluster worker {w}"))?;
            workers.push(WorkerSlot {
                proc: Some(proc),
                state: ShardState::Live,
                gen: 0,
                spawns: 1,
                cur_seq: 0,
                last_seq: u64::MAX,
                misses: 0,
                reported_depth: 0,
                shutdown_sent: false,
                last_metrics: None,
                final_metrics: None,
            });
        }

        let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(1));
        let misses_allowed = cfg.heartbeat_misses.max(1);
        let pump_interval = Duration::from_micros(cfg.monitor_pump_us.max(1));
        let preempt_file = (!cfg.preempt_file.trim().is_empty())
            .then(|| PathBuf::from(cfg.preempt_file.trim()));
        let state = Arc::new(Mutex::new(ClusterState {
            workers,
            pending: Vec::new(),
            rng: Rng::new(cfg.seed ^ 0xc1a5_7e12),
            stats: FleetStats::default(),
            e2e: StreamingPercentiles::new(),
            per_model: ModelMetrics::rows(),
            queue_depth: cfg.queue_depth,
            draining: false,
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let spawn_ctx = SpawnCtx {
            exe: exe.to_path_buf(),
            cfg_path,
            dir: dir.clone(),
            events: events_tx,
        };
        let monitor = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("cluster-monitor".into())
                .spawn(move || {
                    Self::monitor_main(
                        state,
                        stop,
                        events_rx,
                        spawn_ctx,
                        heartbeat,
                        misses_allowed,
                        pump_interval,
                        preempt_file,
                    )
                })
                .expect("spawn cluster monitor")
        };
        Ok(ClusterFleet {
            state,
            monitor: Some(monitor),
            stop,
            t0: Instant::now(),
            next_id: AtomicU64::new(0),
            dir,
        })
    }

    /// Worker slots the cluster was started with (regardless of state).
    pub fn workers(&self) -> usize {
        self.state.lock().unwrap().workers.len()
    }

    /// Instantaneous per-worker lifecycle states, in slot order.
    pub fn worker_states(&self) -> Vec<ShardState> {
        let st = self.state.lock().unwrap();
        st.workers.iter().map(|w| w.state).collect()
    }

    /// Cluster counters plus the instantaneous worker census.
    pub fn stats(&self) -> FleetStats {
        Self::census(&self.state.lock().unwrap())
    }

    /// Admit a request; never sheds. If every live worker is at
    /// capacity the request parks front-door-side and the monitor
    /// admits it when room frees up. Fails only when no live worker
    /// exists (or the cluster is shutting down).
    pub fn submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        self.admit(req.into(), true)
    }

    /// Admit without parking: a cluster where every live worker is at
    /// capacity returns [`AdmissionError::QueueFull`] immediately.
    pub fn try_submit(
        &self,
        req: impl Into<InferenceRequest>,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        self.admit(req.into(), false)
    }

    fn admit(
        &self,
        req: InferenceRequest,
        park: bool,
    ) -> std::result::Result<FleetTicket, AdmissionError> {
        let mut st = self.state.lock().unwrap();
        if st.draining {
            return Err(AdmissionError::ShuttingDown);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let now = Instant::now();
        let entry = match Self::assign(&mut st, id, &req) {
            Ok(worker) => CPending {
                req,
                ticket: id,
                worker: Some(worker),
                tx,
                submitted_at: now,
            },
            Err(AdmissionError::QueueFull) if park => CPending {
                req,
                ticket: id,
                worker: None,
                tx,
                submitted_at: now,
            },
            Err(e) => return Err(e),
        };
        st.pending.push(entry);
        st.stats.submitted += 1;
        Ok(FleetTicket::new(id, rx))
    }

    /// Power-of-two-choices admission on (reported queue depth + local
    /// in-flight count): pick the lighter of two distinct eligible
    /// workers, then fall through the rest of the eligible set. The
    /// front door caps in-flight work per worker at the configured
    /// queue depth, so a routed `submit` frame is never shed worker-side
    /// (a racing `submit_err` is handled as a requeue regardless).
    fn assign(
        st: &mut ClusterState,
        ticket: u64,
        req: &InferenceRequest,
    ) -> std::result::Result<usize, AdmissionError> {
        loop {
            let mut inflight = vec![0usize; st.workers.len()];
            for p in &st.pending {
                if let Some(w) = p.worker {
                    inflight[w] += 1;
                }
            }
            let any_live = st.workers.iter().any(WorkerSlot::routable);
            if !any_live {
                return Err(AdmissionError::NoLiveShards);
            }
            let eligible: Vec<usize> = st
                .workers
                .iter()
                .enumerate()
                .filter(|(i, w)| w.routable() && inflight[*i] < st.queue_depth)
                .map(|(i, _)| i)
                .collect();
            if eligible.is_empty() {
                return Err(AdmissionError::QueueFull);
            }
            let (ai, bi) = Self::p2c_candidates(&mut st.rng, eligible.len());
            let (a, b) = (eligible[ai], eligible[bi]);
            let score = |i: usize| st.workers[i].reported_depth as usize + inflight[i];
            let first = if score(a) <= score(b) { a } else { b };
            let order: Vec<usize> = std::iter::once(first)
                .chain(eligible.into_iter().filter(|&i| i != first))
                .collect();
            let mut sent = None;
            for i in order {
                let Some(p) = st.workers[i].proc.as_mut() else {
                    continue;
                };
                let msg = WireMsg::Submit {
                    ticket,
                    req: req.clone(),
                };
                if p.send(&msg).is_ok() {
                    sent = Some(i);
                    break;
                }
                // the socket is down: the worker is dead, retire it and
                // keep trying the rest
                Self::declare_dead(st, i);
            }
            match sent {
                Some(i) => return Ok(i),
                None => continue, // every candidate died mid-send; re-evaluate
            }
        }
    }

    /// The two distinct p2c candidate slots out of `n` (see the fleet's
    /// equivalent: distinct draws avoid silently degrading to
    /// single-choice routing).
    fn p2c_candidates(rng: &mut Rng, n: usize) -> (usize, usize) {
        let a = rng.below(n as u64) as usize;
        if n < 2 {
            return (a, a);
        }
        let mut b = rng.below(n as u64 - 1) as usize;
        if b >= a {
            b += 1;
        }
        (a, b)
    }

    /// Operational hard kill: SIGKILL the worker process. Death then
    /// flows through the real wire path — the reader thread sees EOF,
    /// the monitor declares the slot dead, strips and re-admits its
    /// work, and (budget permitting) respawns the slot.
    pub fn kill_worker(&self, worker: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let n = st.workers.len();
        if worker >= n {
            bail!("kill_worker: worker {worker} out of range ({n} workers)");
        }
        if let Some(p) = st.workers[worker].proc.as_mut() {
            p.kill();
        }
        Ok(())
    }

    /// Preemption notice: stop routing to `worker` and drain it — every
    /// request already on it resolves normally, then the process exits
    /// and the slot parks as [`ShardState::Drained`] (no respawn).
    pub fn begin_preempt(&self, worker: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let n = st.workers.len();
        if worker >= n {
            bail!("begin_preempt: worker {worker} out of range ({n} workers)");
        }
        match st.workers[worker].state {
            ShardState::Live => {
                Self::start_preempt(&mut st, worker);
                Ok(())
            }
            other => bail!("begin_preempt: worker {worker} is {other:?}, not Live"),
        }
    }

    fn start_preempt(st: &mut ClusterState, worker: usize) {
        st.workers[worker].state = ShardState::Preempting;
        let died = match st.workers[worker].proc.as_mut() {
            Some(p) => p.send(&WireMsg::Drain).is_err(),
            None => false,
        };
        if died {
            Self::declare_dead(st, worker);
        }
    }

    /// Live snapshot of cluster counters, per-worker metrics (the most
    /// recent wire snapshot each worker reported), and the front-door
    /// e2e percentiles.
    pub fn metrics_snapshot(&self) -> FleetMetrics {
        let st = self.state.lock().unwrap();
        let per_shard = Self::per_worker_metrics(&st);
        let per_model = Self::cluster_per_model(&st, &per_shard);
        FleetMetrics {
            stats: Self::census(&st),
            per_shard,
            e2e_latency: st.e2e.clone(),
            per_model,
            wall: self.t0.elapsed(),
        }
    }

    /// Graceful cluster shutdown: close the front door, drain every
    /// worker, collect each worker's final metrics frame, reap the
    /// processes, and return the merged metrics. Every admitted ticket
    /// resolves before this returns: tickets already on a worker drain
    /// in place; a ticket that cannot be placed once the drain begins
    /// (parked front-door-side, or stripped from a worker that dies on
    /// the way out — the surviving workers are draining too and refuse
    /// new work) resolves with an error, the same contract as the
    /// in-process fleet.
    pub fn shutdown(mut self) -> Result<FleetMetrics> {
        self.close();
        let mut st = self.state.lock().unwrap();
        for w in st.workers.iter_mut() {
            if let Some(p) = w.proc.take() {
                p.reap(SHUTDOWN_GRACE);
            }
        }
        let per_shard = Self::per_worker_metrics(&st);
        let per_model = Self::cluster_per_model(&st, &per_shard);
        let metrics = FleetMetrics {
            stats: Self::census(&st),
            per_shard,
            e2e_latency: st.e2e.clone(),
            per_model,
            wall: self.t0.elapsed(),
        };
        drop(st);
        let _ = std::fs::remove_dir_all(&self.dir);
        Ok(metrics)
    }

    /// Close admission, start draining every live worker, and join the
    /// monitor (which exits only once every ticket has resolved and the
    /// workers were told to shut down).
    ///
    /// Draining goes through [`Self::start_preempt`] so each worker
    /// turns non-routable the moment its `Drain` frame goes out. That
    /// is what lets the monitor terminate: a draining worker answers
    /// any late `submit` with `SubmitErr(ShuttingDown)`, so if drained
    /// workers stayed routable, an unplaceable ticket would ping-pong
    /// between `pump` and the refusal forever and `pending` would never
    /// empty.
    fn close(&mut self) {
        {
            let mut st = self.state.lock().unwrap();
            st.draining = true;
            for i in 0..st.workers.len() {
                if st.workers[i].state == ShardState::Live {
                    Self::start_preempt(&mut st, i);
                }
            }
        }
        self.stop.store(true, Ordering::Relaxed);
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
    }

    fn census(st: &ClusterState) -> FleetStats {
        let mut s = st.stats;
        s.shards = st.workers.len();
        for w in &st.workers {
            match w.state {
                ShardState::Live => s.live += 1,
                ShardState::Preempting => s.preempting += 1,
                ShardState::Dead => s.dead += 1,
                ShardState::Drained => s.drained += 1,
            }
        }
        s
    }

    /// Cluster per-model rows: front-door delivered/failed counts and
    /// e2e percentiles plus executed steps summed over the workers
    /// (retries included, same as the fleet).
    fn cluster_per_model(st: &ClusterState, per_shard: &[ServeMetrics]) -> Vec<ModelMetrics> {
        let mut rows = st.per_model.clone();
        for m in per_shard {
            for (row, sm) in rows.iter_mut().zip(&m.per_model) {
                row.steps_done += sm.steps_done;
            }
        }
        rows
    }

    fn per_worker_metrics(st: &ClusterState) -> Vec<ServeMetrics> {
        st.workers
            .iter()
            .map(|w| match (&w.final_metrics, &w.last_metrics) {
                (Some(m), _) => m.clone(),
                (None, Some(m)) => m.clone(),
                (None, None) => ServeMetrics::new(),
            })
            .collect()
    }

    // ------------------------------------------------------------ monitor

    #[allow(clippy::too_many_arguments)] // mirrors the fleet monitor's signature
    fn monitor_main(
        state: Arc<Mutex<ClusterState>>,
        stop: Arc<AtomicBool>,
        events: Receiver<WorkerEvent>,
        spawn_ctx: SpawnCtx,
        heartbeat: Duration,
        misses_allowed: u64,
        pump_interval: Duration,
        preempt_file: Option<PathBuf>,
    ) {
        let mut last_hb = Instant::now();
        let mut preempt_armed = preempt_file.is_some();
        loop {
            let mut respawn: Vec<(usize, u64)> = Vec::new();
            let done = {
                let mut st = state.lock().unwrap();
                while let Ok(ev) = events.try_recv() {
                    Self::on_event(&mut st, ev);
                }
                if last_hb.elapsed() >= heartbeat {
                    last_hb = Instant::now();
                    Self::sample_heartbeats(&mut st, misses_allowed);
                    Self::request_metrics(&mut st);
                    if preempt_armed {
                        if let Some(path) = preempt_file.as_deref() {
                            if Self::poll_preempt_sentinel(&mut st, path) {
                                preempt_armed = false;
                            }
                        }
                    }
                }
                let draining = st.draining;
                Self::pump(&mut st, draining);
                Self::finish_drained(&mut st);
                if !draining {
                    for (i, w) in st.workers.iter().enumerate() {
                        if w.state == ShardState::Dead
                            && w.proc.is_none()
                            && w.spawns < SPAWNS_PER_SLOT
                        {
                            respawn.push((i, w.gen + 1));
                        }
                    }
                }
                stop.load(Ordering::Relaxed) && st.pending.is_empty()
            };
            // Respawns happen outside the state lock: a spawn blocks on
            // process startup and the handshake, and admission must not
            // stall behind it.
            for (i, gen) in respawn {
                let spawned = WorkerProc::spawn(
                    &spawn_ctx.exe,
                    &spawn_ctx.cfg_path,
                    &spawn_ctx.dir,
                    i,
                    gen,
                    spawn_ctx.events.clone(),
                );
                let mut st = state.lock().unwrap();
                let w = &mut st.workers[i];
                // only install into a slot still waiting for this spawn
                if w.state == ShardState::Dead && w.proc.is_none() {
                    w.spawns += 1;
                    if let Ok(p) = spawned {
                        w.proc = Some(p);
                        w.state = ShardState::Live;
                        w.gen = gen;
                        w.cur_seq = 0;
                        w.last_seq = u64::MAX;
                        w.misses = 0;
                        w.reported_depth = 0;
                    }
                }
            }
            if done {
                Self::shutdown_workers(&state, &events, pump_interval);
                break;
            }
            std::thread::sleep(pump_interval);
        }
    }

    /// Apply one wire event. Events carry the spawn generation they
    /// arrived on; anything from a generation the slot already replaced
    /// is stale and ignored.
    fn on_event(st: &mut ClusterState, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Msg { worker, gen, msg } => {
                if st.workers[worker].gen != gen {
                    return;
                }
                Self::on_msg(st, worker, msg);
            }
            WorkerEvent::Gone { worker, gen } => {
                if st.workers[worker].gen != gen {
                    return;
                }
                Self::declare_dead(st, worker);
            }
        }
    }

    fn on_msg(st: &mut ClusterState, worker: usize, msg: WireMsg) {
        match msg {
            WireMsg::Heartbeat { seq, queue_depth } => {
                let w = &mut st.workers[worker];
                w.cur_seq = w.cur_seq.max(seq);
                w.reported_depth = queue_depth;
            }
            WireMsg::TicketResult { ticket, result } => {
                // an absent ticket is a stale duplicate (the request
                // failed over and already resolved) — drop it
                if let Some(i) = st.pending.iter().position(|p| p.ticket == ticket) {
                    let p = st.pending.swap_remove(i);
                    Self::deliver(st, p, result.map_err(|e| anyhow!(e)));
                }
            }
            WireMsg::SubmitErr { ticket, error } => {
                let Some(i) = st.pending.iter().position(|p| p.ticket == ticket) else {
                    return;
                };
                match error {
                    // terminal: the deadline had already expired when the
                    // worker saw the request
                    AdmissionError::Deadline => {
                        let p = st.pending.swap_remove(i);
                        let req_id = p.req.id();
                        Self::deliver(st, p, Err(anyhow!("request {req_id}: {error}")));
                    }
                    // transient (race against a fill-up or a preemption
                    // drain): strip the assignment; the pump re-admits
                    // on a surviving live worker
                    _ if !st.draining => {
                        st.pending[i].worker = None;
                        st.stats.requeued += 1;
                    }
                    // cluster-wide drain: every worker is refusing new
                    // work, so a refusal is terminal (requeueing would
                    // ping-pong forever and stall shutdown) — same
                    // contract as the in-process fleet's drain
                    _ => {
                        let p = st.pending.swap_remove(i);
                        let req_id = p.req.id();
                        Self::deliver(
                            st,
                            p,
                            Err(anyhow!("request {req_id}: refused during drain ({error})")),
                        );
                    }
                }
            }
            WireMsg::Metrics { last, snapshot } => {
                let w = &mut st.workers[worker];
                let m = snapshot.to_metrics();
                if last {
                    w.final_metrics = Some(m);
                    // a final metrics frame means an orderly exit: park
                    // the slot now, so the connection-closed event right
                    // behind this frame cannot read as a death
                    if matches!(w.state, ShardState::Live | ShardState::Preempting) {
                        w.state = ShardState::Drained;
                    }
                } else {
                    w.last_metrics = Some(m);
                }
            }
            // workers never originate the remaining frame types
            _ => {}
        }
    }

    /// Resolve one cluster ticket (single-shot) and account for it, on
    /// the cluster aggregate and the request's per-model row.
    fn deliver(st: &mut ClusterState, p: CPending, r: Result<DenoiseResult>) {
        let row = &mut st.per_model[p.req.model().index()];
        match r {
            Ok(res) => {
                st.stats.delivered += 1;
                row.requests_done += 1;
                let us = p.submitted_at.elapsed().as_micros() as f64;
                row.e2e_latency.record_us(us);
                st.e2e.record_us(us);
                let _ = p.tx.send(Ok(res));
            }
            Err(e) => {
                st.stats.failed += 1;
                row.requests_failed += 1;
                let _ = p.tx.send(Err(e));
            }
        }
    }

    /// Declare a worker dead: drop the supervised process (killing it if
    /// needed), and strip its in-flight requests for re-admission. Any
    /// result the worker flushed before dying was already applied — the
    /// event channel is processed in arrival order — so nothing resolved
    /// re-executes.
    fn declare_dead(st: &mut ClusterState, worker: usize) {
        if !matches!(
            st.workers[worker].state,
            ShardState::Live | ShardState::Preempting
        ) {
            return;
        }
        st.workers[worker].state = ShardState::Dead;
        st.stats.failovers += 1;
        drop(st.workers[worker].proc.take());
        for p in st.pending.iter_mut() {
            if p.worker == Some(worker) {
                p.worker = None;
                st.stats.requeued += 1;
            }
        }
    }

    /// One monitor pass: (re-)admit unassigned requests onto live
    /// workers; during a drain, requests that can no longer be placed
    /// resolve with an error (same contract as the fleet).
    fn pump(st: &mut ClusterState, draining: bool) {
        let mut i = 0;
        while i < st.pending.len() {
            if st.pending[i].worker.is_some() {
                i += 1;
                continue;
            }
            let req = st.pending[i].req.clone();
            let ticket = st.pending[i].ticket;
            match Self::assign(st, ticket, &req) {
                Ok(worker) => {
                    st.pending[i].worker = Some(worker);
                    i += 1;
                }
                Err(AdmissionError::QueueFull) if !draining => i += 1,
                Err(e) => {
                    let p = st.pending.swap_remove(i);
                    let req_id = p.req.id();
                    Self::deliver(
                        st,
                        p,
                        Err(anyhow!("request {req_id}: not re-admittable after failover ({e})")),
                    );
                }
            }
        }
    }

    /// A `Preempting` worker with no in-flight requests has finished its
    /// drain: tell it to exit (once). The slot parks as `Drained` when
    /// its final metrics frame arrives.
    fn finish_drained(st: &mut ClusterState) {
        for i in 0..st.workers.len() {
            if st.workers[i].state != ShardState::Preempting || st.workers[i].shutdown_sent {
                continue;
            }
            let busy = st.pending.iter().any(|p| p.worker == Some(i));
            if busy {
                continue;
            }
            st.workers[i].shutdown_sent = true;
            let died = match st.workers[i].proc.as_mut() {
                Some(p) => p.send(&WireMsg::Shutdown).is_err(),
                None => false,
            };
            if died {
                Self::declare_dead(st, i);
            }
        }
    }

    /// Sample every routable worker's heartbeat sequence (as carried by
    /// its heartbeat frames); a sequence frozen for `allowed`
    /// consecutive samples retires the worker. Covers both a wedged
    /// worker process (frames stop, sequence freezes) and a wedged lane
    /// inside a live process (frames continue, sequence freezes).
    fn sample_heartbeats(st: &mut ClusterState, allowed: u64) {
        let mut retire: Vec<usize> = Vec::new();
        for (i, w) in st.workers.iter_mut().enumerate() {
            if !matches!(w.state, ShardState::Live | ShardState::Preempting) {
                continue;
            }
            if w.last_seq == u64::MAX {
                w.last_seq = w.cur_seq; // first sample: no miss yet
                continue;
            }
            if w.cur_seq == w.last_seq {
                w.misses += 1;
                if w.misses >= allowed {
                    retire.push(i);
                }
            } else {
                w.last_seq = w.cur_seq;
                w.misses = 0;
            }
        }
        for i in retire {
            Self::declare_dead(st, i);
        }
    }

    /// Ask every routable worker for a metrics snapshot (refreshes the
    /// per-worker view returned by [`ClusterFleet::metrics_snapshot`]).
    fn request_metrics(st: &mut ClusterState) {
        let mut died: Vec<usize> = Vec::new();
        for (i, w) in st.workers.iter_mut().enumerate() {
            if !matches!(w.state, ShardState::Live | ShardState::Preempting) {
                continue;
            }
            if let Some(p) = w.proc.as_mut() {
                if p.send(&WireMsg::MetricsReq).is_err() {
                    died.push(i);
                }
            }
        }
        for i in died {
            Self::declare_dead(st, i);
        }
    }

    /// Spot-interruption sentinel, identical protocol to the fleet's:
    /// when `serve.preempt_file` appears, drain the worker index it
    /// names (empty file = worker 0). Fires at most once.
    fn poll_preempt_sentinel(st: &mut ClusterState, path: &Path) -> bool {
        let Ok(text) = std::fs::read_to_string(path) else {
            return false;
        };
        let trimmed = text.trim();
        let worker = if trimmed.is_empty() {
            0
        } else {
            match trimmed.parse::<usize>() {
                Ok(s) => s,
                Err(_) => return true, // malformed: consume, no action
            }
        };
        if worker < st.workers.len() && st.workers[worker].state == ShardState::Live {
            Self::start_preempt(st, worker);
        }
        true
    }

    /// Orderly end-of-life for the worker processes, run by the monitor
    /// just before it exits (every ticket has already resolved): send
    /// each remaining worker the `shutdown` frame, then keep applying
    /// events until each has delivered its final metrics frame (or its
    /// connection closed), bounded by [`SHUTDOWN_GRACE`].
    fn shutdown_workers(
        state: &Arc<Mutex<ClusterState>>,
        events: &Receiver<WorkerEvent>,
        pump_interval: Duration,
    ) {
        {
            let mut st = state.lock().unwrap();
            for i in 0..st.workers.len() {
                if !matches!(
                    st.workers[i].state,
                    ShardState::Live | ShardState::Preempting
                ) || st.workers[i].shutdown_sent
                {
                    continue;
                }
                st.workers[i].shutdown_sent = true;
                let died = match st.workers[i].proc.as_mut() {
                    Some(p) => p.send(&WireMsg::Shutdown).is_err(),
                    None => false,
                };
                if died {
                    Self::declare_dead(&mut st, i);
                }
            }
        }
        let deadline = Instant::now() + SHUTDOWN_GRACE;
        loop {
            let open = {
                let mut st = state.lock().unwrap();
                while let Ok(ev) = events.try_recv() {
                    Self::on_event(&mut st, ev);
                }
                // a live worker that sent its final metrics counts as
                // drained even outside the preempt path
                for w in st.workers.iter_mut() {
                    if w.state == ShardState::Live && w.final_metrics.is_some() {
                        w.state = ShardState::Drained;
                    }
                }
                st.workers
                    .iter()
                    .any(|w| matches!(w.state, ShardState::Live | ShardState::Preempting))
            };
            if !open || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(pump_interval);
        }
    }
}

impl Drop for ClusterFleet {
    fn drop(&mut self) {
        if self.monitor.is_some() {
            self.close();
        }
        let mut st = self.state.lock().unwrap();
        for w in st.workers.iter_mut() {
            // WorkerProc::drop kills and reaps anything still running
            drop(w.proc.take());
        }
        drop(st);
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}
