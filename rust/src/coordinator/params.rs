//! Loader for the U-net weight blob produced by `python/compile/aot.py`.
//!
//! Format: `unet_params.manifest` has one `name d0 d1 ...` line per
//! tensor (in the canonical order the artifact's trailing inputs expect);
//! `unet_params.bin` is the little-endian f32 concatenation.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::models::graph::{Layer, ModelGraph, Residual};
use crate::models::{unet, UnetConfig};
use crate::runtime::TensorBuf;
use crate::util::Rng;

/// The loaded parameter set.
#[derive(Debug, Clone)]
pub struct UnetParams {
    /// Parameter names, in manifest order.
    pub names: Vec<String>,
    /// Parameter tensors, aligned with `names`.
    pub tensors: Vec<TensorBuf>,
}

impl UnetParams {
    /// Load `<stem>.manifest` + `<stem>.bin` from a directory.
    pub fn load(dir: &Path, stem: &str) -> Result<Self> {
        let man_path = dir.join(format!("{stem}.manifest"));
        let bin_path = dir.join(format!("{stem}.bin"));
        let manifest = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?;
        let blob = std::fs::read(&bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;

        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let mut off = 0usize;
        for (lineno, line) in manifest.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts
                .next()
                .with_context(|| format!("manifest line {}", lineno + 1))?;
            let dims: Vec<usize> = parts
                .map(|d| d.parse::<usize>())
                .collect::<std::result::Result<_, _>>()
                .with_context(|| format!("manifest line {}: bad dims", lineno + 1))?;
            let n: usize = dims.iter().product::<usize>().max(1);
            let nbytes = 4 * n;
            if off + nbytes > blob.len() {
                bail!(
                    "blob too small: `{name}` wants {nbytes} bytes at offset {off}, \
                     blob is {} bytes",
                    blob.len()
                );
            }
            let mut data = Vec::with_capacity(n);
            for i in 0..n {
                let b = &blob[off + 4 * i..off + 4 * i + 4];
                data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += nbytes;
            names.push(name.to_string());
            tensors.push(TensorBuf::new(dims, data)?);
        }
        if off != blob.len() {
            bail!(
                "blob has {} trailing bytes not covered by the manifest",
                blob.len() - off
            );
        }
        if tensors.is_empty() {
            bail!("empty parameter manifest");
        }
        Ok(Self { names, tensors })
    }

    /// Deterministic synthetic parameter set shaped like the real
    /// artifact's (one `w`/`b` per conv, plus time-dense and skip-conv
    /// tensors where the graph has them), for the native backend — lets
    /// the serving stack run offline with no `make artifacts`. Same seed,
    /// same tensors, bit-for-bit.
    pub fn synthetic(cfg: &UnetConfig, seed: u64) -> Self {
        Self::synthetic_for_graph(&unet(*cfg), seed)
    }

    /// Graph-generic synthetic parameters (ISSUE 7): walks any
    /// [`ModelGraph`] — the U-net, but also the ResNet-18 / VGG-16
    /// classification graphs, whose Dense heads get `w`/`b` tensors too.
    /// Generation order is the node walk, so a given (graph, seed) pair
    /// is bit-for-bit reproducible anywhere (the failover and batched ≡
    /// per-request identities depend on this).
    pub fn synthetic_for_graph(g: &ModelGraph, seed: u64) -> Self {
        fn gen(rng: &mut Rng, shape: Vec<usize>) -> TensorBuf {
            let n: usize = shape.iter().product();
            TensorBuf {
                shape,
                data: (0..n).map(|_| rng.normal() * 0.05).collect(),
            }
        }
        let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for (i, node) in g.nodes.iter().enumerate() {
            match &node.layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    residual,
                    time_dense,
                    ..
                } => {
                    names.push(format!("n{i}.w"));
                    tensors.push(gen(&mut rng, vec![*c_out, *c_in, *k, *k]));
                    names.push(format!("n{i}.b"));
                    tensors.push(gen(&mut rng, vec![*c_out]));
                    if let Some(td) = time_dense {
                        names.push(format!("n{i}.wt"));
                        tensors.push(gen(&mut rng, vec![*c_out, *td]));
                    }
                    if let Residual::Conv { from, .. } = residual {
                        names.push(format!("n{i}.wr"));
                        tensors.push(gen(&mut rng, vec![*c_out, g.nodes[*from].out_shape.c]));
                    }
                }
                Layer::Dense { in_f, out_f, .. } => {
                    names.push(format!("n{i}.w"));
                    tensors.push(gen(&mut rng, vec![*out_f, *in_f]));
                    names.push(format!("n{i}.b"));
                    tensors.push(gen(&mut rng, vec![*out_f]));
                }
                _ => {}
            }
        }
        Self { names, tensors }
    }

    /// Number of parameter tensors.
    pub fn count(&self) -> usize {
        self.tensors.len()
    }

    /// Total parameter scalars.
    pub fn total_values(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("p.manifest"), "a 2 2\nb 3\n").unwrap();
        let mut blob = Vec::new();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("p.bin"), blob).unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("sfmmcn_params_test");
        write_fixture(&dir);
        let p = UnetParams::load(&dir, "p").unwrap();
        assert_eq!(p.count(), 2);
        assert_eq!(p.names, vec!["a", "b"]);
        assert_eq!(p.tensors[0].shape, vec![2, 2]);
        assert_eq!(p.tensors[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.tensors[1].data, vec![5.0, 6.0, 7.0]);
        assert_eq!(p.total_values(), 7);
    }

    #[test]
    fn rejects_short_blob() {
        let dir = std::env::temp_dir().join("sfmmcn_params_short");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("p.manifest"), "a 4\n").unwrap();
        std::fs::write(dir.join("p.bin"), [0u8; 8]).unwrap();
        assert!(UnetParams::load(&dir, "p").is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let dir = std::env::temp_dir().join("sfmmcn_params_trail");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("p.manifest"), "a 1\n").unwrap();
        std::fs::write(dir.join("p.bin"), [0u8; 12]).unwrap();
        assert!(UnetParams::load(&dir, "p").is_err());
    }

    #[test]
    fn synthetic_params_deterministic_and_sized() {
        let cfg = UnetConfig::default();
        let a = UnetParams::synthetic(&cfg, 7);
        let b = UnetParams::synthetic(&cfg, 7);
        let c = UnetParams::synthetic(&cfg, 8);
        assert_eq!(a.names, b.names);
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(ta, tb, "same seed must be bit-identical");
        }
        assert_ne!(
            a.tensors[0].data, c.tensors[0].data,
            "different seed differs"
        );
        // shaped like the real blob: tens of tensors, >50k scalars
        assert!(a.count() > 10, "{} tensors", a.count());
        assert!(a.total_values() > 50_000, "{} values", a.total_values());
    }

    #[test]
    fn synthetic_for_graph_covers_classifier_graphs() {
        use crate::models::{resnet18, vgg16};
        let r = UnetParams::synthetic_for_graph(&resnet18(32, 10), 7);
        let r2 = UnetParams::synthetic_for_graph(&resnet18(32, 10), 7);
        assert_eq!(r.names, r2.names);
        for (ta, tb) in r.tensors.iter().zip(&r2.tensors) {
            assert_eq!(ta, tb, "same (graph, seed) must be bit-identical");
        }
        // the Dense head gets parameters too: last two tensors are w/b
        assert!(r.names.last().unwrap().ends_with(".b"));
        assert_eq!(r.tensors.last().unwrap().shape, vec![10]);
        let w = &r.tensors[r.tensors.len() - 2];
        assert_eq!(w.shape, vec![10, 512]);
        // distinct graphs under the same seed yield distinct sets
        let v = UnetParams::synthetic_for_graph(&vgg16(32, 10), 7);
        assert_ne!(r.count(), v.count());
        // and the unet wrapper is exactly the graph walk it always was
        let cfg = UnetConfig::default();
        let u = UnetParams::synthetic(&cfg, 7);
        let ug = UnetParams::synthetic_for_graph(&unet(cfg), 7);
        assert_eq!(u.names, ug.names);
        for (ta, tb) in u.tensors.iter().zip(&ug.tensors) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new("artifacts");
        if dir.join("unet_params.manifest").exists() {
            let p = UnetParams::load(dir, "unet_params").unwrap();
            assert_eq!(p.count(), 33, "canonical U-net has 33 tensors");
            assert_eq!(p.names[0], "stem.w");
            assert_eq!(p.names.last().unwrap(), "head.b");
        }
    }
}
