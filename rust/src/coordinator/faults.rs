//! Seeded, schedulable fault-injection plane (ISSUE 6).
//!
//! Every failure mode the fleet layer recovers from — shard kill, lane
//! stall, panic-in-step, delayed delivery — is driven by a [`FaultSpec`]:
//! a parseable schedule of [`FaultEvent`]s keyed on *executed-request
//! ordinals per shard*. The schedule is data, not randomness scattered
//! through the code, so every recovery scenario in tests, benches, and
//! EXPERIMENTS.md reproduces from the spec string (or from the seed that
//! generated it via [`FaultSpec::seeded_kill`]).
//!
//! Grammar (`;`-separated events):
//!
//! ```text
//! event   := kind ':' shard ':' request (':' arg)?
//! kind    := 'kill' | 'stall' | 'panic' | 'delay'
//! shard   := shard index (usize)
//! request := 0-based executed-request ordinal on that shard (u64)
//! arg     := stall/delay: milliseconds (u64); panic: message string
//! ```
//!
//! Examples: `kill:1:5` (hard-kill shard 1 when its lanes reach the 5th
//! executed request), `stall:0:3:40` (sleep 40 ms before executing),
//! `panic:0:2:boom` (panic with message "boom" inside request
//! execution), `delay:1:0:15` (resolve tickets 15 ms late). Combined:
//! `kill:1:5;stall:0:3:40`.
//!
//! At runtime each shard gets one [`FaultPlane`]: worker lanes call
//! [`FaultPlane::on_requests`] as they pick up work, which advances a
//! shard-global atomic request counter and returns the folded
//! [`FaultAction`] for any events whose ordinal falls in the window.
//! Each event fires exactly once — `fetch_add` hands every ordinal to
//! exactly one lane.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::Rng;

/// What one scheduled fault does when it fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultKind {
    /// Hard-kill the shard: lanes stop without resolving tickets (the
    /// software analogue of the host dying). Heartbeats stop; the fleet
    /// fails over.
    Kill,
    /// Sleep this long before executing the request/batch (a slow or
    /// wedged device lane).
    Stall(Duration),
    /// Panic inside request execution with this message. With panic
    /// isolation (ISSUE 6) only the affected ticket(s) fail.
    Panic(String),
    /// Resolve the request's ticket this much later than the result was
    /// ready (a slow delivery path).
    DelayDelivery(Duration),
}

/// One scheduled fault: fires when shard `shard` executes its
/// `at_request`-th request (0-based, counted across all its lanes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    /// Target shard index.
    pub shard: usize,
    /// 0-based executed-request ordinal on that shard at which to fire.
    pub at_request: u64,
    /// What the fault does.
    pub kind: FaultKind,
}

/// A parsed fault schedule. Construct with [`FaultSpec::parse`] (the
/// canonical reproducible form) or [`FaultSpec::seeded_kill`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// The scheduled events, in spec order.
    pub events: Vec<FaultEvent>,
}

impl FaultSpec {
    /// Parse the `;`-separated event grammar (see module docs). The
    /// empty string parses to the no-fault spec.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut events = Vec::new();
        for ev in spec.split(';') {
            let ev = ev.trim();
            if ev.is_empty() {
                continue;
            }
            // panic messages may contain ':', so split only the first 4
            let mut parts = ev.splitn(4, ':');
            let kind = parts.next().unwrap_or("");
            let shard: usize = parts
                .next()
                .with_context(|| format!("fault event `{ev}`: missing shard index"))?
                .trim()
                .parse()
                .with_context(|| format!("fault event `{ev}`: bad shard index"))?;
            let at_request: u64 = parts
                .next()
                .with_context(|| format!("fault event `{ev}`: missing request ordinal"))?
                .trim()
                .parse()
                .with_context(|| format!("fault event `{ev}`: bad request ordinal"))?;
            let arg = parts.next();
            let kind = match kind.trim() {
                "kill" => FaultKind::Kill,
                "stall" => FaultKind::Stall(parse_ms(ev, arg)?),
                "delay" => FaultKind::DelayDelivery(parse_ms(ev, arg)?),
                "panic" => FaultKind::Panic(
                    arg.map(str::to_string)
                        .unwrap_or_else(|| "injected panic".into()),
                ),
                other => bail!(
                    "fault event `{ev}`: unknown kind `{other}` (kill|stall|panic|delay)"
                ),
            };
            events.push(FaultEvent {
                shard,
                at_request,
                kind,
            });
        }
        Ok(Self { events })
    }

    /// Render back to the canonical spec string (parse ∘ render = id).
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| match &e.kind {
                FaultKind::Kill => format!("kill:{}:{}", e.shard, e.at_request),
                FaultKind::Stall(d) => {
                    format!("stall:{}:{}:{}", e.shard, e.at_request, d.as_millis())
                }
                FaultKind::Panic(m) => format!("panic:{}:{}:{m}", e.shard, e.at_request),
                FaultKind::DelayDelivery(d) => {
                    format!("delay:{}:{}:{}", e.shard, e.at_request, d.as_millis())
                }
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Generate the canonical seeded scenario: one hard kill on a
    /// pseudo-random shard at a pseudo-random executed-request ordinal
    /// in `1..horizon`. Same seed → same schedule; `render()` gives the
    /// equivalent literal spec for the experiment log.
    pub fn seeded_kill(seed: u64, shards: usize, horizon: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xfa_17);
        let shard = rng.below(shards.max(1) as u64) as usize;
        let at_request = 1 + rng.below(horizon.max(2) - 1);
        Self {
            events: vec![FaultEvent {
                shard,
                at_request,
                kind: FaultKind::Kill,
            }],
        }
    }

    /// The per-shard runtime plane for shard `shard` (only its events).
    pub fn plane_for(&self, shard: usize) -> FaultPlane {
        let mut events: Vec<FaultEvent> = self
            .events
            .iter()
            .filter(|e| e.shard == shard)
            .cloned()
            .collect();
        events.sort_by_key(|e| e.at_request);
        FaultPlane {
            events,
            counter: AtomicU64::new(0),
        }
    }

    /// True for the no-fault spec (no scheduled events).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

fn parse_ms(ev: &str, arg: Option<&str>) -> Result<Duration> {
    let ms: u64 = arg
        .with_context(|| format!("fault event `{ev}`: missing duration (ms)"))?
        .trim()
        .trim_end_matches("ms")
        .parse()
        .with_context(|| format!("fault event `{ev}`: bad duration (integer ms)"))?;
    Ok(Duration::from_millis(ms))
}

/// The folded effect of every fault event that fired in one
/// [`FaultPlane::on_requests`] window. Defaults to "no fault".
#[derive(Debug, Clone, Default)]
pub struct FaultAction {
    /// Hard-kill the shard before executing this work.
    pub kill: bool,
    /// Sleep this long before executing.
    pub stall: Option<Duration>,
    /// Panic with this message inside execution.
    pub panic_msg: Option<String>,
    /// Resolve tickets this much late.
    pub delay: Option<Duration>,
}

impl FaultAction {
    /// True when no fault event fired in the window.
    pub fn is_none(&self) -> bool {
        !self.kill && self.stall.is_none() && self.panic_msg.is_none() && self.delay.is_none()
    }
}

/// One shard's live fault plane: a shard-global executed-request counter
/// plus that shard's scheduled events. Lanes share it behind an `Arc`;
/// `on_requests` is lock-free.
#[derive(Debug)]
pub struct FaultPlane {
    /// Sorted by `at_request`.
    events: Vec<FaultEvent>,
    counter: AtomicU64,
}

impl FaultPlane {
    /// A plane with no scheduled events (counts requests, fires nothing).
    pub fn none() -> Self {
        Self {
            events: Vec::new(),
            counter: AtomicU64::new(0),
        }
    }

    /// Advance the shard's executed-request counter by `n` (one batch)
    /// and fold every event whose ordinal falls in the claimed window.
    /// Disjoint windows per call mean each event fires exactly once even
    /// with concurrent lanes.
    pub fn on_requests(&self, n: u64) -> FaultAction {
        let mut action = FaultAction::default();
        if n == 0 {
            return action;
        }
        let start = self.counter.fetch_add(n, Ordering::Relaxed);
        let end = start + n;
        for e in &self.events {
            if e.at_request < start {
                continue;
            }
            if e.at_request >= end {
                break;
            }
            match &e.kind {
                FaultKind::Kill => action.kill = true,
                FaultKind::Stall(d) => {
                    action.stall = Some(action.stall.map_or(*d, |s| s.max(*d)));
                }
                FaultKind::Panic(m) => {
                    action.panic_msg.get_or_insert_with(|| m.clone());
                }
                FaultKind::DelayDelivery(d) => {
                    action.delay = Some(action.delay.map_or(*d, |s| s.max(*d)));
                }
            }
        }
        action
    }

    /// Requests this shard's lanes have claimed so far.
    pub fn requests_seen(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        let spec = FaultSpec::parse("kill:1:5;stall:0:3:40;panic:0:2:boom;delay:1:0:15")
            .unwrap();
        assert_eq!(spec.events.len(), 4);
        assert_eq!(spec.events[0].kind, FaultKind::Kill);
        assert_eq!(
            spec.events[1].kind,
            FaultKind::Stall(Duration::from_millis(40))
        );
        assert_eq!(spec.events[2].kind, FaultKind::Panic("boom".into()));
        assert_eq!(
            spec.events[3].kind,
            FaultKind::DelayDelivery(Duration::from_millis(15))
        );
        let rendered = spec.render();
        assert_eq!(FaultSpec::parse(&rendered).unwrap(), spec);
    }

    #[test]
    fn parse_rejects_malformed_events() {
        assert!(FaultSpec::parse("kill:1").is_err(), "missing ordinal");
        assert!(FaultSpec::parse("kill:x:5").is_err(), "bad shard");
        assert!(FaultSpec::parse("stall:0:3").is_err(), "missing duration");
        assert!(FaultSpec::parse("explode:0:1").is_err(), "unknown kind");
        assert!(FaultSpec::parse("").unwrap().is_empty(), "empty = no faults");
        assert!(FaultSpec::parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn panic_message_may_contain_colons() {
        let spec = FaultSpec::parse("panic:0:1:a:b:c").unwrap();
        assert_eq!(spec.events[0].kind, FaultKind::Panic("a:b:c".into()));
    }

    #[test]
    fn plane_fires_each_event_exactly_once_per_window() {
        let spec = FaultSpec::parse("kill:0:5;stall:0:2:10").unwrap();
        let plane = spec.plane_for(0);
        // window [0, 2): nothing
        assert!(plane.on_requests(2).is_none());
        // window [2, 6): both the stall (at 2) and the kill (at 5)
        let a = plane.on_requests(4);
        assert!(a.kill);
        assert_eq!(a.stall, Some(Duration::from_millis(10)));
        // later windows: nothing left
        assert!(plane.on_requests(10).is_none());
        assert_eq!(plane.requests_seen(), 16);
    }

    #[test]
    fn plane_filters_by_shard() {
        let spec = FaultSpec::parse("kill:1:0").unwrap();
        let p0 = spec.plane_for(0);
        let p1 = spec.plane_for(1);
        assert!(!p0.on_requests(4).kill, "shard 0 has no events");
        assert!(p1.on_requests(1).kill, "shard 1 kills at its first request");
    }

    #[test]
    fn seeded_kill_is_reproducible() {
        let a = FaultSpec::seeded_kill(42, 3, 20);
        let b = FaultSpec::seeded_kill(42, 3, 20);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 1);
        assert_eq!(a.events[0].kind, FaultKind::Kill);
        assert!(a.events[0].shard < 3);
        assert!((1..20).contains(&a.events[0].at_request));
        // the rendered spec is the reproducible artifact
        assert_eq!(FaultSpec::parse(&a.render()).unwrap(), a);
    }
}
