//! Cluster wire protocol (ISSUE 10): length-prefixed, versioned frames
//! between the `ClusterFleet` front door and its `shard-worker`
//! processes.
//!
//! A frame is a 4-byte little-endian payload length followed by exactly
//! that many bytes of UTF-8 JSON (one [`WireMsg`]), parsed with the
//! crate's own `json_lite`. The framing layer is deliberately transport
//! agnostic — anything `Read`/`Write` carries it — so every rejection
//! path (truncated header, truncated payload, oversized length, garbage
//! JSON, unknown message type) is testable without a socket.
//!
//! Field encoding follows the trace-file rules
//! (`coordinator::traffic`): `u64` values that must survive exactly
//! (seeds) travel as decimal strings, every numeric field is validated
//! back into the 2^53 exact-integer window (nanosecond durations clamp
//! to that window at render, so no sendable frame is unreceivable), and
//! image tensors travel as hex-encoded little-endian `f32` bytes so a
//! result delivered across the wire is bit-identical to one delivered
//! in process.
//!
//! Versioning: the first frame each side sends is [`WireMsg::Hello`] /
//! [`WireMsg::HelloAck`] carrying [`WIRE_VERSION`]; a mismatch is
//! answered with [`WireMsg::Reject`] and the connection closes. Errors
//! from [`FrameReader`] carry the frame index and byte offset of the
//! failure.

use std::io::{Read, Write};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelChoice;
use crate::coordinator::metrics::{AdmissionStats, ServeMetrics};
use crate::coordinator::server::{
    AdmissionError, ClassifyRequest, DenoiseRequest, DenoiseResult, InferenceRequest,
};
use crate::runtime::TensorBuf;
use crate::util::json_lite::Json;

/// Protocol version spoken by this build. Bump on any frame or field
/// change; the handshake refuses mismatched peers instead of
/// misparsing them.
pub const WIRE_VERSION: u32 = 1;

/// Ceiling on one frame's payload length. Far above any real message
/// (a 3x32x32 result is ~25 KiB hex) — its job is to reject a
/// corrupted length prefix before it turns into a giant allocation.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Largest integer `f64` (and so json_lite) represents exactly: 2^53.
const MAX_EXACT: f64 = 9_007_199_254_740_992.0;

/// One protocol message. Direction conventions: `Hello`, `Submit`,
/// `Drain`, `MetricsReq`, and `Shutdown` flow front door → worker;
/// `HelloAck`, `Reject`, `SubmitErr`, `TicketResult`, `Heartbeat`, and
/// `Metrics` flow worker → front door.
#[derive(Debug)]
pub enum WireMsg {
    /// Handshake opener (front door → worker): the version the front
    /// door speaks and the worker slot it believes it is addressing.
    Hello {
        /// Sender's [`WIRE_VERSION`].
        version: u32,
        /// Worker slot index the connection is for.
        worker: usize,
    },
    /// Handshake acceptance (worker → front door).
    HelloAck {
        /// Worker's [`WIRE_VERSION`] (equal, or the worker rejects).
        version: u32,
        /// The worker slot index the worker was started as.
        worker: usize,
        /// Worker process id, for supervision and diagnostics.
        pid: u64,
    },
    /// Handshake refusal (worker → front door), e.g. version mismatch.
    /// The sender closes the connection after this frame.
    Reject {
        /// Human-readable refusal reason.
        reason: String,
    },
    /// Admit one request (front door → worker). `ticket` is the front
    /// door's correlation id; every later message about this request
    /// echoes it.
    Submit {
        /// Front-door correlation id.
        ticket: u64,
        /// The request, bit-exactly re-creatable on the worker.
        req: InferenceRequest,
    },
    /// The worker's admission queue refused the submit (worker → front
    /// door). `QueueFull` / `ShuttingDown` are retryable elsewhere;
    /// `Deadline` is terminal for the request.
    SubmitErr {
        /// Correlation id of the refused submit.
        ticket: u64,
        /// Why admission refused it.
        error: AdmissionError,
    },
    /// A request resolved (worker → front door): the result or a
    /// terminal execution/expiry error message.
    TicketResult {
        /// Correlation id of the resolved request.
        ticket: u64,
        /// The delivered result, or the error text it resolved with.
        result: std::result::Result<DenoiseResult, String>,
    },
    /// Periodic worker liveness (worker → front door): the lane-pulse
    /// sequence number and the instantaneous admission queue depth (the
    /// p2c routing signal).
    Heartbeat {
        /// Lane heartbeat sequence (`ShardPulse::seq`); frozen = wedged.
        seq: u64,
        /// Requests waiting in the worker's admission queue.
        queue_depth: u64,
    },
    /// Stop admission and finish everything already admitted (front
    /// door → worker). Every outstanding ticket still resolves.
    Drain,
    /// Ask for a live counters snapshot (front door → worker).
    MetricsReq,
    /// Counters snapshot (worker → front door); `last` marks the final
    /// post-shutdown snapshot, after which the worker exits.
    Metrics {
        /// True on the final snapshot a worker emits before exiting.
        last: bool,
        /// The counters.
        snapshot: WireMetrics,
    },
    /// Finish the session and exit (front door → worker). The worker
    /// answers with a final `Metrics { last: true, .. }` frame.
    Shutdown,
}

/// The counter subset of one worker's [`ServeMetrics`] that travels the
/// wire. Latency percentiles are *not* shipped: the front door records
/// end-to-end latency itself (submit → delivery, exactly like the
/// in-process `ShardFleet`), so per-worker rows carry throughput,
/// admission, and invariant counters only.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WireMetrics {
    /// Requests that resolved with a result.
    pub requests_done: u64,
    /// Executed steps (one per classification request).
    pub steps_done: u64,
    /// Device dispatches issued.
    pub dispatches: u64,
    /// Total request-slots across all dispatches.
    pub batch_items: u64,
    /// Tickets that resolved with an error.
    pub requests_failed: u64,
    /// Worker lanes that died during setup.
    pub lanes_down: u64,
    /// Batches that mixed models (invariant: stays 0).
    pub cross_model_batches: u64,
    /// Batches that mixed image shapes (invariant: stays 0).
    pub cross_shape_batches: u64,
    /// Session wall time, nanoseconds.
    pub wall_ns: u64,
    /// Admission counters (`AdmissionStats`, flattened).
    pub admission: AdmissionStats,
    /// Per-model `(done, steps, failed)` rows.
    pub per_model: Vec<WireModelRow>,
}

/// One per-model counters row of a [`WireMetrics`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireModelRow {
    /// The model this row covers.
    pub model: ModelChoice,
    /// Requests of this model that resolved with a result.
    pub requests_done: u64,
    /// Steps executed for this model.
    pub steps_done: u64,
    /// Requests of this model whose ticket resolved with an error.
    pub requests_failed: u64,
}

impl WireMetrics {
    /// Capture the wire-portable counter subset of a session snapshot.
    pub fn from_metrics(m: &ServeMetrics) -> Self {
        Self {
            requests_done: m.requests_done as u64,
            steps_done: m.steps_done as u64,
            dispatches: m.dispatches as u64,
            batch_items: m.batch_items as u64,
            requests_failed: m.requests_failed as u64,
            lanes_down: m.lanes_down as u64,
            cross_model_batches: m.cross_model_batches as u64,
            cross_shape_batches: m.cross_shape_batches as u64,
            wall_ns: ns_u64(m.wall),
            admission: m.admission,
            per_model: m
                .per_model
                .iter()
                .map(|r| WireModelRow {
                    model: r.model,
                    requests_done: r.requests_done as u64,
                    steps_done: r.steps_done as u64,
                    requests_failed: r.requests_failed as u64,
                })
                .collect(),
        }
    }

    /// Re-inflate into a [`ServeMetrics`] whose counters match the
    /// snapshot (histograms and percentiles stay empty — the front door
    /// records latency itself).
    pub fn to_metrics(&self) -> ServeMetrics {
        let mut m = ServeMetrics::new();
        m.requests_done = self.requests_done as usize;
        m.steps_done = self.steps_done as usize;
        m.dispatches = self.dispatches as usize;
        m.batch_items = self.batch_items as usize;
        m.requests_failed = self.requests_failed as usize;
        m.lanes_down = self.lanes_down as usize;
        m.cross_model_batches = self.cross_model_batches as usize;
        m.cross_shape_batches = self.cross_shape_batches as usize;
        m.wall = Duration::from_nanos(self.wall_ns);
        m.admission = self.admission;
        for row in &self.per_model {
            let slot = &mut m.per_model[row.model.index()];
            slot.requests_done = row.requests_done as usize;
            slot.steps_done = row.steps_done as usize;
            slot.requests_failed = row.requests_failed as usize;
        }
        m
    }
}

// ---------------------------------------------------------------------
// Rendering (struct -> JSON payload)
// ---------------------------------------------------------------------

/// Escape a string for embedding in a JSON payload.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A duration as nanoseconds, clamped to the 2^53 exact-integer window
/// the wire's numeric fields accept. `field_u64` rejects anything
/// larger on receive, so an unclamped render (a configured deadline
/// over ~104 days) would produce a frame the peer's [`FrameReader`]
/// refuses — killing the connection and, worse, re-killing it on every
/// respawn that re-sends the same request.
fn ns_u64(d: Duration) -> u64 {
    d.as_nanos().min(MAX_EXACT as u128) as u64
}

fn deadline_json(d: Option<Duration>) -> String {
    match d {
        Some(d) => format!("{}", ns_u64(d)),
        None => "null".into(),
    }
}

/// Stable wire code of an admission error.
fn admission_code(e: AdmissionError) -> &'static str {
    match e {
        AdmissionError::QueueFull => "queue_full",
        AdmissionError::Deadline => "deadline",
        AdmissionError::ShuttingDown => "shutting_down",
        AdmissionError::NoLiveShards => "no_live_shards",
    }
}

fn parse_admission_code(s: &str) -> Result<AdmissionError> {
    Ok(match s {
        "queue_full" => AdmissionError::QueueFull,
        "deadline" => AdmissionError::Deadline,
        "shutting_down" => AdmissionError::ShuttingDown,
        "no_live_shards" => AdmissionError::NoLiveShards,
        other => bail!("unknown admission error code `{other}`"),
    })
}

/// Hex-encode `f32` data as little-endian bytes — exact bit round-trip,
/// NaN payloads and signed zeros included.
fn hex_of_f32(data: &[f32]) -> String {
    let mut out = String::with_capacity(data.len() * 8);
    for v in data {
        for b in v.to_le_bytes() {
            out.push_str(&format!("{b:02x}"));
        }
    }
    out
}

fn f32_of_hex(s: &str) -> Result<Vec<f32>> {
    if s.len() % 8 != 0 {
        bail!("image hex length {} is not a multiple of 8", s.len());
    }
    if !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        bail!("image hex contains a non-hex character");
    }
    let mut out = Vec::with_capacity(s.len() / 8);
    let bytes = s.as_bytes();
    for chunk in bytes.chunks_exact(8) {
        let mut le = [0u8; 4];
        for (i, pair) in chunk.chunks_exact(2).enumerate() {
            let hi = (pair[0] as char).to_digit(16).unwrap() as u8;
            let lo = (pair[1] as char).to_digit(16).unwrap() as u8;
            le[i] = (hi << 4) | lo;
        }
        out.push(f32::from_le_bytes(le));
    }
    Ok(out)
}

/// Render one request as a JSON object, the trace-record field rules
/// (`kind` / `id` / `seed`-as-string / `steps` or `model` / `priority`
/// / `deadline_ns`).
fn render_request(req: &InferenceRequest) -> String {
    match req {
        InferenceRequest::Denoise(r) => format!(
            "{{\"kind\":\"denoise\",\"id\":{},\"seed\":\"{}\",\"steps\":{},\
             \"priority\":{},\"deadline_ns\":{}}}",
            r.id,
            r.seed,
            r.steps,
            r.priority,
            deadline_json(r.deadline)
        ),
        InferenceRequest::Classify(r) => format!(
            "{{\"kind\":\"classify\",\"id\":{},\"seed\":\"{}\",\"model\":\"{}\",\
             \"priority\":{},\"deadline_ns\":{}}}",
            r.id,
            r.seed,
            r.model.name(),
            r.priority,
            deadline_json(r.deadline)
        ),
    }
}

fn render_result(r: &DenoiseResult) -> String {
    let shape = r
        .image
        .shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"id\":{},\"shape\":[{}],\"image\":\"{}\",\"latency_ns\":{},\
         \"steps\":{},\"model\":\"{}\"}}",
        r.id,
        shape,
        hex_of_f32(&r.image.data),
        ns_u64(r.latency),
        r.steps,
        r.model.name()
    )
}

fn render_wire_metrics(m: &WireMetrics) -> String {
    let rows = m
        .per_model
        .iter()
        .map(|r| {
            format!(
                "{{\"model\":\"{}\",\"done\":{},\"steps\":{},\"failed\":{}}}",
                r.model.name(),
                r.requests_done,
                r.steps_done,
                r.requests_failed
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let a = &m.admission;
    format!(
        "{{\"done\":{},\"steps\":{},\"dispatches\":{},\"batch_items\":{},\
         \"failed\":{},\"lanes_down\":{},\"cross_model\":{},\"cross_shape\":{},\
         \"wall_ns\":{},\"offered\":{},\"admitted\":{},\"rej_full\":{},\
         \"rej_deadline\":{},\"rej_shutdown\":{},\"expired\":{},\
         \"queue_depth\":{},\"per_model\":[{}]}}",
        m.requests_done,
        m.steps_done,
        m.dispatches,
        m.batch_items,
        m.requests_failed,
        m.lanes_down,
        m.cross_model_batches,
        m.cross_shape_batches,
        m.wall_ns,
        a.offered,
        a.admitted,
        a.rejected_queue_full,
        a.rejected_deadline,
        a.rejected_shutdown,
        a.expired,
        a.queue_depth,
        rows
    )
}

impl WireMsg {
    /// Render the message as its JSON payload (no frame header).
    pub fn render(&self) -> String {
        match self {
            WireMsg::Hello { version, worker } => {
                format!("{{\"type\":\"hello\",\"version\":{version},\"worker\":{worker}}}")
            }
            WireMsg::HelloAck {
                version,
                worker,
                pid,
            } => format!(
                "{{\"type\":\"hello_ack\",\"version\":{version},\"worker\":{worker},\
                 \"pid\":{pid}}}"
            ),
            WireMsg::Reject { reason } => {
                format!("{{\"type\":\"reject\",\"reason\":\"{}\"}}", esc(reason))
            }
            WireMsg::Submit { ticket, req } => format!(
                "{{\"type\":\"submit\",\"ticket\":{ticket},\"req\":{}}}",
                render_request(req)
            ),
            WireMsg::SubmitErr { ticket, error } => format!(
                "{{\"type\":\"submit_err\",\"ticket\":{ticket},\"error\":\"{}\"}}",
                admission_code(*error)
            ),
            WireMsg::TicketResult { ticket, result } => match result {
                Ok(r) => format!(
                    "{{\"type\":\"result\",\"ticket\":{ticket},\"ok\":{}}}",
                    render_result(r)
                ),
                Err(e) => format!(
                    "{{\"type\":\"result\",\"ticket\":{ticket},\"err\":\"{}\"}}",
                    esc(e)
                ),
            },
            WireMsg::Heartbeat { seq, queue_depth } => format!(
                "{{\"type\":\"heartbeat\",\"seq\":{seq},\"queue_depth\":{queue_depth}}}"
            ),
            WireMsg::Drain => "{\"type\":\"drain\"}".into(),
            WireMsg::MetricsReq => "{\"type\":\"metrics_req\"}".into(),
            WireMsg::Metrics { last, snapshot } => format!(
                "{{\"type\":\"metrics\",\"last\":{last},\"snapshot\":{}}}",
                render_wire_metrics(snapshot)
            ),
            WireMsg::Shutdown => "{\"type\":\"shutdown\"}".into(),
        }
    }

    /// Parse a frame payload back into a message. Errors name the bad
    /// or missing field; [`FrameReader`] adds the frame/byte position.
    pub fn parse(payload: &str) -> Result<WireMsg> {
        let v = Json::parse(payload).context("payload is not valid JSON")?;
        let ty = field_str(&v, "type")?;
        Ok(match ty {
            "hello" => WireMsg::Hello {
                version: field_u64(&v, "version")? as u32,
                worker: field_u64(&v, "worker")? as usize,
            },
            "hello_ack" => WireMsg::HelloAck {
                version: field_u64(&v, "version")? as u32,
                worker: field_u64(&v, "worker")? as usize,
                pid: field_u64(&v, "pid")?,
            },
            "reject" => WireMsg::Reject {
                reason: field_str(&v, "reason")?.to_string(),
            },
            "submit" => WireMsg::Submit {
                ticket: field_u64(&v, "ticket")?,
                req: parse_request(
                    v.get("req").ok_or_else(|| anyhow!("missing `req`"))?,
                )?,
            },
            "submit_err" => WireMsg::SubmitErr {
                ticket: field_u64(&v, "ticket")?,
                error: parse_admission_code(field_str(&v, "error")?)?,
            },
            "result" => {
                let ticket = field_u64(&v, "ticket")?;
                let result = match (v.get("ok"), v.get("err")) {
                    (Some(ok), None) => Ok(parse_result(ok)?),
                    (None, Some(e)) => Err(e
                        .as_str()
                        .ok_or_else(|| anyhow!("`err` must be a string"))?
                        .to_string()),
                    _ => bail!("result frame needs exactly one of `ok` / `err`"),
                };
                WireMsg::TicketResult { ticket, result }
            }
            "heartbeat" => WireMsg::Heartbeat {
                seq: field_u64(&v, "seq")?,
                queue_depth: field_u64(&v, "queue_depth")?,
            },
            "drain" => WireMsg::Drain,
            "metrics_req" => WireMsg::MetricsReq,
            "metrics" => WireMsg::Metrics {
                last: v
                    .get("last")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("missing or non-boolean `last`"))?,
                snapshot: parse_wire_metrics(
                    v.get("snapshot")
                        .ok_or_else(|| anyhow!("missing `snapshot`"))?,
                )?,
            },
            "shutdown" => WireMsg::Shutdown,
            other => bail!("unknown message type `{other}`"),
        })
    }
}

// ---------------------------------------------------------------------
// Parsing helpers (JSON -> struct)
// ---------------------------------------------------------------------

/// Exact-integer numeric field: rejects negatives, fractions, and
/// values beyond 2^53 (where `f64` stops being exact).
fn field_u64(v: &Json, key: &str) -> Result<u64> {
    let f = v
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !(0.0..=MAX_EXACT).contains(&f) || f.fract() != 0.0 {
        bail!("`{key}` out of exact-integer range: {f}");
    }
    Ok(f as u64)
}

fn field_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing or non-string `{key}`"))
}

fn parse_request(v: &Json) -> Result<InferenceRequest> {
    let id = field_u64(v, "id")?;
    let seed: u64 = field_str(v, "seed")?
        .parse()
        .map_err(|_| anyhow!("bad `seed` (expected a decimal u64 string)"))?;
    let priority_raw = field_u64(v, "priority")?;
    if priority_raw > u8::MAX as u64 {
        bail!("`priority` out of range: {priority_raw}");
    }
    let priority = priority_raw as u8;
    let deadline = match v.get("deadline_ns") {
        None | Some(Json::Null) => None,
        Some(_) => Some(Duration::from_nanos(field_u64(v, "deadline_ns")?)),
    };
    Ok(match field_str(v, "kind")? {
        "denoise" => {
            let steps = field_u64(v, "steps")? as usize;
            if steps == 0 {
                bail!("`steps` must be >= 1");
            }
            InferenceRequest::Denoise(DenoiseRequest {
                id,
                seed,
                steps,
                priority,
                deadline,
            })
        }
        "classify" => InferenceRequest::Classify(ClassifyRequest {
            id,
            seed,
            model: ModelChoice::parse(field_str(v, "model")?).context("bad `model`")?,
            priority,
            deadline,
        }),
        other => bail!("unknown `kind` `{other}` (expected denoise | classify)"),
    })
}

fn parse_result(v: &Json) -> Result<DenoiseResult> {
    let shape: Vec<usize> = v
        .get("shape")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("missing or non-array `shape`"))?
        .iter()
        .map(|d| {
            d.as_f64()
                .filter(|f| (0.0..=MAX_EXACT).contains(f) && f.fract() == 0.0)
                .map(|f| f as usize)
                .ok_or_else(|| anyhow!("bad `shape` element"))
        })
        .collect::<Result<_>>()?;
    let data = f32_of_hex(field_str(v, "image")?)?;
    let image = TensorBuf::new(shape, data).context("inconsistent `shape` / `image`")?;
    Ok(DenoiseResult {
        id: field_u64(v, "id")?,
        image,
        latency: Duration::from_nanos(field_u64(v, "latency_ns")?),
        steps: field_u64(v, "steps")? as usize,
        model: ModelChoice::parse(field_str(v, "model")?).context("bad `model`")?,
    })
}

fn parse_wire_metrics(v: &Json) -> Result<WireMetrics> {
    let mut per_model = Vec::new();
    for row in v
        .get("per_model")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("missing or non-array `per_model`"))?
    {
        per_model.push(WireModelRow {
            model: ModelChoice::parse(field_str(row, "model")?).context("bad `model`")?,
            requests_done: field_u64(row, "done")?,
            steps_done: field_u64(row, "steps")?,
            requests_failed: field_u64(row, "failed")?,
        });
    }
    Ok(WireMetrics {
        requests_done: field_u64(v, "done")?,
        steps_done: field_u64(v, "steps")?,
        dispatches: field_u64(v, "dispatches")?,
        batch_items: field_u64(v, "batch_items")?,
        requests_failed: field_u64(v, "failed")?,
        lanes_down: field_u64(v, "lanes_down")?,
        cross_model_batches: field_u64(v, "cross_model")?,
        cross_shape_batches: field_u64(v, "cross_shape")?,
        wall_ns: field_u64(v, "wall_ns")?,
        admission: AdmissionStats {
            offered: field_u64(v, "offered")?,
            admitted: field_u64(v, "admitted")?,
            rejected_queue_full: field_u64(v, "rej_full")?,
            rejected_deadline: field_u64(v, "rej_deadline")?,
            rejected_shutdown: field_u64(v, "rej_shutdown")?,
            expired: field_u64(v, "expired")?,
            queue_depth: field_u64(v, "queue_depth")? as usize,
        },
        per_model,
    })
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// Write one message as a frame: 4-byte little-endian payload length,
/// then the JSON payload. Flushes, so a frame is visible to the peer as
/// soon as this returns.
pub fn write_frame<W: Write>(w: &mut W, msg: &WireMsg) -> Result<()> {
    let payload = msg.render();
    let len = payload.len();
    if len as u64 > MAX_FRAME as u64 {
        bail!("refusing to send oversized frame ({len} bytes > max {MAX_FRAME})");
    }
    w.write_all(&(len as u32).to_le_bytes())
        .context("writing frame header")?;
    w.write_all(payload.as_bytes())
        .context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Reads frames off any byte stream, tracking the frame index and byte
/// offset so every rejection (truncation, oversized length, garbage
/// payload) reports *where* the stream went bad.
#[derive(Debug)]
pub struct FrameReader<R: Read> {
    inner: R,
    /// Frames fully consumed so far; the next frame is index `frames`.
    frames: u64,
    /// Bytes consumed so far (frame headers included).
    offset: u64,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a byte stream at position 0.
    pub fn new(inner: R) -> Self {
        Self {
            inner,
            frames: 0,
            offset: 0,
        }
    }

    /// Frames fully read so far.
    pub fn frames_read(&self) -> u64 {
        self.frames
    }

    /// Read into `buf` until full. Returns bytes read, which is short
    /// only at EOF.
    fn fill(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut got = 0;
        while got < buf.len() {
            match self.inner.read(&mut buf[got..]) {
                Ok(0) => break,
                Ok(n) => got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "frame {} at byte {}: read failed",
                            self.frames,
                            self.offset + got as u64
                        )
                    })
                }
            }
        }
        Ok(got)
    }

    /// Read the next frame. `Ok(None)` on a clean EOF at a frame
    /// boundary; every other shortfall is an error carrying the frame
    /// index and byte offset.
    pub fn next_msg(&mut self) -> Result<Option<WireMsg>> {
        let mut header = [0u8; 4];
        let got = self.fill(&mut header)?;
        if got == 0 {
            return Ok(None); // clean EOF between frames
        }
        if got < 4 {
            bail!(
                "frame {} at byte {}: truncated header ({got} of 4 bytes)",
                self.frames,
                self.offset
            );
        }
        let len = u32::from_le_bytes(header);
        if len > MAX_FRAME {
            bail!(
                "frame {} at byte {}: oversized frame ({len} bytes > max {MAX_FRAME})",
                self.frames,
                self.offset
            );
        }
        let mut payload = vec![0u8; len as usize];
        let got = self.fill(&mut payload)?;
        if got < payload.len() {
            bail!(
                "frame {} at byte {}: truncated payload ({got} of {len} bytes)",
                self.frames,
                self.offset + 4
            );
        }
        let text = std::str::from_utf8(&payload).map_err(|e| {
            anyhow!(
                "frame {} at byte {}: payload is not UTF-8 ({e})",
                self.frames,
                self.offset + 4
            )
        })?;
        let msg = WireMsg::parse(text).with_context(|| {
            format!(
                "frame {} at byte {}: bad payload",
                self.frames,
                self.offset + 4
            )
        })?;
        self.offset += 4 + len as u64;
        self.frames += 1;
        Ok(Some(msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &WireMsg) -> WireMsg {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg).unwrap();
        let mut r = FrameReader::new(&buf[..]);
        let back = r.next_msg().unwrap().expect("one frame");
        assert!(r.next_msg().unwrap().is_none(), "clean EOF after frame");
        back
    }

    #[test]
    fn result_image_bits_roundtrip_exactly() {
        let data = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e-7, 1e30];
        let msg = WireMsg::TicketResult {
            ticket: 9,
            result: Ok(DenoiseResult {
                id: 3,
                image: TensorBuf::new(vec![2, 3], data.clone()).unwrap(),
                latency: Duration::from_nanos(123_456),
                steps: 4,
                model: ModelChoice::Unet,
            }),
        };
        match roundtrip(&msg) {
            WireMsg::TicketResult {
                ticket,
                result: Ok(r),
            } => {
                assert_eq!(ticket, 9);
                assert_eq!(r.id, 3);
                assert_eq!(r.image.shape, vec![2, 3]);
                let want: Vec<u32> = data.iter().map(|f| f.to_bits()).collect();
                let got: Vec<u32> = r.image.data.iter().map(|f| f.to_bits()).collect();
                assert_eq!(want, got, "bit-exact image transport");
                assert_eq!(r.latency, Duration::from_nanos(123_456));
                assert_eq!(r.steps, 4);
                assert_eq!(r.model, ModelChoice::Unet);
            }
            other => panic!("wrong message back: {other:?}"),
        }
    }

    #[test]
    fn truncated_header_and_payload_carry_position() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &WireMsg::Drain).unwrap();
        let whole = buf.len();
        // cut inside the second frame's header
        write_frame(&mut buf, &WireMsg::Shutdown).unwrap();
        let cut = &buf[..whole + 2];
        let mut r = FrameReader::new(cut);
        assert!(matches!(r.next_msg().unwrap(), Some(WireMsg::Drain)));
        let err = r.next_msg().unwrap_err().to_string();
        assert!(err.contains("frame 1"), "{err}");
        assert!(err.contains(&format!("byte {whole}")), "{err}");
        assert!(err.contains("truncated header"), "{err}");
        // cut inside the second frame's payload
        let cut = &buf[..whole + 6];
        let mut r = FrameReader::new(cut);
        r.next_msg().unwrap();
        let err = r.next_msg().unwrap_err().to_string();
        assert!(err.contains("truncated payload"), "{err}");
        assert!(err.contains(&format!("byte {}", whole + 4)), "{err}");
    }

    #[test]
    fn oversized_and_garbage_frames_rejected() {
        let mut buf = (MAX_FRAME + 1).to_le_bytes().to_vec();
        buf.extend_from_slice(b"xx");
        let err = FrameReader::new(&buf[..]).next_msg().unwrap_err().to_string();
        assert!(err.contains("oversized frame"), "{err}");

        let payload = b"not json at all";
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(payload);
        let err = FrameReader::new(&buf[..]).next_msg().unwrap_err().to_string();
        assert!(err.contains("frame 0"), "{err}");
        assert!(err.contains("bad payload"), "{err}");
    }

    #[test]
    fn unknown_type_and_bad_fields_rejected() {
        assert!(WireMsg::parse("{\"type\":\"warp\"}").is_err());
        assert!(WireMsg::parse("{\"type\":\"hello\",\"version\":1}").is_err());
        assert!(
            WireMsg::parse("{\"type\":\"heartbeat\",\"seq\":-1,\"queue_depth\":0}").is_err(),
            "negative counters rejected"
        );
        assert!(
            WireMsg::parse("{\"type\":\"submit_err\",\"ticket\":1,\"error\":\"oom\"}").is_err(),
            "unknown admission code rejected"
        );
    }

    #[test]
    fn huge_nanosecond_fields_clamp_instead_of_poisoning_the_wire() {
        // A deadline beyond the 2^53-ns exact window (~104 days) must
        // render as a frame the receiving FrameReader accepts — an
        // unclamped render would kill the connection on every delivery
        // attempt, poisoning the respawn loop.
        let msg = WireMsg::Submit {
            ticket: 1,
            req: InferenceRequest::Denoise(DenoiseRequest {
                id: 7,
                seed: 42,
                steps: 2,
                priority: 0,
                deadline: Some(Duration::MAX),
            }),
        };
        match roundtrip(&msg) {
            WireMsg::Submit { ticket, req } => {
                assert_eq!(ticket, 1);
                let InferenceRequest::Denoise(r) = req else {
                    panic!("wrong request kind back");
                };
                assert_eq!(
                    r.deadline,
                    Some(Duration::from_nanos(MAX_EXACT as u64)),
                    "deadline clamps to the 2^53-ns window"
                );
            }
            other => panic!("wrong message back: {other:?}"),
        }
        // same clamp on the result's latency field
        let msg = WireMsg::TicketResult {
            ticket: 2,
            result: Ok(DenoiseResult {
                id: 1,
                image: TensorBuf::new(vec![1], vec![0.5f32]).unwrap(),
                latency: Duration::MAX,
                steps: 1,
                model: ModelChoice::Unet,
            }),
        };
        match roundtrip(&msg) {
            WireMsg::TicketResult { result: Ok(r), .. } => {
                assert_eq!(r.latency, Duration::from_nanos(MAX_EXACT as u64));
            }
            other => panic!("wrong message back: {other:?}"),
        }
    }

    #[test]
    fn hex_codec_rejects_malformed_input() {
        assert!(f32_of_hex("0000803").is_err(), "odd length");
        assert!(f32_of_hex("zz00803f").is_err(), "non-hex chars");
        assert_eq!(f32_of_hex("0000803f").unwrap(), vec![1.0f32]);
    }
}
