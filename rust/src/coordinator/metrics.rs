//! Serving metrics: wall-clock latency/throughput, batching/pipeline
//! behaviour, plus the co-simulated accelerator's cycles/energy for the
//! same work.

use std::time::Duration;

use crate::config::ModelChoice;
use crate::runtime::PoolStats;
use crate::sim::energy::{EnergyModel, EventCounts, PpaReport};
use crate::util::stats::{LatencyHist, StreamingPercentiles};

/// Admission-control counters of a streaming serving session (ISSUE 5).
/// All counters are cumulative since `start()`; `queue_depth` is the
/// instantaneous backlog at snapshot time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Submission attempts (admitted + rejected).
    pub offered: u64,
    /// Requests accepted into the bounded queue.
    pub admitted: u64,
    /// `try_submit` attempts bounced off a full queue.
    pub rejected_queue_full: u64,
    /// Requests whose deadline was already unmeetable at admission.
    pub rejected_deadline: u64,
    /// Submissions after shutdown began.
    pub rejected_shutdown: u64,
    /// Admitted requests whose deadline passed while still queued (their
    /// tickets resolve with an error instead of occupying a lane).
    pub expired: u64,
    /// Requests waiting in the admission queue right now.
    pub queue_depth: usize,
}

impl AdmissionStats {
    /// Total submissions turned away (for any reason).
    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue_full + self.rejected_deadline + self.rejected_shutdown
    }
}

/// Per-model slice of a multi-mode session's counters (ISSUE 7): the
/// SF-MMCN fleet serves U-net denoise plus ResNet-18 / VGG-16
/// classification side by side, and capacity planning needs each mode's
/// throughput, tail latency, and co-simulated accelerator counts on its
/// own row — the aggregate hides an 8× per-request cost spread.
#[derive(Debug, Clone)]
pub struct ModelMetrics {
    /// The model this row covers.
    pub model: ModelChoice,
    /// Requests of this model that resolved with a result.
    pub requests_done: usize,
    /// Executed steps (denoise steps for the U-net; one per
    /// classification request).
    pub steps_done: usize,
    /// Requests of this model whose ticket resolved with an error.
    pub requests_failed: usize,
    /// End-to-end latency (admission → ticket resolution) of this
    /// model's requests, P² fixed-memory percentiles.
    pub e2e_latency: StreamingPercentiles,
    /// Co-simulated accelerator counts for this model's share of the
    /// work (attached by shutdown when co-simulation is enabled).
    pub sim_counts: Option<EventCounts>,
}

impl ModelMetrics {
    /// An empty row for `model`.
    pub fn new(model: ModelChoice) -> Self {
        Self {
            model,
            requests_done: 0,
            steps_done: 0,
            requests_failed: 0,
            e2e_latency: StreamingPercentiles::new(),
            sim_counts: None,
        }
    }

    /// One row per model in [`ModelChoice::ALL`] order — every
    /// `per_model` vector in this module is indexable by
    /// [`ModelChoice::index`].
    pub fn rows() -> Vec<Self> {
        ModelChoice::ALL.iter().map(|&m| Self::new(m)).collect()
    }

    /// Anything to report for this model?
    pub fn has_traffic(&self) -> bool {
        self.requests_done + self.requests_failed > 0
    }

    /// Price this model's co-simulated counts under an energy model —
    /// the per-mode cycles/energy and GOPs/mm² area-efficiency FoM.
    pub fn sim_report(&self, model: &EnergyModel, units: u64) -> Option<PpaReport> {
        self.sim_counts.as_ref().map(|c| model.report(c, units))
    }

    fn render_line(&self) -> String {
        format!(
            "  {}: {} done, {} steps, {} failed  e2e p50 {:.2} ms  p99 {:.2} ms\n",
            self.model.name(),
            self.requests_done,
            self.steps_done,
            self.requests_failed,
            self.e2e_latency.p50_us() / 1e3,
            self.e2e_latency.p99_us() / 1e3,
        )
    }
}

/// Aggregated results of one serving session.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    /// Per-request end-to-end latency.
    pub request_latency: LatencyHist,
    /// Per-denoise-step latency.
    pub step_latency: LatencyHist,
    /// Host-side batch preparation latency (noise + embeddings), one
    /// sample per prepared batch. Empty on the per-request path.
    pub host_prep: LatencyHist,
    /// Requests that resolved with a result.
    pub requests_done: usize,
    /// Denoise steps executed (one per classification request).
    pub steps_done: usize,
    /// Device dispatches issued (batched mode: one per timestep chunk;
    /// per-request mode: one per step, or per request when fused).
    pub dispatches: usize,
    /// Total request-slots across all dispatches; `batch_occupancy()` =
    /// `batch_items / dispatches`.
    pub batch_items: usize,
    /// Times a worker's device lane had to wait on the host stage (the
    /// double buffer was empty when the device went to fetch work).
    pub pipeline_stalls: usize,
    /// Buffer-pool leases served from the free list, summed across the
    /// per-worker pools (ISSUE 4).
    pub pool_hits: u64,
    /// Buffer-pool leases that had to allocate. In steady state this
    /// stays flat — only warmup (the first few batches per worker)
    /// allocates.
    pub pool_misses: u64,
    /// Total bytes leased from the per-worker pools (hit or miss).
    pub pool_bytes_leased: u64,
    /// Requests completed per worker — the batcher-fairness signal.
    pub per_worker_requests: Vec<usize>,
    /// Session wall time (start → drain complete).
    pub wall: Duration,
    /// Co-simulated accelerator counts for all served work (if enabled).
    pub sim_counts: Option<EventCounts>,
    /// Admission-control counters of the streaming session (ISSUE 5).
    /// All zero on workloads that never touch the bounded queue.
    pub admission: AdmissionStats,
    /// Admitted requests whose ticket resolved with an error (bad step
    /// counts, dispatch failures) — distinct from `admission.expired`.
    pub requests_failed: usize,
    /// Worker lanes that died during setup (a session with all lanes down
    /// drains its queue with errors instead of hanging tickets).
    pub lanes_down: usize,
    /// End-to-end latency (admission -> ticket resolution, queue wait
    /// included) via the fixed-memory P² estimator. Together with the
    /// bounded-reservoir [`LatencyHist`]s above, every metric here is
    /// O(1) in session length, so live snapshots of a week-long session
    /// cost the same as minute-one snapshots.
    pub e2e_latency: StreamingPercentiles,
    /// Per-model breakdown (ISSUE 7), one row per [`ModelChoice::ALL`]
    /// entry, indexable by [`ModelChoice::index`]. Pure-diffusion
    /// sessions leave the classification rows at zero.
    pub per_model: Vec<ModelMetrics>,
    /// Batches that mixed models — the batcher invariant says this stays
    /// 0; anything else is a routing bug (rendered as a warning).
    pub cross_model_batches: usize,
    /// Batches that mixed served-image shapes (ISSUE 9): the batch key's
    /// shape component makes this impossible by construction, so like
    /// `cross_model_batches` this stays 0 and anything else is a routing
    /// bug (rendered as a warning).
    pub cross_shape_batches: usize,
}

impl ServeMetrics {
    /// An all-zero metrics block (what a session starts from).
    pub fn new() -> Self {
        Self {
            request_latency: LatencyHist::new(),
            step_latency: LatencyHist::new(),
            host_prep: LatencyHist::new(),
            requests_done: 0,
            steps_done: 0,
            dispatches: 0,
            batch_items: 0,
            pipeline_stalls: 0,
            pool_hits: 0,
            pool_misses: 0,
            pool_bytes_leased: 0,
            per_worker_requests: Vec::new(),
            wall: Duration::ZERO,
            sim_counts: None,
            admission: AdmissionStats::default(),
            requests_failed: 0,
            lanes_down: 0,
            e2e_latency: StreamingPercentiles::new(),
            per_model: ModelMetrics::rows(),
            cross_model_batches: 0,
            cross_shape_batches: 0,
        }
    }

    /// True when any non-U-net model carried traffic — the signal that
    /// per-model breakdown lines are worth rendering.
    pub fn is_multi_mode(&self) -> bool {
        self.per_model
            .iter()
            .any(|r| r.model != ModelChoice::Unet && r.has_traffic())
    }

    /// Completed-request throughput over the session wall time.
    pub fn requests_per_s(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.requests_done as f64 / self.wall.as_secs_f64()
    }

    /// Executed-step throughput over the session wall time.
    pub fn steps_per_s(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.steps_done as f64 / self.wall.as_secs_f64()
    }

    /// Mean requests per device dispatch (1.0 = no cross-request batching).
    pub fn batch_occupancy(&self) -> f64 {
        if self.dispatches == 0 {
            return 0.0;
        }
        self.batch_items as f64 / self.dispatches as f64
    }

    /// Fraction of buffer-pool leases served without allocating (the
    /// aggregated counters viewed through [`PoolStats::hit_rate`]).
    pub fn pool_hit_rate(&self) -> f64 {
        PoolStats {
            hits: self.pool_hits,
            misses: self.pool_misses,
            ..Default::default()
        }
        .hit_rate()
    }

    /// Price the co-simulated counts under an energy model.
    pub fn sim_report(&self, model: &EnergyModel, units: u64) -> Option<PpaReport> {
        self.sim_counts.as_ref().map(|c| model.report(c, units))
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {} in {:.2}s  ({:.2} req/s, {:.1} steps/s)\n",
            self.requests_done,
            self.wall.as_secs_f64(),
            self.requests_per_s(),
            self.steps_per_s()
        ));
        s.push_str(&format!(
            "request latency: mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}\n",
            self.request_latency.mean_us() / 1e3,
            self.request_latency.percentile_us(50.0) / 1e3,
            self.request_latency.percentile_us(95.0) / 1e3,
            self.request_latency.percentile_us(99.0) / 1e3,
        ));
        s.push_str(&format!(
            "step latency: mean {:.3} ms  p95 {:.3} ms\n",
            self.step_latency.mean_us() / 1e3,
            self.step_latency.percentile_us(95.0) / 1e3,
        ));
        if self.e2e_latency.count() > 0 {
            s.push_str(&format!(
                "e2e latency (queue + service, streaming): mean {:.2} ms  \
                 p50 {:.2}  p95 {:.2}  p99 {:.2}\n",
                self.e2e_latency.mean_us() / 1e3,
                self.e2e_latency.p50_us() / 1e3,
                self.e2e_latency.p95_us() / 1e3,
                self.e2e_latency.p99_us() / 1e3,
            ));
        }
        if self.admission.offered > 0 {
            s.push_str(&format!(
                "admission: {} offered, {} admitted, {} rejected \
                 (full {} / deadline {} / shutdown {}), {} expired, queue depth {}\n",
                self.admission.offered,
                self.admission.admitted,
                self.admission.rejected_total(),
                self.admission.rejected_queue_full,
                self.admission.rejected_deadline,
                self.admission.rejected_shutdown,
                self.admission.expired,
                self.admission.queue_depth,
            ));
        }
        if self.is_multi_mode() {
            s.push_str("per-model:\n");
            for row in self.per_model.iter().filter(|r| r.has_traffic()) {
                s.push_str(&row.render_line());
            }
        }
        if self.cross_model_batches > 0 {
            s.push_str(&format!(
                "WARNING: {} batch(es) mixed models — batcher invariant violated\n",
                self.cross_model_batches
            ));
        }
        if self.cross_shape_batches > 0 {
            s.push_str(&format!(
                "WARNING: {} batch(es) mixed image shapes — batcher invariant violated\n",
                self.cross_shape_batches
            ));
        }
        if self.requests_failed > 0 {
            s.push_str(&format!(
                "failed requests: {} (tickets resolved with an error)\n",
                self.requests_failed
            ));
        }
        if self.lanes_down > 0 {
            s.push_str(&format!("worker lanes down: {}\n", self.lanes_down));
        }
        if self.dispatches > 0 {
            s.push_str(&format!(
                "dispatches: {}  batch occupancy: {:.2} req/dispatch  pipeline stalls: {}\n",
                self.dispatches,
                self.batch_occupancy(),
                self.pipeline_stalls,
            ));
        }
        if self.pool_hits + self.pool_misses > 0 {
            s.push_str(&format!(
                "buffer pool: {} hits / {} misses ({:.1}% hit rate), {:.1} MB leased\n",
                self.pool_hits,
                self.pool_misses,
                self.pool_hit_rate() * 100.0,
                self.pool_bytes_leased as f64 / 1e6,
            ));
        }
        if self.host_prep.count() > 0 {
            s.push_str(&format!(
                "host prep: mean {:.3} ms/batch ({} batches, overlapped with device)\n",
                self.host_prep.mean_us() / 1e3,
                self.host_prep.count(),
            ));
        }
        if !self.per_worker_requests.is_empty() {
            let min = self.per_worker_requests.iter().min().copied().unwrap_or(0);
            let max = self.per_worker_requests.iter().max().copied().unwrap_or(0);
            s.push_str(&format!(
                "worker spread: {min}..{max} requests/worker across {} workers\n",
                self.per_worker_requests.len(),
            ));
        }
        s
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Fleet-level counters of a fault-tolerant sharded session (ISSUE 6).
/// Shard-state counts are the instantaneous census at snapshot time;
/// the rest are cumulative since the fleet started.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetStats {
    /// Shards the fleet was started with.
    pub shards: usize,
    /// Shards currently routable.
    pub live: usize,
    /// Shards draining after a preemption notice.
    pub preempting: usize,
    /// Shards declared dead (missed heartbeats or injected kill).
    pub dead: usize,
    /// Shards that finished a preemption drain (or fleet shutdown).
    pub drained: usize,
    /// Requests accepted by the fleet front door.
    pub submitted: u64,
    /// Fleet tickets resolved with a result.
    pub delivered: u64,
    /// Fleet tickets resolved with an error (execution failures, queue
    /// expiry, or requests unroutable after repeated failover).
    pub failed: u64,
    /// Shards the monitor failed over (dead declarations).
    pub failovers: u64,
    /// Undelivered requests re-admitted onto surviving shards.
    pub requeued: u64,
}

/// Aggregated results of one fleet session: fleet-level counters and
/// end-to-end (submit → delivery, failover included) percentiles, plus
/// each shard's full [`ServeMetrics`] for per-shard drill-down.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// Fleet-level counters (routing, health, failover).
    pub stats: FleetStats,
    /// One entry per shard, in shard order. A dead shard contributes its
    /// last observable snapshot.
    pub per_shard: Vec<ServeMetrics>,
    /// Fleet-level end-to-end latency (front-door submit → ticket
    /// delivery), which spans queue wait, execution, and any failover
    /// re-execution — the number a client actually experiences.
    pub e2e_latency: StreamingPercentiles,
    /// Fleet-level per-model breakdown (ISSUE 7): delivered/failed counts
    /// and e2e percentiles are recorded at the front door (failover
    /// included), steps are summed over the shards. One row per
    /// [`ModelChoice::ALL`] entry, indexable by [`ModelChoice::index`].
    pub per_model: Vec<ModelMetrics>,
    /// Fleet wall time (start → shutdown complete).
    pub wall: Duration,
}

impl FleetMetrics {
    /// Requests completed across all shards (shard-side view; the
    /// fleet-side view is `stats.delivered`).
    pub fn requests_done(&self) -> usize {
        self.per_shard.iter().map(|m| m.requests_done).sum()
    }

    /// Delivered-request throughput over the fleet wall time.
    pub fn requests_per_s(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.stats.delivered as f64 / self.wall.as_secs_f64()
    }

    /// Human-readable summary block (fleet header + per-shard lines).
    pub fn render(&self) -> String {
        let s = &self.stats;
        let mut out = String::new();
        out.push_str(&format!(
            "fleet: {} shards ({} live / {} preempting / {} dead / {} drained)\n",
            s.shards, s.live, s.preempting, s.dead, s.drained,
        ));
        out.push_str(&format!(
            "delivered: {} of {} submitted ({} failed) in {:.2}s  ({:.2} req/s)\n",
            s.delivered,
            s.submitted,
            s.failed,
            self.wall.as_secs_f64(),
            self.requests_per_s(),
        ));
        if s.failovers > 0 || s.requeued > 0 {
            out.push_str(&format!(
                "failover: {} shard(s) failed over, {} request(s) re-admitted\n",
                s.failovers, s.requeued,
            ));
        }
        if self.e2e_latency.count() > 0 {
            out.push_str(&format!(
                "fleet e2e latency: mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}\n",
                self.e2e_latency.mean_us() / 1e3,
                self.e2e_latency.p50_us() / 1e3,
                self.e2e_latency.p95_us() / 1e3,
                self.e2e_latency.p99_us() / 1e3,
            ));
        }
        if self
            .per_model
            .iter()
            .any(|r| r.model != ModelChoice::Unet && r.has_traffic())
        {
            out.push_str("per-model:\n");
            for row in self.per_model.iter().filter(|r| r.has_traffic()) {
                out.push_str(&row.render_line());
            }
        }
        for (i, m) in self.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "shard {i}: {} done, {} failed, {} expired, {} lanes down\n",
                m.requests_done,
                m.requests_failed,
                m.admission.expired,
                m.lanes_down,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_computed() {
        let mut m = ServeMetrics::new();
        m.requests_done = 10;
        m.steps_done = 500;
        m.wall = Duration::from_secs(5);
        assert!((m.requests_per_s() - 2.0).abs() < 1e-9);
        assert!((m.steps_per_s() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn render_contains_key_lines() {
        let mut m = ServeMetrics::new();
        m.requests_done = 1;
        m.wall = Duration::from_millis(100);
        m.request_latency.record_us(1000.0);
        let s = m.render();
        assert!(s.contains("requests: 1"));
        assert!(s.contains("request latency"));
    }

    #[test]
    fn zero_wall_is_safe() {
        let m = ServeMetrics::new();
        assert_eq!(m.requests_per_s(), 0.0);
        assert_eq!(m.batch_occupancy(), 0.0);
    }

    #[test]
    fn occupancy_and_render_batched_lines() {
        let mut m = ServeMetrics::new();
        m.dispatches = 4;
        m.batch_items = 14;
        m.pipeline_stalls = 2;
        m.per_worker_requests = vec![3, 4];
        assert!((m.batch_occupancy() - 3.5).abs() < 1e-12);
        let s = m.render();
        assert!(s.contains("batch occupancy"), "{s}");
        assert!(s.contains("worker spread"), "{s}");
        assert!(!s.contains("buffer pool"), "no pool counters, no pool line");
    }

    #[test]
    fn admission_line_and_streaming_percentiles_render() {
        let mut m = ServeMetrics::new();
        let s = m.render();
        assert!(
            !s.contains("admission:") && !s.contains("e2e latency"),
            "idle session renders neither admission nor e2e lines: {s}"
        );
        m.admission.offered = 12;
        m.admission.admitted = 9;
        m.admission.rejected_queue_full = 2;
        m.admission.rejected_deadline = 1;
        m.admission.expired = 1;
        m.admission.queue_depth = 3;
        assert_eq!(m.admission.rejected_total(), 3);
        for i in 1..=100 {
            m.e2e_latency.record_us(i as f64 * 1000.0);
        }
        let s = m.render();
        assert!(s.contains("admission: 12 offered, 9 admitted, 3 rejected"), "{s}");
        assert!(s.contains("queue depth 3"), "{s}");
        assert!(s.contains("e2e latency"), "{s}");
        m.requests_failed = 2;
        m.lanes_down = 1;
        let s = m.render();
        assert!(s.contains("failed requests: 2"), "{s}");
        assert!(s.contains("worker lanes down: 1"), "{s}");
    }

    #[test]
    fn fleet_metrics_render_and_rates() {
        let mut fm = FleetMetrics {
            stats: FleetStats {
                shards: 3,
                live: 2,
                dead: 1,
                submitted: 24,
                delivered: 24,
                failovers: 1,
                requeued: 5,
                ..Default::default()
            },
            per_shard: vec![ServeMetrics::new(), ServeMetrics::new()],
            e2e_latency: StreamingPercentiles::new(),
            per_model: ModelMetrics::rows(),
            wall: Duration::from_secs(2),
        };
        fm.per_shard[0].requests_done = 14;
        fm.per_shard[1].requests_done = 15;
        fm.e2e_latency.record_us(1000.0);
        assert_eq!(fm.requests_done(), 29, "shard-side view counts retries");
        assert!((fm.requests_per_s() - 12.0).abs() < 1e-9);
        let s = fm.render();
        assert!(s.contains("fleet: 3 shards"), "{s}");
        assert!(s.contains("delivered: 24 of 24"), "{s}");
        assert!(s.contains("1 shard(s) failed over"), "{s}");
        assert!(s.contains("shard 0:"), "{s}");
        assert!(s.contains("fleet e2e latency"), "{s}");
    }

    #[test]
    fn per_model_rows_render_only_under_mixed_traffic() {
        let mut m = ServeMetrics::new();
        assert_eq!(m.per_model.len(), ModelChoice::ALL.len());
        for (i, row) in m.per_model.iter().enumerate() {
            assert_eq!(row.model.index(), i, "rows are index-aligned");
        }
        // pure-diffusion traffic keeps the summary unchanged
        m.per_model[ModelChoice::Unet.index()].requests_done = 4;
        assert!(!m.is_multi_mode());
        assert!(!m.render().contains("per-model:"), "{}", m.render());
        // classification traffic flips the breakdown on
        let r = &mut m.per_model[ModelChoice::Resnet18.index()];
        r.requests_done = 3;
        r.steps_done = 3;
        r.e2e_latency.record_us(2000.0);
        assert!(m.is_multi_mode());
        let s = m.render();
        assert!(s.contains("per-model:"), "{s}");
        assert!(s.contains("unet: 4 done"), "{s}");
        assert!(s.contains("resnet18: 3 done, 3 steps"), "{s}");
        assert!(!s.contains("vgg16"), "zero-traffic rows stay hidden: {s}");
        assert!(!s.contains("WARNING"), "{s}");
        m.cross_model_batches = 1;
        assert!(m.render().contains("WARNING: 1 batch(es) mixed models"));
        // the shape invariant renders its own warning (ISSUE 9),
        // mirroring the cross-model one
        m.cross_shape_batches = 2;
        assert!(m
            .render()
            .contains("WARNING: 2 batch(es) mixed image shapes"));
    }

    #[test]
    fn model_metrics_price_sim_counts_per_mode() {
        use crate::sim::energy::CAL_40NM;
        let mut row = ModelMetrics::new(ModelChoice::Vgg16);
        assert!(row.sim_report(&CAL_40NM, 8).is_none());
        let mut counts = EventCounts {
            total_pes: 256,
            cycles: 10_000,
            ..Default::default()
        };
        counts.pe.macs = 1_000_000;
        row.sim_counts = Some(counts);
        let rep = row.sim_report(&CAL_40NM, 8).expect("counts attached");
        assert!(rep.gops_per_mm2 > 0.0, "per-mode FoM must price");
    }

    #[test]
    fn pool_counters_render_and_rate() {
        let mut m = ServeMetrics::new();
        assert_eq!(m.pool_hit_rate(), 0.0);
        m.pool_hits = 30;
        m.pool_misses = 10;
        m.pool_bytes_leased = 4_000_000;
        assert!((m.pool_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.render();
        assert!(s.contains("buffer pool"), "{s}");
        assert!(s.contains("75.0% hit rate"), "{s}");
    }
}
