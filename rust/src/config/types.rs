//! Typed configuration the launcher consumes, loadable from TOML files
//! (see `configs/*.toml`) with CLI overrides applied on top.

use std::path::Path;

use anyhow::{bail, Result};

use crate::sim::array::AcceleratorConfig;

use super::toml_lite::{parse_toml, DocExt};

/// Which network to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelChoice {
    /// VGG-16 classifier (the paper's Mode 1 reference CNN).
    Vgg16,
    /// ResNet-18 classifier (the paper's residual-mode CNN).
    Resnet18,
    /// The diffusion U-net (denoise requests always run here).
    Unet,
}

impl ModelChoice {
    /// Every serveable model, in the order per-model metrics rows use.
    pub const ALL: [ModelChoice; 3] =
        [ModelChoice::Unet, ModelChoice::Resnet18, ModelChoice::Vgg16];

    /// Parse a model name; hyphenated aliases (`vgg-16`, `u-net`, …)
    /// are accepted.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "vgg16" | "vgg" | "vgg-16" => ModelChoice::Vgg16,
            "resnet18" | "resnet" | "resnet-18" => ModelChoice::Resnet18,
            "unet" | "u-net" => ModelChoice::Unet,
            other => bail!("unknown model `{other}` (vgg16|resnet18|unet)"),
        })
    }

    /// Canonical lowercase name (what configs, metrics rows, and trace
    /// files spell).
    pub fn name(&self) -> &'static str {
        match self {
            ModelChoice::Vgg16 => "vgg16",
            ModelChoice::Resnet18 => "resnet18",
            ModelChoice::Unet => "unet",
        }
    }

    /// Stable position in [`ModelChoice::ALL`] (per-model metrics rows).
    pub fn index(&self) -> usize {
        match self {
            ModelChoice::Unet => 0,
            ModelChoice::Resnet18 => 1,
            ModelChoice::Vgg16 => 2,
        }
    }
}

/// Deterministic traffic mix over the serveable models: a weighted
/// round-robin pattern, so request `i` of a workload maps to
/// `pattern[i % len]` — a pure function of the index, which is what
/// keeps mixed-traffic failover re-execution bit-identical (the fleet
/// regenerates exactly the same request from the same index).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelMix {
    pattern: Vec<ModelChoice>,
}

impl ModelMix {
    /// The historical single-mode workload: every request is a U-net
    /// denoise.
    pub fn all_unet() -> Self {
        Self {
            pattern: vec![ModelChoice::Unet],
        }
    }

    /// Parse `"unet:2,resnet18:1,vgg16:1"` — comma-separated
    /// `model[:weight]` entries (weight defaults to 1, capped at 64).
    /// The weights expand into a repeating pattern in entry order
    /// (`unet,unet,resnet18,vgg16` for the example). Empty input is the
    /// all-U-net mix.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(Self::all_unet());
        }
        let mut pattern = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            let (name, weight) = match entry.split_once(':') {
                Some((n, w)) => {
                    let w: u64 = w.trim().parse().map_err(|_| {
                        anyhow::anyhow!("model mix entry `{entry}`: bad weight `{w}`")
                    })?;
                    (n.trim(), w)
                }
                None => (entry, 1),
            };
            if !(1..=64).contains(&weight) {
                bail!("model mix entry `{entry}`: weight must be in 1..=64");
            }
            let model = ModelChoice::parse(name)?;
            pattern.extend((0..weight).map(|_| model));
        }
        Ok(Self { pattern })
    }

    /// The model request `index` of a workload carries.
    pub fn model_for(&self, index: u64) -> ModelChoice {
        self.pattern[(index % self.pattern.len() as u64) as usize]
    }

    /// True when the mix is the single-mode all-U-net workload.
    pub fn is_all_unet(&self) -> bool {
        self.pattern.iter().all(|m| *m == ModelChoice::Unet)
    }

    /// Distinct models present, in [`ModelChoice::ALL`] order.
    pub fn models(&self) -> Vec<ModelChoice> {
        ModelChoice::ALL
            .into_iter()
            .filter(|m| self.pattern.contains(m))
            .collect()
    }
}

/// `sf-mmcn run` configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Network to simulate.
    pub model: ModelChoice,
    /// Input image side length (pixels).
    pub img: usize,
    /// Simulated accelerator geometry and feature toggles.
    pub accel: AcceleratorConfig,
    /// Post-ReLU activation sparsity assumed by the analytic model.
    pub sparsity: f64,
    /// Seed for synthetic inputs.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: ModelChoice::Vgg16,
            img: 224,
            accel: AcceleratorConfig::default(),
            sparsity: 0.0,
            seed: 42,
        }
    }
}

/// Which runtime the serving workers execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeBackend {
    /// AOT HLO artifacts through PJRT (requires `make artifacts` and a
    /// `--features pjrt` build). The default.
    Pjrt,
    /// The built-in host-CPU denoise surrogate with synthetic parameters
    /// (`runtime::NativeDenoise`) — no artifacts needed; what tier-1 and
    /// the serve benchmarks run on.
    Native,
}

impl ServeBackend {
    /// Parse a backend name (`pjrt`, `native`; `stub` is an alias).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "pjrt" => ServeBackend::Pjrt,
            "native" | "stub" => ServeBackend::Native,
            other => bail!("unknown serve backend `{other}` (pjrt|native)"),
        })
    }

    /// Canonical backend name.
    pub fn name(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Native => "native",
        }
    }
}

/// `sf-mmcn serve` (diffusion) configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// DDPM reverse steps per request.
    pub steps: usize,
    /// Number of requests the workload generator submits.
    pub requests: usize,
    /// Worker threads pulling from the request queue.
    pub workers: usize,
    /// Max requests the batcher hands a worker per grab. With `batched`
    /// they stack into one `[B, ...]` device dispatch; without it they
    /// amortize queueing only (each image still runs solo — §III.D).
    pub max_batch: usize,
    /// Workload seed: every request's content derives from
    /// `(seed, index)`, which is what makes replay and failover
    /// re-execution bit-identical.
    pub seed: u64,
    /// Artifact name for the denoise step.
    pub artifact: String,
    /// Co-simulate the accelerator (cycles/energy) alongside execution.
    /// Batched traffic co-sims through the cycle-accurate micro simulator;
    /// the per-request path keeps the analytic model.
    pub cosim: bool,
    /// Use the fused T-step scan artifact (`unet_denoise_scan<T>_16`)
    /// instead of step-at-a-time execution (§Perf, L2).
    pub fused: bool,
    /// Runtime backend (see [`ServeBackend`]).
    pub backend: ServeBackend,
    /// Cross-request batched dispatch: stack up to `max_batch` requests
    /// into one `[B, ...]` execution per timestep chunk (ISSUE 3).
    pub batched: bool,
    /// Double-buffer the host stage: generate the next batch's noise and
    /// time embeddings on a separate thread while the device executes the
    /// current one. Only affects `batched` mode.
    pub pipeline: bool,
    /// Timesteps per batched dispatch (0 = the whole request in one).
    /// On the PJRT backend the chunk must equal the scan artifact's baked
    /// step count, so only 0 (or `steps`) is valid there.
    pub chunk: usize,
    /// Lease batch tensors from a per-worker buffer pool and execute in
    /// place (ISSUE 4): the batched lane reaches zero steady-state
    /// allocation. `false` restores the per-batch-allocating behaviour —
    /// the "unpooled" baseline the serve bench compares against. Only
    /// affects `batched` mode.
    pub pooled: bool,
    /// Bounded admission queue depth of the streaming session API
    /// (ISSUE 5): `try_submit` returns `QueueFull` once this many
    /// requests are waiting, `submit` blocks. The legacy `serve()` drain
    /// widens the bound to its whole workload, so it never rejects.
    pub queue_depth: usize,
    /// Default per-request deadline in milliseconds, applied at admission
    /// to requests that carry none of their own. 0 disables the default
    /// (requests without an explicit deadline never expire).
    pub default_deadline_ms: u64,
    /// Number of admission priority levels. Priority 0 is the most
    /// urgent; request priorities clamp to `priorities - 1`.
    pub priorities: usize,
    /// Shards in the fault-tolerant fleet (ISSUE 6): each shard is a
    /// full serving session (its own lanes and admission queue) behind
    /// the `ShardFleet` front door. 1 = no fleet (a single session).
    pub shards: usize,
    /// Heartbeat period in milliseconds: lanes beat their shard's pulse
    /// at least once per period while idle; the fleet monitor samples at
    /// the same period.
    pub heartbeat_ms: u64,
    /// Consecutive missed heartbeat samples before the monitor declares
    /// a shard dead and fails its undelivered work over. Executing lanes
    /// beat per step (per-request path) or per dispatched chunk (batched
    /// path), so the tolerance `heartbeat_ms * heartbeat_misses` must
    /// exceed the longest single device dispatch — raise it for big
    /// batched chunks or PJRT scan artifacts.
    pub heartbeat_misses: u64,
    /// Fault-injection schedule (see `coordinator::faults`), e.g.
    /// `"kill:1:5;stall:0:3:40"`. Empty = no injected faults.
    pub fault_spec: String,
    /// Traffic mix for the workload generator (ISSUE 7), e.g.
    /// `"unet:2,resnet18:1,vgg16:1"` — see [`ModelMix::parse`]. Empty =
    /// the historical all-U-net workload.
    pub model_mix: String,
    /// Arrival-rate profile for open-loop serving (ISSUE 8), e.g.
    /// `"ou:60:2:15"` or `"burst:40:200:1000:100"` — see
    /// `coordinator::traffic::TrafficProfile` for the grammar. Empty =
    /// no profile (closed-loop, or the legacy fixed `--rate` schedule).
    pub traffic: String,
    /// Fused resident-x scan (ISSUE 9): execute a batch's *entire*
    /// reverse trajectory in one native dispatch, keeping every image hot
    /// in a single slab (no per-chunk noise re-gather or slab ping-pong)
    /// while still beating the shard pulse once per step. Bit-identical
    /// to the chunked loop; counts as a single dispatch in metrics, so
    /// leave it off when comparing chunking strategies. Batched native
    /// lanes only — compiled PJRT artifacts fall back to the chunk loop.
    pub resident: bool,
    /// Pin each worker lane (and, by mask inheritance, its fanout
    /// threads) to one NUMA node, round-robin across nodes
    /// (`util::affinity::CoreMap`). Best-effort: unsupported hosts and
    /// denied syscalls leave lanes unpinned. Never changes served bits.
    pub pin_lanes: bool,
    /// Worker *processes* in the cluster fleet (ISSUE 10): each worker is
    /// a separate OS process running one serving session behind a Unix
    /// socket, supervised and routed to by the `ClusterFleet` front door.
    /// 0 = no cluster (in-process serving; the default). Mutually
    /// exclusive with `shards > 1` — one front door at a time.
    pub cluster: usize,
    /// Fleet monitor pump period in microseconds: how often the
    /// `ShardFleet` / `ClusterFleet` monitor polls tickets, samples
    /// heartbeats, and re-admits requeued work. The compiled-in default
    /// is 500; the `SF_MMCN_MONITOR_PUMP_US` environment variable
    /// overrides the default (CI stress loops lengthen it to cut
    /// busy-poll wall-clock without touching every test's config).
    pub monitor_pump_us: u64,
    /// Spot-interruption sentinel: when non-empty, the fleet monitor
    /// polls this path and, on the file appearing, reads a shard/worker
    /// index from its contents (empty file = shard 0) and drives
    /// `begin_preempt` on it — the cloud "instance reclaim notice"
    /// signal source. Empty = no polling.
    pub preempt_file: String,
}

/// Compiled-in monitor pump period (µs), before the environment
/// override in [`default_monitor_pump_us`].
pub const MONITOR_PUMP_US_DEFAULT: u64 = 500;

/// The `serve.monitor_pump_us` default: `SF_MMCN_MONITOR_PUMP_US` when
/// set to a positive integer, else [`MONITOR_PUMP_US_DEFAULT`].
pub fn default_monitor_pump_us() -> u64 {
    std::env::var("SF_MMCN_MONITOR_PUMP_US")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(MONITOR_PUMP_US_DEFAULT)
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            steps: 50,
            requests: 8,
            workers: 2,
            max_batch: 4,
            seed: 7,
            artifact: "unet_denoise_16".into(),
            cosim: true,
            fused: false,
            backend: ServeBackend::Pjrt,
            batched: false,
            pipeline: true,
            chunk: 0,
            pooled: true,
            queue_depth: 64,
            default_deadline_ms: 0,
            priorities: 3,
            shards: 1,
            heartbeat_ms: 25,
            heartbeat_misses: 8,
            fault_spec: String::new(),
            model_mix: String::new(),
            traffic: String::new(),
            resident: false,
            pin_lanes: false,
            cluster: 0,
            monitor_pump_us: default_monitor_pump_us(),
            preempt_file: String::new(),
        }
    }
}

/// `sf-mmcn sweep` (design space) configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Server-flow unit counts to sweep over.
    pub unit_counts: Vec<usize>,
    /// Network to price at each design point.
    pub model: ModelChoice,
    /// Input image side length (pixels).
    pub img: usize,
    /// Post-ReLU activation sparsity assumed by the analytic model.
    pub sparsity: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            unit_counts: vec![2, 4, 8, 16],
            model: ModelChoice::Resnet18,
            img: 224,
            sparsity: 0.0,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text; missing keys keep defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        cfg.model = ModelChoice::parse(&doc.get_str_or("run", "model", cfg.model.name()))?;
        cfg.img = doc.get_int_or("run", "img", cfg.img as i64) as usize;
        cfg.sparsity = doc.get_float_or("run", "sparsity", cfg.sparsity);
        cfg.seed = doc.get_int_or("run", "seed", cfg.seed as i64) as u64;
        cfg.accel.units =
            doc.get_int_or("accelerator", "units", cfg.accel.units as i64) as usize;
        cfg.accel.input_buf_elems = doc.get_int_or(
            "accelerator",
            "input_buf_elems",
            cfg.accel.input_buf_elems as i64,
        ) as u64;
        cfg.accel.weight_buf_elems = doc.get_int_or(
            "accelerator",
            "weight_buf_elems",
            cfg.accel.weight_buf_elems as i64,
        ) as u64;
        cfg.accel.zero_gate = doc.get_bool_or("accelerator", "zero_gate", cfg.accel.zero_gate);
        cfg.accel.data_reuse =
            doc.get_bool_or("accelerator", "data_reuse", cfg.accel.data_reuse);
        if cfg.accel.units == 0 {
            bail!("accelerator.units must be >= 1");
        }
        if !(0.0..=1.0).contains(&cfg.sparsity) {
            bail!("run.sparsity must be in [0,1]");
        }
        Ok(cfg)
    }
}

impl ServeConfig {
    /// Load from a TOML file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text; missing keys keep defaults, and the result
    /// is [`ServeConfig::validate`]d.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        cfg.steps = doc.get_int_or("serve", "steps", cfg.steps as i64) as usize;
        cfg.requests = doc.get_int_or("serve", "requests", cfg.requests as i64) as usize;
        cfg.workers = doc.get_int_or("serve", "workers", cfg.workers as i64) as usize;
        cfg.max_batch = doc.get_int_or("serve", "max_batch", cfg.max_batch as i64) as usize;
        cfg.seed = doc.get_int_or("serve", "seed", cfg.seed as i64) as u64;
        cfg.artifact = doc.get_str_or("serve", "artifact", &cfg.artifact);
        cfg.cosim = doc.get_bool_or("serve", "cosim", cfg.cosim);
        cfg.fused = doc.get_bool_or("serve", "fused", cfg.fused);
        cfg.backend =
            ServeBackend::parse(&doc.get_str_or("serve", "backend", cfg.backend.name()))?;
        cfg.batched = doc.get_bool_or("serve", "batched", cfg.batched);
        cfg.pipeline = doc.get_bool_or("serve", "pipeline", cfg.pipeline);
        cfg.pooled = doc.get_bool_or("serve", "pooled", cfg.pooled);
        let chunk = doc.get_int_or("serve", "chunk", cfg.chunk as i64);
        if chunk < 0 {
            bail!("serve.chunk must be >= 0 (0 = whole request per dispatch)");
        }
        cfg.chunk = chunk as usize;
        cfg.queue_depth =
            doc.get_u64_or("serve", "queue_depth", cfg.queue_depth as u64)? as usize;
        cfg.default_deadline_ms =
            doc.get_u64_or("serve", "default_deadline_ms", cfg.default_deadline_ms)?;
        cfg.priorities =
            doc.get_u64_or("serve", "priorities", cfg.priorities as u64)? as usize;
        cfg.shards = doc.get_u64_or("serve", "shards", cfg.shards as u64)? as usize;
        cfg.heartbeat_ms = doc.get_u64_or("serve", "heartbeat_ms", cfg.heartbeat_ms)?;
        cfg.heartbeat_misses =
            doc.get_u64_or("serve", "heartbeat_misses", cfg.heartbeat_misses)?;
        cfg.fault_spec = doc.get_str_or("serve", "fault_spec", &cfg.fault_spec);
        cfg.model_mix = doc.get_str_or("serve", "model_mix", &cfg.model_mix);
        cfg.traffic = doc.get_str_or("serve", "traffic", &cfg.traffic);
        cfg.resident = doc.get_bool_or("serve", "resident", cfg.resident);
        cfg.pin_lanes = doc.get_bool_or("serve", "pin_lanes", cfg.pin_lanes);
        cfg.cluster = doc.get_u64_or("serve", "cluster", cfg.cluster as u64)? as usize;
        cfg.monitor_pump_us =
            doc.get_u64_or("serve", "monitor_pump_us", cfg.monitor_pump_us)?;
        cfg.preempt_file = doc.get_str_or("serve", "preempt_file", &cfg.preempt_file);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Render the config as TOML text that [`ServeConfig::from_toml`]
    /// parses back to an equal config — how the cluster supervisor ships
    /// the full serving configuration to its worker processes.
    pub fn to_toml(&self) -> String {
        fn quote(s: &str) -> String {
            format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
        }
        format!(
            "[serve]\n\
             steps = {}\nrequests = {}\nworkers = {}\nmax_batch = {}\n\
             seed = {}\nartifact = {}\ncosim = {}\nfused = {}\n\
             backend = {}\nbatched = {}\npipeline = {}\nchunk = {}\n\
             pooled = {}\nqueue_depth = {}\ndefault_deadline_ms = {}\n\
             priorities = {}\nshards = {}\nheartbeat_ms = {}\n\
             heartbeat_misses = {}\nfault_spec = {}\nmodel_mix = {}\n\
             traffic = {}\nresident = {}\npin_lanes = {}\ncluster = {}\n\
             monitor_pump_us = {}\npreempt_file = {}\n",
            self.steps,
            self.requests,
            self.workers,
            self.max_batch,
            self.seed,
            quote(&self.artifact),
            self.cosim,
            self.fused,
            quote(self.backend.name()),
            self.batched,
            self.pipeline,
            self.chunk,
            self.pooled,
            self.queue_depth,
            self.default_deadline_ms,
            self.priorities,
            self.shards,
            self.heartbeat_ms,
            self.heartbeat_misses,
            quote(&self.fault_spec),
            quote(&self.model_mix),
            quote(&self.traffic),
            self.resident,
            self.pin_lanes,
            self.cluster,
            self.monitor_pump_us,
            quote(&self.preempt_file),
        )
    }

    /// The parsed traffic profile, `None` when `serve.traffic` is empty
    /// (validated by [`ServeConfig::validate`]).
    pub fn parsed_traffic(&self) -> Result<Option<crate::coordinator::traffic::TrafficProfile>> {
        if self.traffic.trim().is_empty() {
            return Ok(None);
        }
        crate::coordinator::traffic::TrafficProfile::parse(&self.traffic).map(Some)
    }

    /// The parsed traffic mix (validated by [`ServeConfig::validate`]).
    pub fn parsed_model_mix(&self) -> Result<ModelMix> {
        ModelMix::parse(&self.model_mix)
    }

    /// Reject degenerate configurations with a clear error instead of
    /// letting a construction-time clamp hide them (a zero-worker or
    /// zero-depth session would otherwise hang or silently reshape
    /// itself). Called by `from_toml`, `DiffusionServer::new`, and
    /// `ShardFleet::start`, so every entry point fails fast.
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("serve.steps must be >= 1 (a request must run at least one step)");
        }
        if self.workers == 0 {
            bail!("serve.workers must be >= 1 (zero lanes could never drain the queue)");
        }
        if self.max_batch == 0 {
            bail!("serve.max_batch must be >= 1 (a grab must take at least one request)");
        }
        if self.queue_depth == 0 {
            bail!("serve.queue_depth must be >= 1 (bounded admission needs room for one)");
        }
        if !(1..=16).contains(&self.priorities) {
            bail!("serve.priorities must be in 1..=16, got {}", self.priorities);
        }
        if self.shards == 0 {
            bail!("serve.shards must be >= 1 (a fleet needs at least one shard)");
        }
        if self.heartbeat_ms == 0 {
            bail!("serve.heartbeat_ms must be >= 1");
        }
        if self.heartbeat_misses == 0 {
            bail!("serve.heartbeat_misses must be >= 1 (zero tolerance would declare every shard dead)");
        }
        if self.monitor_pump_us == 0 {
            bail!("serve.monitor_pump_us must be >= 1 (a zero-period monitor pump would spin)");
        }
        if self.cluster > 64 {
            bail!(
                "serve.cluster must be <= 64 worker processes, got {}",
                self.cluster
            );
        }
        if self.cluster > 0 && self.shards > 1 {
            bail!(
                "serve.cluster and serve.shards > 1 are mutually exclusive \
                 (one front door at a time; each cluster worker is a single-session process)"
            );
        }
        // String fields travel to cluster workers via `to_toml` /
        // `from_toml`, and that TOML subset is line-based: a newline or
        // other control character cannot be represented, so every
        // worker process would fail to start (or misparse its config).
        // Reject them here instead of shipping a malformed worker.toml.
        for (key, val) in [
            ("artifact", &self.artifact),
            ("fault_spec", &self.fault_spec),
            ("model_mix", &self.model_mix),
            ("traffic", &self.traffic),
            ("preempt_file", &self.preempt_file),
        ] {
            if val.chars().any(char::is_control) {
                bail!(
                    "serve.{key} must not contain control characters \
                     (newlines cannot survive the worker config file)"
                );
            }
        }
        ModelMix::parse(&self.model_mix)
            .map_err(|e| anyhow::anyhow!("serve.model_mix: {e}"))?;
        if !self.traffic.trim().is_empty() {
            crate::coordinator::traffic::TrafficProfile::parse(&self.traffic)
                .map_err(|e| anyhow::anyhow!("serve.traffic: {e}"))?;
        }
        Ok(())
    }
}

impl SweepConfig {
    /// Parse from TOML text; missing keys keep defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        if let Some(v) = doc.get_val("sweep", "unit_counts") {
            let arr = v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("sweep.unit_counts must be an array"))?;
            cfg.unit_counts = arr
                .iter()
                .map(|x| x.as_int().map(|i| i as usize))
                .collect::<Option<_>>()
                .ok_or_else(|| anyhow::anyhow!("sweep.unit_counts must be integers"))?;
        }
        cfg.model = ModelChoice::parse(&doc.get_str_or("sweep", "model", cfg.model.name()))?;
        cfg.img = doc.get_int_or("sweep", "img", cfg.img as i64) as usize;
        cfg.sparsity = doc.get_float_or("sweep", "sparsity", cfg.sparsity);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_config_roundtrip() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
model = "resnet18"
img = 32
sparsity = 0.4

[accelerator]
units = 4
data_reuse = false
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, ModelChoice::Resnet18);
        assert_eq!(cfg.img, 32);
        assert_eq!(cfg.accel.units, 4);
        assert!(!cfg.accel.data_reuse);
        assert!((cfg.sparsity - 0.4).abs() < 1e-12);
    }

    #[test]
    fn defaults_preserved_for_missing_keys() {
        let cfg = RunConfig::from_toml("[run]\nmodel = \"unet\"\n").unwrap();
        assert_eq!(cfg.model, ModelChoice::Unet);
        assert_eq!(cfg.accel.units, 8);
    }

    #[test]
    fn bad_model_rejected() {
        assert!(RunConfig::from_toml("[run]\nmodel = \"alexnet\"\n").is_err());
    }

    #[test]
    fn bad_sparsity_rejected() {
        assert!(RunConfig::from_toml("[run]\nsparsity = 1.5\n").is_err());
    }

    #[test]
    fn serve_config_validation() {
        assert!(ServeConfig::from_toml("[serve]\nsteps = 0\n").is_err());
        let cfg = ServeConfig::from_toml("[serve]\nsteps = 10\nworkers = 3\n").unwrap();
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.backend, ServeBackend::Pjrt, "pjrt stays the default");
        assert!(!cfg.batched);
        assert!(cfg.pipeline);
    }

    #[test]
    fn serve_config_batching_keys() {
        let cfg = ServeConfig::from_toml(
            "[serve]\nbackend = \"native\"\nbatched = true\npipeline = false\nchunk = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.backend, ServeBackend::Native);
        assert!(cfg.batched);
        assert!(!cfg.pipeline);
        assert_eq!(cfg.chunk, 8);
        assert!(cfg.pooled, "pooled serving is the default");
        let unpooled =
            ServeConfig::from_toml("[serve]\npooled = false\n").unwrap();
        assert!(!unpooled.pooled);
        assert!(ServeConfig::from_toml("[serve]\nbackend = \"tpu\"\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nchunk = -1\n").is_err());
    }

    #[test]
    fn serve_config_admission_keys() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert_eq!(cfg.queue_depth, 64, "bounded admission default");
        assert_eq!(cfg.default_deadline_ms, 0, "no default deadline");
        assert_eq!(cfg.priorities, 3);
        let cfg = ServeConfig::from_toml(
            "[serve]\nqueue_depth = 8\ndefault_deadline_ms = 250\npriorities = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.queue_depth, 8);
        assert_eq!(cfg.default_deadline_ms, 250);
        assert_eq!(cfg.priorities, 2);
        assert!(ServeConfig::from_toml("[serve]\nqueue_depth = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\npriorities = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\npriorities = 99\n").is_err());
    }

    #[test]
    fn serve_config_fleet_keys() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert_eq!(cfg.shards, 1, "single session by default");
        assert_eq!(cfg.heartbeat_ms, 25);
        assert_eq!(cfg.heartbeat_misses, 8);
        assert!(cfg.fault_spec.is_empty(), "no injected faults by default");
        let cfg = ServeConfig::from_toml(
            "[serve]\nshards = 3\nheartbeat_ms = 10\nheartbeat_misses = 2\n\
             fault_spec = \"kill:1:5\"\n",
        )
        .unwrap();
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.heartbeat_ms, 10);
        assert_eq!(cfg.heartbeat_misses, 2);
        assert_eq!(cfg.fault_spec, "kill:1:5");
    }

    #[test]
    fn serve_config_rejects_degenerate_fleet_values() {
        assert!(ServeConfig::from_toml("[serve]\nshards = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nheartbeat_ms = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nheartbeat_misses = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nworkers = 0\n").is_err());
    }

    #[test]
    fn validate_rejects_each_degenerate_field_with_a_clear_message() {
        let base = ServeConfig::default();
        base.validate().expect("default config is valid");
        let cases: Vec<(ServeConfig, &str)> = vec![
            (ServeConfig { workers: 0, ..base.clone() }, "workers"),
            (ServeConfig { queue_depth: 0, ..base.clone() }, "queue_depth"),
            (ServeConfig { priorities: 0, ..base.clone() }, "priorities"),
            (ServeConfig { priorities: 17, ..base.clone() }, "priorities"),
            (ServeConfig { shards: 0, ..base.clone() }, "shards"),
            (ServeConfig { steps: 0, ..base.clone() }, "steps"),
            (ServeConfig { max_batch: 0, ..base.clone() }, "max_batch"),
            (ServeConfig { heartbeat_ms: 0, ..base.clone() }, "heartbeat_ms"),
            (ServeConfig { heartbeat_misses: 0, ..base }, "heartbeat_misses"),
        ];
        for (cfg, key) in cases {
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(key), "error for {key} names the field: {err}");
        }
    }

    #[test]
    fn serve_config_cluster_keys() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert_eq!(cfg.cluster, 0, "in-process serving by default");
        assert!(cfg.preempt_file.is_empty(), "no sentinel polling by default");
        if std::env::var("SF_MMCN_MONITOR_PUMP_US").is_err() {
            assert_eq!(cfg.monitor_pump_us, MONITOR_PUMP_US_DEFAULT);
        }
        let cfg = ServeConfig::from_toml(
            "[serve]\ncluster = 4\nmonitor_pump_us = 2000\n\
             preempt_file = \"/tmp/reclaim\"\n",
        )
        .unwrap();
        assert_eq!(cfg.cluster, 4);
        assert_eq!(cfg.monitor_pump_us, 2000);
        assert_eq!(cfg.preempt_file, "/tmp/reclaim");
        assert!(ServeConfig::from_toml("[serve]\nmonitor_pump_us = 0\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\ncluster = 65\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\ncluster = -1\n").is_err());
        // one front door at a time: a cluster of single-session workers
        let err = ServeConfig::from_toml("[serve]\ncluster = 2\nshards = 3\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn serve_config_toml_roundtrip() {
        // to_toml must reproduce every field through from_toml — the
        // supervisor ships worker configs this way, so a field that
        // falls out of the renderer silently reverts to default in
        // every worker process.
        let cfg = ServeConfig {
            steps: 6,
            requests: 24,
            workers: 3,
            max_batch: 2,
            seed: 12345,
            artifact: "unet_denoise_16".into(),
            cosim: false,
            fused: true,
            backend: ServeBackend::Native,
            batched: true,
            pipeline: false,
            chunk: 3,
            pooled: false,
            queue_depth: 17,
            default_deadline_ms: 250,
            priorities: 2,
            shards: 1,
            heartbeat_ms: 10,
            heartbeat_misses: 4,
            fault_spec: "kill:1:5;stall:0:3:40".into(),
            model_mix: "unet:2,resnet18:1,vgg16:1".into(),
            traffic: "ou:60:2:15".into(),
            resident: true,
            pin_lanes: true,
            cluster: 0,
            monitor_pump_us: 900,
            preempt_file: "/tmp/pre\"empt\\x".into(),
        };
        let back = ServeConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
    }

    #[test]
    fn serve_config_rejects_control_chars_in_strings() {
        // A newline in any shipped string field would break the
        // line-based worker.toml the cluster supervisor writes; validate
        // must reject it up front, naming the field.
        for (key, cfg) in [
            (
                "preempt_file",
                ServeConfig {
                    preempt_file: "/tmp/x\ny".into(),
                    ..ServeConfig::default()
                },
            ),
            (
                "fault_spec",
                ServeConfig {
                    fault_spec: "kill:1:5\r".into(),
                    ..ServeConfig::default()
                },
            ),
            (
                "artifact",
                ServeConfig {
                    artifact: "unet\tdenoise".into(),
                    ..ServeConfig::default()
                },
            ),
        ] {
            let err = cfg
                .validate()
                .expect_err(&format!("control char in {key} must be rejected"))
                .to_string();
            assert!(err.contains(key), "error names `{key}`: {err}");
        }
    }

    #[test]
    fn sweep_config_array() {
        let cfg = SweepConfig::from_toml("[sweep]\nunit_counts = [2, 8]\n").unwrap();
        assert_eq!(cfg.unit_counts, vec![2, 8]);
    }

    #[test]
    fn model_choice_aliases() {
        assert_eq!(ModelChoice::parse("VGG-16").unwrap(), ModelChoice::Vgg16);
        assert_eq!(ModelChoice::parse("u-net").unwrap(), ModelChoice::Unet);
    }

    #[test]
    fn model_mix_parses_weighted_pattern() {
        let mix = ModelMix::parse("unet:2,resnet18:1,vgg16:1").unwrap();
        // weighted round-robin in entry order: U U R V U U R V ...
        let want = [
            ModelChoice::Unet,
            ModelChoice::Unet,
            ModelChoice::Resnet18,
            ModelChoice::Vgg16,
        ];
        for i in 0..12u64 {
            assert_eq!(mix.model_for(i), want[(i % 4) as usize], "index {i}");
        }
        assert!(!mix.is_all_unet());
        assert_eq!(
            mix.models(),
            vec![ModelChoice::Unet, ModelChoice::Resnet18, ModelChoice::Vgg16]
        );
    }

    #[test]
    fn model_mix_defaults_and_rejects() {
        let mix = ModelMix::parse("").unwrap();
        assert!(mix.is_all_unet());
        assert_eq!(mix.model_for(7), ModelChoice::Unet);
        // weight defaults to 1 per entry
        let mix = ModelMix::parse("resnet18,vgg16").unwrap();
        assert_eq!(mix.model_for(0), ModelChoice::Resnet18);
        assert_eq!(mix.model_for(1), ModelChoice::Vgg16);
        assert!(mix.models() == vec![ModelChoice::Resnet18, ModelChoice::Vgg16]);
        assert!(ModelMix::parse("alexnet:1").is_err());
        assert!(ModelMix::parse("unet:0").is_err());
        assert!(ModelMix::parse("unet:65").is_err());
        assert!(ModelMix::parse("unet:x").is_err());
    }

    #[test]
    fn serve_config_perf_keys() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert!(!cfg.resident, "chunked dispatch loop stays the default");
        assert!(!cfg.pin_lanes, "lanes unpinned by default");
        let cfg = ServeConfig::from_toml("[serve]\nresident = true\npin_lanes = true\n").unwrap();
        assert!(cfg.resident);
        assert!(cfg.pin_lanes);
    }

    #[test]
    fn serve_config_model_mix_key() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert!(cfg.model_mix.is_empty(), "all-unet workload by default");
        assert!(cfg.parsed_model_mix().unwrap().is_all_unet());
        let cfg = ServeConfig::from_toml(
            "[serve]\nmodel_mix = \"unet:2,resnet18:1,vgg16:1\"\n",
        )
        .unwrap();
        assert_eq!(cfg.model_mix, "unet:2,resnet18:1,vgg16:1");
        let mix = cfg.parsed_model_mix().unwrap();
        assert_eq!(mix.models().len(), 3);
        let err = ServeConfig::from_toml("[serve]\nmodel_mix = \"alexnet\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("model_mix"), "{err}");
    }

    #[test]
    fn serve_config_traffic_key() {
        let cfg = ServeConfig::from_toml("[serve]\n").unwrap();
        assert!(cfg.traffic.is_empty(), "no traffic profile by default");
        assert!(cfg.parsed_traffic().unwrap().is_none());

        let cfg =
            ServeConfig::from_toml("[serve]\ntraffic = \"ou:60:2:15\"\n").unwrap();
        assert_eq!(cfg.traffic, "ou:60:2:15");
        let profile = cfg.parsed_traffic().unwrap().expect("profile set");
        assert_eq!(profile.render(), "ou:60:2:15");

        // errors name both the config key and the bad grammar key
        let err = ServeConfig::from_toml("[serve]\ntraffic = \"ou:60:x:15\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("serve.traffic"), "{err}");
        assert!(err.contains("bad theta"), "{err}");
        let err = ServeConfig::from_toml("[serve]\ntraffic = \"warp:9\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("serve.traffic") && err.contains("unknown profile"), "{err}");
    }
}
