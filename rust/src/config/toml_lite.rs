//! TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / flat array values, `#` comments, blank lines.
//! Unsupported (rejected with line numbers): nested tables, multi-line
//! strings, dates, inline tables.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat `[a, b, c]` array.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, `None` for other variants.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, `None` for other variants.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The numeric payload as `f64` (integers widen), `None` otherwise.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean payload, `None` for other variants.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array payload, `None` for other variants.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// `section -> key -> value`; keys before any `[section]` land in `""`.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                bail!(
                    "line {}: unsupported section name `{name}` (no nesting)",
                    lineno + 1
                );
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(val.trim())
            .with_context(|| format!("line {}: bad value for `{key}`", lineno + 1))?;
        doc.get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting string quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .context("unterminated string")?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse `{s}`")
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Convenience getters over a parsed document.
pub trait DocExt {
    fn get_val(&self, section: &str, key: &str) -> Option<&Value>;
    fn get_str_or(&self, section: &str, key: &str, default: &str) -> String;
    fn get_int_or(&self, section: &str, key: &str, default: i64) -> i64;
    fn get_float_or(&self, section: &str, key: &str, default: f64) -> f64;
    fn get_bool_or(&self, section: &str, key: &str, default: bool) -> bool;
    /// Unsigned integer getter for keys where a negative value has no
    /// meaning (queue depths, millisecond budgets): a negative value is a
    /// per-key configuration error naming `section.key`, never a clamp
    /// or a silent `as u64` wrap to a huge number.
    fn get_u64_or(&self, section: &str, key: &str, default: u64) -> Result<u64>;
}

impl DocExt for Doc {
    fn get_val(&self, section: &str, key: &str) -> Option<&Value> {
        self.get(section).and_then(|s| s.get(key))
    }

    fn get_str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get_val(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    fn get_int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get_val(section, key)
            .and_then(|v| v.as_int())
            .unwrap_or(default)
    }

    fn get_float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get_val(section, key)
            .and_then(|v| v.as_float())
            .unwrap_or(default)
    }

    fn get_bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get_val(section, key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }

    fn get_u64_or(&self, section: &str, key: &str, default: u64) -> Result<u64> {
        match self.get_val(section, key).and_then(|v| v.as_int()) {
            Some(i) if i < 0 => bail!(
                "`{section}.{key}` must be a non-negative integer, got {i}"
            ),
            Some(i) => Ok(i as u64),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
# top comment
title = "sf-mmcn"

[accelerator]
units = 8
freq_mhz = 400.0
zero_gate = true
sizes = [2, 4, 8, 16]

[serve]
steps = 200  # ddpm steps
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["title"], Value::Str("sf-mmcn".into()));
        assert_eq!(doc["accelerator"]["units"], Value::Int(8));
        assert_eq!(doc["accelerator"]["freq_mhz"], Value::Float(400.0));
        assert_eq!(doc["accelerator"]["zero_gate"], Value::Bool(true));
        assert_eq!(
            doc["accelerator"]["sizes"],
            Value::Array(vec![
                Value::Int(2),
                Value::Int(4),
                Value::Int(8),
                Value::Int(16)
            ])
        );
        assert_eq!(doc["serve"]["steps"], Value::Int(200));
    }

    #[test]
    fn string_with_hash_not_truncated() {
        let doc = parse_toml(r##"k = "a # b""##).unwrap();
        assert_eq!(doc[""]["k"], Value::Str("a # b".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("ok = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_toml("x = ").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(parse_toml("[a.b]\nx = 1").is_err());
    }

    #[test]
    fn underscored_ints() {
        let doc = parse_toml("n = 1_000_000").unwrap();
        assert_eq!(doc[""]["n"], Value::Int(1_000_000));
    }

    #[test]
    fn doc_ext_defaults() {
        let doc = parse_toml("[s]\nx = 3").unwrap();
        assert_eq!(doc.get_int_or("s", "x", 0), 3);
        assert_eq!(doc.get_int_or("s", "missing", 7), 7);
        assert_eq!(doc.get_str_or("nosect", "k", "d"), "d");
        assert!(doc.get_bool_or("s", "b", true));
        assert_eq!(doc.get_float_or("s", "x", 0.0), 3.0);
    }

    #[test]
    fn u64_getter_rejects_negatives_with_per_key_error() {
        // Regression (ISSUE 7): negatives used to clamp to 0 (and before
        // that, an unchecked `as u64` would have wrapped `-1` to 2^64-1).
        // They are configuration errors and must say which key is wrong.
        let doc = parse_toml("[s]\nx = 3\nneg = -7").unwrap();
        assert_eq!(doc.get_u64_or("s", "x", 0).unwrap(), 3);
        assert_eq!(doc.get_u64_or("s", "missing", 9).unwrap(), 9);
        let err = doc.get_u64_or("s", "neg", 9).unwrap_err().to_string();
        assert!(err.contains("`s.neg`"), "error names the key: {err}");
        assert!(err.contains("-7"), "error shows the offending value: {err}");
        assert!(err.contains("non-negative"), "{err}");
    }

    #[test]
    fn degenerate_serve_values_reject_at_config_construction() {
        // ISSUE 6 hardening: zero-valued serve knobs must surface as a
        // clear Err from the typed-config layer fed by this parser —
        // never a panic, and never a silently clamped session.
        use crate::config::ServeConfig;
        for (toml, key) in [
            ("[serve]\nqueue_depth = 0\n", "queue_depth"),
            ("[serve]\npriorities = 0\n", "priorities"),
            ("[serve]\nworkers = 0\n", "workers"),
            ("[serve]\nshards = 0\n", "shards"),
            // negatives reject in get_u64_or itself, naming the key
            ("[serve]\nqueue_depth = -4\n", "queue_depth"),
            ("[serve]\nshards = -1\n", "shards"),
            ("[serve]\nheartbeat_ms = -25\n", "heartbeat_ms"),
            ("[serve]\ndefault_deadline_ms = -1\n", "default_deadline_ms"),
        ] {
            let err = ServeConfig::from_toml(toml)
                .expect_err(&format!("`{key} = 0` must be rejected"))
                .to_string();
            assert!(err.contains(key), "error names `{key}`: {err}");
        }
    }

    #[test]
    fn empty_array_and_string_array() {
        let doc = parse_toml(r#"a = []
b = ["x", "y"]"#)
            .unwrap();
        assert_eq!(doc[""]["a"], Value::Array(vec![]));
        assert_eq!(
            doc[""]["b"],
            Value::Array(vec![Value::Str("x".into()), Value::Str("y".into())])
        );
    }
}
