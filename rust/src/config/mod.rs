//! Configuration system: a from-scratch TOML-subset parser plus the typed
//! configs the launcher consumes (accelerator, model, serving, sweep).

mod toml_lite;
mod types;

pub use toml_lite::{parse_toml, Value};
pub use types::{
    default_monitor_pump_us, ModelChoice, ModelMix, RunConfig, ServeBackend, ServeConfig,
    SweepConfig, MONITOR_PUMP_US_DEFAULT,
};
