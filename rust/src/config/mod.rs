//! Configuration system: a from-scratch TOML-subset parser plus the typed
//! configs the launcher consumes (accelerator, model, serving, sweep).

mod toml_lite;
mod types;

pub use toml_lite::{parse_toml, Value};
pub use types::{ModelChoice, ModelMix, RunConfig, ServeBackend, ServeConfig, SweepConfig};
