//! Closed-form schedule/cost model — the analytic twin of the micro
//! simulator. Every formula here mirrors a line of `sim/array.rs` /
//! `sim/unit.rs`; `rust/tests/schedule_vs_sim.rs` enforces exact equality
//! of cycles and event counts on randomized layers (with dense, non-zero
//! data so gating is driven by padding alone, which both sides count).

use crate::models::graph::{Layer, ModelGraph, Node, Residual};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::EventCounts;
use crate::sim::unit::WORKERS;

/// Analytic per-node result (mirror of [`crate::sim::LayerRun`]).
#[derive(Debug, Clone)]
pub struct LayerAnalysis {
    pub node_idx: usize,
    pub label: String,
    pub cycles: u64,
    pub counts: EventCounts,
    pub u_pe: f64,
    pub macs: u64,
    /// Active units during this layer.
    pub active_units: usize,
}

/// Whole-graph analytic result.
#[derive(Debug, Clone)]
pub struct GraphAnalysis {
    pub name: String,
    pub layers: Vec<LayerAnalysis>,
    pub totals: EventCounts,
}

impl GraphAnalysis {
    pub fn total_cycles(&self) -> u64 {
        self.totals.cycles
    }

    /// Conv-layer utilizations in graph order (Fig 21's series).
    pub fn conv_utilizations(&self) -> Vec<f64> {
        self.layers
            .iter()
            .filter(|l| l.label.starts_with("conv"))
            .map(|l| l.u_pe)
            .collect()
    }
}

/// Round-robin share of `total` items for lane `i` of `lanes`.
fn rr_share(total: u64, lanes: u64, i: u64) -> u64 {
    total / lanes + u64::from(i < total % lanes)
}

/// Padding-induced zero taps for a conv layer: the number of (window,
/// channel) tap positions that fall outside the input — these quantize to
/// zero and are gated by the zero-gate unit. O(H_out + W_out).
fn padding_zero_taps(
    h_in: usize,
    w_in: usize,
    h_out: usize,
    w_out: usize,
    k: usize,
    stride: usize,
    pad: usize,
    c_in: usize,
) -> u64 {
    // rows_in(oy) = #ky with 0 <= oy*s + ky - p < h_in; separable in y/x.
    let count_in = |o: usize, n_in: usize| -> u64 {
        let lo = o * stride;
        (0..k)
            .filter(|&kk| {
                let idx = lo as isize + kk as isize - pad as isize;
                idx >= 0 && (idx as usize) < n_in
            })
            .count() as u64
    };
    let rows: Vec<u64> = (0..h_out).map(|oy| count_in(oy, h_in)).collect();
    let cols: Vec<u64> = (0..w_out).map(|ox| count_in(ox, w_in)).collect();
    let sum_rows: u64 = rows.iter().sum();
    let sum_cols: u64 = cols.iter().sum();
    // total in-bounds taps = sum_oy sum_ox rows(oy)*cols(ox)
    let in_bounds = sum_rows * sum_cols;
    let total = (h_out * w_out * k * k) as u64;
    (total - in_bounds) * c_in as u64
}

/// Analyze one conv node. `sparsity` is the fraction of *in-bounds* input
/// taps that are zero (post-ReLU sparsity); the equality tests use 0.0.
#[allow(clippy::too_many_arguments)]
fn analyze_conv(
    cfg: &AcceleratorConfig,
    node: &Node,
    node_idx: usize,
    g: &ModelGraph,
    sparsity: f64,
) -> LayerAnalysis {
    let (c_in, c_out, k, stride, pad, residual, time_dense) = match &node.layer {
        Layer::Conv {
            c_in,
            c_out,
            k,
            stride,
            pad,
            residual,
            time_dense,
            ..
        } => (*c_in, *c_out, *k, *stride, *pad, *residual, *time_dense),
        _ => unreachable!(),
    };
    let (h_out, w_out) = (node.out_shape.h, node.out_shape.w);
    let (h_in, w_in) = (node.in_shape.h, node.in_shape.w);
    let taps = (k * k * c_in) as u64;
    let active = cfg.units.min(2 * c_in).max(1) as u64;

    // Small-input split path (Figs 11-12) — mirror of the array driver's
    // paired-channel mode for maps of <= 4 outputs.
    if h_out * w_out <= 4 && c_out >= 2 {
        return analyze_conv_split(cfg, node, node_idx, g, sparsity);
    }

    // --- groups (flattened row-major positions, may wrap rows) ----------
    let windows_per_oc = (h_out * w_out) as u64;
    let groups_per_oc = windows_per_oc.div_ceil(WORKERS as u64);
    let rem = windows_per_oc % WORKERS as u64;

    // --- cycles ---------------------------------------------------------
    // Per unit: its ocs' groups back-to-back; +1 cold-start (first group
    // after the per-layer pipeline flush). Time-dense overhang: PE_9 runs
    // a time_dim-tap dense on the first group of each oc; cycles extend
    // only if time_dim > taps of that group.
    let overhang_per_oc = time_dense
        .map(|td| (td as u64).saturating_sub(taps))
        .unwrap_or(0);
    let n_max = rr_share(c_out as u64, active, 0);
    let cycles = n_max * (groups_per_oc * taps + overhang_per_oc) + u64::from(n_max > 0);

    // --- worker PE events ------------------------------------------------
    let mut c = EventCounts {
        cycles,
        total_pes: cfg.total_pes(),
        ..Default::default()
    };
    let total_windows = windows_per_oc * c_out as u64;
    let mac_slots = total_windows * taps;
    let pad_gated = padding_zero_taps(h_in, w_in, h_out, w_out, k, stride, pad, c_in)
        * c_out as u64;
    let sparse_gated = ((mac_slots - pad_gated) as f64 * sparsity) as u64;
    let gated = pad_gated + sparse_gated;
    c.pe.macs = mac_slots - gated;
    c.pe.gated_macs = gated;
    c.pe.writebacks = total_windows;
    c.pe.active_cycles = mac_slots; // workers: one tap-cycle per slot

    // Idle cycles of workers *inside* groups: only the final (partial)
    // group of each oc leaves lanes idle.
    if rem > 0 {
        let idle_lanes = WORKERS as u64 - rem;
        c.pe.idle_cycles += idle_lanes * taps * c_out as u64;
    }

    // --- PE_9 (server) events --------------------------------------------
    match residual {
        Residual::None => {
            if let Some(td) = time_dense {
                // one dense per oc on its first group; x values may be zero
                // only if the embedding has zeros (tests use nonzero).
                let dense_macs = td as u64 * c_out as u64;
                c.pe.macs += dense_macs;
                c.pe.active_cycles += dense_macs;
                c.pe.writebacks += c_out as u64;
                // PE_9 idles the rest of each group
                let active_groups = groups_per_oc * c_out as u64;
                let group_cycles = active_groups * taps + overhang_per_oc * c_out as u64;
                c.pe.idle_cycles += group_cycles - dense_macs;
            } else {
                // series: PE_9 idles every group cycle
                c.pe.idle_cycles += groups_per_oc * taps * c_out as u64;
            }
        }
        Residual::Identity { .. } => {
            // PE_9 is engaged (serving/holding) for every cycle of every
            // group — the paper's 100%-utilization residual mode.
            c.unit.served_values = total_windows;
            c.pe.active_cycles += groups_per_oc * taps * c_out as u64;
            c.pe.residual_adds = total_windows;
            c.mem.output_buf_reads += total_windows;
        }
        Residual::Conv { from, .. } => {
            let c_skip = g.nodes[from].out_shape.c as u64;
            // PE_9 computes c_skip-tap 1x1 convs (one per output) within
            // the group's cycles and transmits for the remainder: engaged
            // every cycle. The sync invariant (8*c_skip <= taps*8 for k=3)
            // guarantees it fits.
            let rmacs = total_windows * c_skip;
            c.unit.served_values = total_windows;
            c.pe.macs += rmacs;
            c.pe.active_cycles += groups_per_oc * taps * c_out as u64;
            c.pe.writebacks += total_windows;
            c.pe.residual_adds = total_windows;
            c.mem.output_buf_reads += total_windows * c_skip;
        }
    }

    // --- unit counters -----------------------------------------------------
    // unit.cycles = sum over units of their busy cycles
    let mut unit_cycles = 0u64;
    for i in 0..active {
        let n_i = rr_share(c_out as u64, active, i);
        unit_cycles += n_i * (groups_per_oc * taps + overhang_per_oc) + u64::from(n_i > 0);
    }
    c.unit.cycles = unit_cycles;
    c.unit.conv_outputs = total_windows;
    c.unit.weight_reads = taps * groups_per_oc * c_out as u64;

    // --- buffer reads with reuse (mirror of run_conv's per-group math) ---
    let (reads, reads_no_reuse) = conv_buffer_reads(
        cfg, c_in, c_out, k, stride, h_out, w_out,
    );
    c.unit.buffer_reads = reads;
    c.unit.buffer_reads_no_reuse = reads_no_reuse;
    c.unit.reuse_reg_writes = reads_no_reuse - reads;
    c.mem.input_buf_reads += 0; // core reads carried in unit.buffer_reads

    // --- memory system (layer level) -------------------------------------
    let ifm = node.in_shape.elems();
    let iterations = (c_out as u64).div_ceil(active);
    if ifm <= cfg.input_buf_elems {
        c.mem.dram_reads += ifm;
        c.mem.input_buf_writes += ifm;
    } else {
        c.mem.dram_reads += ifm * iterations;
        c.mem.input_buf_writes += ifm * iterations;
    }
    let wsize = (c_out * c_in * k * k) as u64;
    c.mem.dram_reads += wsize;
    c.mem.weight_buf_writes += if wsize <= cfg.weight_buf_elems {
        wsize
    } else {
        2 * wsize
    };
    c.mem.output_buf_writes += node.out_shape.elems();

    let macs = node.macs();
    let u_pe = c.u_pe();
    LayerAnalysis {
        node_idx,
        label: format!(
            "conv{k}x{k}/{stride} {}x{}x{} -> {}x{}x{}{}{}",
            c_in,
            h_in,
            w_in,
            c_out,
            h_out,
            w_out,
            match residual {
                Residual::None => "",
                Residual::Identity { .. } => " +skip",
                Residual::Conv { .. } => " +skipconv",
            },
            if time_dense.is_some() { " +time" } else { "" }
        ),
        cycles,
        counts: c,
        u_pe,
        macs,
        active_units: active as usize,
    }
}

/// Closed-form mirror of the small-input split mode (`sim/array.rs`'s
/// `hw_total <= 4` path + `sim/unit.rs::run_split_group`): channel pairs
/// run on disjoint 4-lane halves, PE_9 serves half A then half B.
fn analyze_conv_split(
    cfg: &AcceleratorConfig,
    node: &Node,
    node_idx: usize,
    g: &ModelGraph,
    sparsity: f64,
) -> LayerAnalysis {
    let (c_in, c_out, k, stride, pad, residual, time_dense) = match &node.layer {
        Layer::Conv {
            c_in,
            c_out,
            k,
            stride,
            pad,
            residual,
            time_dense,
            ..
        } => (*c_in, *c_out, *k, *stride, *pad, *residual, *time_dense),
        _ => unreachable!(),
    };
    let (h_out, w_out) = (node.out_shape.h, node.out_shape.w);
    let (h_in, w_in) = (node.in_shape.h, node.in_shape.w);
    let hw = (h_out * w_out) as u64;
    let taps = (k * k * c_in) as u64;
    let active = cfg.units.min(2 * c_in).max(1) as u64;
    let pairs = (c_out / 2) as u64;
    let lone = (c_out % 2) as u64;
    let c_skip = match residual {
        Residual::Conv { from, .. } => g.nodes[from].out_shape.c as u64,
        _ => 0,
    };
    let td = time_dense.unwrap_or(0) as u64;

    // Server work per group and the resulting overhang.
    let server_work = |ocs: u64| -> u64 {
        match residual {
            Residual::None => td * ocs,
            Residual::Identity { .. } => hw * ocs,
            Residual::Conv { .. } => hw * c_skip * ocs,
        }
    };
    let overhang_pair = server_work(2).saturating_sub(taps);
    let overhang_lone = server_work(1).saturating_sub(taps);

    // Unit assignment: pair p -> unit p % active; lone -> unit pairs % active.
    let mut per_unit = vec![0u64; active as usize];
    for p in 0..pairs {
        per_unit[(p % active) as usize] += taps + overhang_pair;
    }
    if lone > 0 {
        per_unit[(pairs % active) as usize] += taps + overhang_lone;
    }
    // +1 cold start per unit that did anything.
    let cycles = per_unit
        .iter()
        .map(|&c| c + u64::from(c > 0))
        .max()
        .unwrap_or(0);

    let mut c = EventCounts {
        cycles,
        total_pes: cfg.total_pes(),
        ..Default::default()
    };

    // Workers.
    let total_windows = hw * c_out as u64;
    let mac_slots = total_windows * taps;
    let pad_gated =
        padding_zero_taps(h_in, w_in, h_out, w_out, k, stride, pad, c_in) * c_out as u64;
    let sparse_gated = ((mac_slots - pad_gated) as f64 * sparsity) as u64;
    c.pe.macs = mac_slots - (pad_gated + sparse_gated);
    c.pe.gated_macs = pad_gated + sparse_gated;
    c.pe.writebacks = total_windows;
    c.pe.active_cycles = mac_slots;
    c.pe.idle_cycles += pairs * (8 - 2 * hw) * taps + lone * (8 - hw) * taps;

    // PE_9.
    match residual {
        Residual::None => {
            if td > 0 {
                let dense_macs = td * c_out as u64;
                c.pe.macs += dense_macs;
                c.pe.active_cycles += dense_macs;
                c.pe.writebacks += c_out as u64;
                // idle: non-consumed window cycles (residual flags false)
                c.pe.idle_cycles += pairs * taps.saturating_sub(2 * td)
                    + lone * taps.saturating_sub(td);
            } else {
                c.pe.idle_cycles += (pairs + lone) * taps;
            }
        }
        Residual::Identity { .. } => {
            c.unit.served_values = total_windows;
            c.pe.active_cycles += pairs * (taps + overhang_pair) + lone * (taps + overhang_lone);
            c.pe.residual_adds = total_windows;
            c.mem.output_buf_reads += total_windows;
        }
        Residual::Conv { .. } => {
            let rmacs = total_windows * c_skip;
            c.unit.served_values = total_windows;
            c.pe.macs += rmacs;
            c.pe.active_cycles += pairs * (taps + overhang_pair) + lone * (taps + overhang_lone);
            c.pe.writebacks += total_windows;
            c.pe.residual_adds = total_windows;
            c.mem.output_buf_reads += total_windows * c_skip;
        }
    }

    // Unit counters.
    let mut unit_cycles = 0u64;
    for &cyc in &per_unit {
        unit_cycles += cyc + u64::from(cyc > 0);
    }
    c.unit.cycles = unit_cycles;
    c.unit.conv_outputs = total_windows;
    c.unit.weight_reads = taps * (2 * pairs + lone);

    // Buffer reads: half A reads the distinct taps of the whole tiny map;
    // half B is a full register hit (same input windows).
    let total_inputs = hw * taps;
    let distinct_a = crate::sim::array::conv_group_distinct(
        c_in,
        k,
        stride,
        cfg.data_reuse,
        0,
        hw as usize,
        w_out,
    )
    .min(total_inputs);
    let b_reads = if cfg.data_reuse { 0 } else { total_inputs };
    c.unit.buffer_reads = pairs * (distinct_a + b_reads) + lone * distinct_a;
    c.unit.buffer_reads_no_reuse = (2 * pairs + lone) * total_inputs;
    c.unit.reuse_reg_writes = c.unit.buffer_reads_no_reuse - c.unit.buffer_reads;

    // Memory system.
    let ifm = node.in_shape.elems();
    let iterations = (c_out as u64).div_ceil(active);
    if ifm <= cfg.input_buf_elems {
        c.mem.dram_reads += ifm;
        c.mem.input_buf_writes += ifm;
    } else {
        c.mem.dram_reads += ifm * iterations;
        c.mem.input_buf_writes += ifm * iterations;
    }
    let wsize = (c_out * c_in * k * k) as u64;
    c.mem.dram_reads += wsize;
    c.mem.weight_buf_writes += if wsize <= cfg.weight_buf_elems {
        wsize
    } else {
        2 * wsize
    };
    c.mem.output_buf_writes += node.out_shape.elems();

    let u_pe = c.u_pe();
    LayerAnalysis {
        node_idx,
        label: format!(
            "conv{k}x{k}/{stride} {}x{}x{} -> {}x{}x{}{}{} [split]",
            c_in,
            h_in,
            w_in,
            c_out,
            h_out,
            w_out,
            match residual {
                Residual::None => "",
                Residual::Identity { .. } => " +skip",
                Residual::Conv { .. } => " +skipconv",
            },
            if time_dense.is_some() { " +time" } else { "" }
        ),
        cycles,
        counts: c,
        u_pe,
        macs: node.macs(),
        active_units: active as usize,
    }
}

/// Buffer reads for a conv layer with/without the SF reuse registers —
/// sums [`conv_group_distinct`] over one output channel's flattened
/// groups and multiplies by `c_out` (every oc walks the same positions).
fn conv_buffer_reads(
    cfg: &AcceleratorConfig,
    c_in: usize,
    c_out: usize,
    k: usize,
    stride: usize,
    h_out: usize,
    w_out: usize,
) -> (u64, u64) {
    use crate::sim::array::conv_group_distinct;
    let taps = (k * k * c_in) as u64;
    let hw = h_out * w_out;
    let mut per_oc_reads = 0u64;
    let mut per_oc_no_reuse = 0u64;
    let mut p = 0usize;
    while p < hw {
        let gw = WORKERS.min(hw - p);
        let total = gw as u64 * taps;
        per_oc_no_reuse += total;
        per_oc_reads +=
            conv_group_distinct(c_in, k, stride, cfg.data_reuse, p, gw, w_out).min(total);
        p += gw;
    }
    (
        per_oc_reads * c_out as u64,
        per_oc_no_reuse * c_out as u64,
    )
}

/// Analyze any node.
pub fn analyze_node(
    cfg: &AcceleratorConfig,
    g: &ModelGraph,
    node_idx: usize,
    sparsity: f64,
) -> LayerAnalysis {
    let node = &g.nodes[node_idx];
    let lanes = (cfg.units * WORKERS) as u64;
    let mk = |label: String, cycles: u64, f: &dyn Fn(&mut EventCounts)| {
        let mut c = EventCounts {
            cycles,
            total_pes: cfg.total_pes(),
            ..Default::default()
        };
        f(&mut c);
        let u_pe = c.u_pe();
        LayerAnalysis {
            node_idx,
            label,
            cycles,
            counts: c,
            u_pe,
            macs: node.macs(),
            active_units: cfg.units,
        }
    };
    match &node.layer {
        Layer::Conv { .. } => analyze_conv(cfg, node, node_idx, g, sparsity),
        Layer::MaxPool { k, stride } => {
            let outs = node.out_shape.elems();
            let reads = outs * (k * k) as u64;
            let cycles = outs.div_ceil(lanes);
            let _ = stride;
            mk(format!("maxpool{k}/{stride}"), cycles, &|c| {
                c.mem.input_buf_reads += reads;
                c.mem.output_buf_writes += outs;
            })
        }
        Layer::GlobalAvgPool => {
            let ins = node.in_shape.elems();
            let couts = node.out_shape.elems();
            mk("gap".into(), ins.div_ceil(lanes), &|c| {
                c.mem.input_buf_reads += ins;
                c.mem.output_buf_writes += couts;
            })
        }
        Layer::Dense { in_f, out_f, .. } => {
            let in_f = *in_f as u64;
            let out_f = *out_f as u64;
            let active = cfg.units as u64;
            // groups of 8 neurons round-robin by *group* over units
            let groups = out_f.div_ceil(WORKERS as u64);
            let gmax = rr_share(groups, active, 0);
            let cycles = gmax * in_f + u64::from(gmax > 0);
            mk(format!("dense {in_f}->{out_f}"), cycles, &|c| {
                c.pe.macs = out_f * in_f; // dense weights assumed nonzero
                c.pe.active_cycles = out_f * in_f;
                c.pe.writebacks = out_f;
                let rem = out_f % WORKERS as u64;
                if rem > 0 {
                    c.pe.idle_cycles += (WORKERS as u64 - rem) * in_f;
                }
                // PE_9 idles through every group (dense is a series op)
                c.pe.idle_cycles += groups * in_f;
                let mut ucycles = 0;
                for i in 0..active {
                    let gi = rr_share(groups, active, i);
                    ucycles += gi * in_f + u64::from(gi > 0);
                }
                c.unit.cycles = ucycles;
                c.unit.conv_outputs = out_f;
                c.unit.weight_reads = groups * in_f;
                let total_inputs = out_f.div_ceil(WORKERS as u64) * WORKERS as u64 * in_f;
                let total_inputs = total_inputs.min(groups * WORKERS as u64 * in_f);
                // broadcast reuse: each group reads in_f distinct (x) once
                // per lane-set; windows are weight rows (distinct), x shared
                let reads_no_reuse: u64 = {
                    // sum over groups of gw*in_f
                    let full = out_f / WORKERS as u64;
                    let rem = out_f % WORKERS as u64;
                    full * WORKERS as u64 * in_f + rem * in_f
                };
                let _ = total_inputs;
                // reused = (gw-1)*in_f per group
                let full = out_f / WORKERS as u64;
                let rem = out_f % WORKERS as u64;
                let reused = full * (WORKERS as u64 - 1) * in_f
                    + if rem > 0 { (rem - 1) * in_f } else { 0 };
                c.unit.buffer_reads_no_reuse = reads_no_reuse;
                c.unit.buffer_reads = reads_no_reuse - reused;
                c.unit.reuse_reg_writes = reused;
                // memory system
                c.mem.dram_reads += in_f; // stream_input(in_f, 1, 0), fits
                c.mem.input_buf_writes += in_f;
                c.mem.dram_reads += in_f * out_f;
                c.mem.weight_buf_writes += if in_f * out_f <= cfg.weight_buf_elems {
                    in_f * out_f
                } else {
                    2 * in_f * out_f
                };
                c.mem.output_buf_writes += out_f;
            })
        }
        Layer::Upsample2x => {
            let elems = node.out_shape.elems();
            let ins = node.in_shape.elems();
            mk("upsample2x".into(), elems.div_ceil(lanes), &|c| {
                c.mem.input_buf_reads += ins;
                c.mem.output_buf_writes += elems;
            })
        }
        Layer::ConcatSkip { .. } => {
            let elems = node.out_shape.elems();
            mk("concat".into(), elems.div_ceil(lanes), &|c| {
                c.mem.input_buf_reads += elems;
                c.mem.output_buf_writes += elems;
            })
        }
    }
}

/// Analyze a whole graph under the given activation sparsity.
pub fn analyze_graph(cfg: &AcceleratorConfig, g: &ModelGraph, sparsity: f64) -> GraphAnalysis {
    let mut layers = Vec::with_capacity(g.nodes.len());
    let mut totals = EventCounts {
        total_pes: cfg.total_pes(),
        ..Default::default()
    };
    for idx in 0..g.nodes.len() {
        let la = analyze_node(cfg, g, idx, sparsity);
        totals.cycles += la.cycles;
        totals.pe.merge(&la.counts.pe);
        totals.unit.merge(&la.counts.unit);
        totals.mem.merge(&la.counts.mem);
        layers.push(la);
    }
    GraphAnalysis {
        name: g.name.clone(),
        layers,
        totals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet18, unet, vgg16, UnetConfig};

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn padding_zeros_3x3_p1() {
        // 4x4 input, 3x3/1/p1: border windows lose taps.
        // corners lose 5, edges lose 3, interior 0.
        let z = padding_zero_taps(4, 4, 4, 4, 3, 1, 1, 1);
        // 4 corners * 5 + 8 edge cells * 3 = 44
        assert_eq!(z, 44);
    }

    #[test]
    fn padding_zeros_no_pad() {
        assert_eq!(padding_zero_taps(8, 8, 6, 6, 3, 1, 0, 4), 0);
    }

    #[test]
    fn vgg16_layer1_utilization_low() {
        let g = vgg16(224, 1000);
        let a = analyze_graph(&cfg(), &g, 0.0);
        let convs: Vec<&LayerAnalysis> = a
            .layers
            .iter()
            .filter(|l| l.label.starts_with("conv"))
            .collect();
        // first layer: 6 of 8 units -> utilization well below the rest
        assert!(convs[0].u_pe < 0.75, "layer1 U_PE = {}", convs[0].u_pe);
        assert_eq!(convs[0].active_units, 6);
        // series layers: ~8/9 = 0.889 (PE_9 idle)
        for l in &convs[1..] {
            assert!(
                (0.80..0.92).contains(&l.u_pe),
                "{}: U_PE = {}",
                l.label,
                l.u_pe
            );
        }
    }

    #[test]
    fn resnet18_residual_layers_full_utilization() {
        // Fig 21b: residual layers reach ~100% (all 9 PEs engaged); series
        // layers sit at ~8/9. Partial tail groups (7x7 maps) shave both.
        let g = resnet18(224, 1000);
        let a = analyze_graph(&cfg(), &g, 0.0);
        let series_max = a
            .layers
            .iter()
            .filter(|l| l.label.starts_with("conv") && !l.label.contains("+skip"))
            .skip(1) // stem (c_in=3) is throttled
            .map(|l| l.u_pe)
            .fold(0.0, f64::max);
        for l in &a.layers {
            if l.label.contains("+skip") {
                assert!(
                    l.u_pe >= series_max - 1e-9,
                    "{}: U_PE {} < best series {}",
                    l.label,
                    l.u_pe,
                    series_max
                );
            }
        }
        // a residual layer whose map tiles by 8 must be ~100%
        let full = a
            .layers
            .iter()
            .find(|l| l.label.contains("56x56 +skip"))
            .or_else(|| a.layers.iter().find(|l| l.label.contains("+skip")))
            .unwrap();
        let hw: u64 = 56 * 56;
        if hw % 8 == 0 && full.label.contains("56x56") {
            assert!(full.u_pe > 0.95, "{}: {}", full.label, full.u_pe);
        }
    }

    #[test]
    fn unet_time_layers_use_pe9() {
        let g = unet(UnetConfig::default());
        let a = analyze_graph(&cfg(), &g, 0.0);
        let time_layers: Vec<&LayerAnalysis> = a
            .layers
            .iter()
            .filter(|l| l.label.contains("+time"))
            .collect();
        assert_eq!(time_layers.len(), 5);
        for l in time_layers {
            assert!(l.counts.pe.macs > 0);
        }
    }

    #[test]
    fn nine_cycles_per_conv_group() {
        // single 3x3 conv, 8 outputs, 1 oc, c_in=1: groups=1, taps=9,
        // cycles = 9 + 1 cold
        use crate::models::graph::{Act, GraphBuilder, Layer as L, TensorShape};
        let mut b = GraphBuilder::new("t", TensorShape::new(1, 1, 8));
        b.add(L::Conv {
            c_in: 1,
            c_out: 1,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        let g = b.build();
        let a = analyze_graph(&cfg(), &g, 0.0);
        assert_eq!(a.layers[0].cycles, 10, "9 MAC cycles + 1 writeback (Fig 7)");
    }

    #[test]
    fn residual_same_cycles_as_series() {
        use crate::models::graph::{Act, GraphBuilder, Layer as L, TensorShape};
        let mk = |residual| {
            let mut b = GraphBuilder::new("t", TensorShape::new(8, 16, 16));
            b.add(L::Conv {
                c_in: 8,
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual: Residual::None,
                time_dense: None,
            })
            .unwrap();
            b.add(L::Conv {
                c_in: 8,
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual,
                time_dense: None,
            })
            .unwrap();
            b.build()
        };
        let a_series = analyze_graph(&cfg(), &mk(Residual::None), 0.0);
        let a_res = analyze_graph(&cfg(), &mk(Residual::Identity { from: 0 }), 0.0);
        assert_eq!(a_series.total_cycles(), a_res.total_cycles());
    }

    #[test]
    fn sparsity_moves_macs_to_gated() {
        let g = vgg16(32, 10);
        let dense = analyze_graph(&cfg(), &g, 0.0);
        let sparse = analyze_graph(&cfg(), &g, 0.5);
        assert!(sparse.totals.pe.macs < dense.totals.pe.macs);
        assert_eq!(
            sparse.totals.pe.mac_slots(),
            dense.totals.pe.mac_slots(),
            "gating changes energy, not work"
        );
        assert_eq!(sparse.total_cycles(), dense.total_cycles());
    }

    #[test]
    fn reuse_cuts_buffer_reads_by_half_or_more() {
        let g = vgg16(32, 10);
        let a = analyze_graph(&cfg(), &g, 0.0);
        let with = a.totals.unit.buffer_reads as f64;
        let without = a.totals.unit.buffer_reads_no_reuse as f64;
        assert!(
            with < 0.55 * without,
            "reuse saves {:.1}%",
            100.0 * (1.0 - with / without)
        );
    }

    #[test]
    fn more_units_fewer_cycles() {
        let g = resnet18(224, 1000);
        let c8 = analyze_graph(&AcceleratorConfig::with_units(8), &g, 0.0).total_cycles();
        let c16 = analyze_graph(&AcceleratorConfig::with_units(16), &g, 0.0).total_cycles();
        let c2 = analyze_graph(&AcceleratorConfig::with_units(2), &g, 0.0).total_cycles();
        assert!(c16 < c8 && c8 < c2);
    }
}
