//! The model "compiler": maps a [`crate::models::ModelGraph`] onto the
//! SF-MMCN array *analytically* — a closed-form mirror of the cycle
//! simulator in [`crate::sim`].
//!
//! Why both exist: the micro simulator executes every MAC (real numerics,
//! exact counts) but full-resolution VGG-16 is ~15.5 G MACs — far too slow
//! to sweep in benches. The schedule model computes the identical counts in
//! O(H·W) per layer. `rust/tests/schedule_vs_sim.rs` property-tests the two
//! against each other on randomized small layers in every SF mode; that
//! equivalence is what licenses using the analytic model for the paper's
//! full-size figures (Figs 20, 21, 24, 25; Table I).

pub mod schedule;

pub use schedule::{analyze_graph, analyze_node, GraphAnalysis, LayerAnalysis};
