//! Tables I, II, III and the §IV headline ratios.

use crate::baselines::{carla, mmcn, pe_array, published};
use crate::compiler::analyze_graph;
use crate::models::{resnet18, unet, vgg16, UnetConfig};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::{PpaReport, CAL_40NM, CAL_40NM_LAYOUT};

use super::render_table;

/// The post-ReLU activation sparsity assumed for full-model energy runs
/// (typical measured VGG/ResNet mid-network sparsity; the zero-gate unit
/// is what makes this matter).
pub const DEFAULT_SPARSITY: f64 = 0.45;

/// Structured Table-I row for the simulated machines.
#[derive(Debug, Clone)]
pub struct SimRow {
    pub name: String,
    pub pes: u64,
    pub report: PpaReport,
}

/// Simulated Table-I data: SF-MMCN + the three baselines on VGG-16 and
/// ResNet-18 at the given resolution (224 for the paper's setting).
pub fn table1_sim_rows(img: usize) -> Vec<SimRow> {
    let vgg = vgg16(img, 1000);
    let rn = resnet18(img, 1000);
    let cfg = AcceleratorConfig::default();

    // SF-MMCN: run both models back-to-back (the paper's evaluation set).
    let mut sf = analyze_graph(&cfg, &vgg, DEFAULT_SPARSITY).totals;
    let sf_rn = analyze_graph(&cfg, &rn, DEFAULT_SPARSITY).totals;
    sf.merge_run(&sf_rn);
    let sf_report = CAL_40NM.report(&sf, cfg.units as u64);

    let mut rows = vec![SimRow {
        name: "SF-MMCN (sim, this repo)".into(),
        pes: cfg.total_pes(),
        report: sf_report,
    }];

    let mut mm = mmcn::analyze_graph(&vgg, DEFAULT_SPARSITY).counts;
    mm.merge_run(&mmcn::analyze_graph(&rn, DEFAULT_SPARSITY).counts);
    rows.push(SimRow {
        name: "MMCN (sim)".into(),
        pes: mm.total_pes,
        report: CAL_40NM.report(&mm, mmcn::MMCN_UNITS as u64),
    });

    let mut ca = carla::analyze_graph(&vgg).counts;
    ca.merge_run(&carla::analyze_graph(&rn).counts);
    rows.push(SimRow {
        name: "CARLA-like (sim)".into(),
        pes: ca.total_pes,
        report: CAL_40NM.report(&ca, carla::CARLA_COLUMNS),
    });

    let mut pa = pe_array::analyze_graph(&vgg).counts;
    pa.merge_run(&pe_array::analyze_graph(&rn).counts);
    rows.push(SimRow {
        name: "PE-array (sim)".into(),
        pes: pa.total_pes,
        report: CAL_40NM.report(&pa, 16),
    });

    rows
}

/// Render Table I: simulated rows under the common 40 nm model, then the
/// as-published rows the paper quotes.
pub fn table1(img: usize) -> (String, Vec<SimRow>) {
    let sim = table1_sim_rows(img);
    let mut rows: Vec<Vec<String>> = Vec::new();
    for r in &sim {
        rows.push(vec![
            r.name.clone(),
            format!("{:.0}", r.report.freq_hz / 1e6),
            r.report.tech.into(),
            format!("{:.2}", r.report.area_mm2),
            "16".into(),
            r.pes.to_string(),
            format!("{:.1}", r.report.core_power_w * 1e3),
            format!("{:.1}", r.report.gops),
            format!("{:.2}k", r.report.gops_per_w / 1e3),
            format!("{:.1}", r.report.gops_per_mm2),
            format!("{:.1}%", r.report.u_pe * 100.0),
            format!("{:.3}", r.report.nu),
        ]);
    }
    rows.push(vec!["--- published rows (quoted, as in the paper) ---".into()]);
    for p in published::table1_rows() {
        rows.push(vec![
            format!("{} {}", p.name, p.reference),
            p.freq_mhz.into(),
            p.tech.into(),
            p.area_mm2.map(|a| format!("{a:.2}")).unwrap_or("n/a".into()),
            p.precision_bits.into(),
            p.num_pes.map(|n| n.to_string()).unwrap_or("n/a".into()),
            p.power_mw.into(),
            p.throughput_gops.into(),
            p.energy_eff_gops_w.into(),
            p.area_eff_gops_mm2
                .map(|a| format!("{a:.1}"))
                .unwrap_or("n/a".into()),
            "-".into(),
            p.nu.map(|n| format!("{n}")).unwrap_or("-".into()),
        ]);
    }
    let paper = published::paper_this_work();
    rows.push(vec![
        format!("{} {}", paper.name, paper.reference),
        paper.freq_mhz.into(),
        paper.tech.into(),
        format!("{:.1}", paper.area_mm2.unwrap()),
        paper.precision_bits.into(),
        paper.num_pes.unwrap().to_string(),
        paper.power_mw.into(),
        paper.throughput_gops.into(),
        paper.energy_eff_gops_w.into(),
        format!("{:.2}", paper.area_eff_gops_mm2.unwrap()),
        "-".into(),
        format!("{}", paper.nu.unwrap()),
    ]);
    let text = format!(
        "TABLE I — comparison with other accelerators (VGG-16 + ResNet-18 @ {img})\n{}",
        render_table(
            &[
                "design", "MHz", "tech", "mm2", "bits", "PEs", "mW", "GOPs", "GOPs/W",
                "GOPs/mm2", "U_PE", "nu"
            ],
            &rows
        )
    );
    (text, sim)
}

/// Table II: operation-efficiency comparison vs CARLA (pixel sweep).
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub pixel: u64,
    pub carla_cycles_per_conv: u64,
    pub sf_cycles_per_conv: u64,
    pub carla_macs_per_cycle: f64,
    pub sf_macs_per_cycle: f64,
    pub speedup: f64,
}

pub fn table2_rows() -> Vec<Table2Row> {
    // Derivation (see EXPERIMENTS.md): per unit, SF finishes 8 outputs
    // every 9 cycles -> 8/9 outputs/cycle; CARLA delivers one output per 3
    // cycles (k = 3). The normalized speedup is (8/9)/(1/3) = 8/3 = 2.67 —
    // exactly the paper's constant column. The paper's "No. of MAC" column
    // scales with the row width N; it is the MAC work in flight for an
    // N-pixel row at each machine's rate.
    [28u64, 32, 224]
        .iter()
        .map(|&n| {
            let carla_cycles = carla::first_output_cycles(n, 3);
            let sf_cycles = 9;
            let carla_rate = n as f64 * 9.0 / (3.0 * n as f64); // 3 MACs/cyc
            let sf_rate = 8.0; // 8 self-computing PEs per unit
            Table2Row {
                pixel: n,
                carla_cycles_per_conv: carla_cycles,
                sf_cycles_per_conv: sf_cycles,
                carla_macs_per_cycle: carla_rate,
                sf_macs_per_cycle: sf_rate,
                speedup: sf_rate / carla_rate,
            }
        })
        .collect()
}

pub fn table2() -> (String, Vec<Table2Row>) {
    let rows = table2_rows();
    let table = render_table(
        &[
            "pixel",
            "cycles/CONV [15]",
            "cycles/CONV SF",
            "MAC/cyc [15]",
            "MAC/cyc SF",
            "speedup (norm)",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.pixel.to_string(),
                    r.carla_cycles_per_conv.to_string(),
                    r.sf_cycles_per_conv.to_string(),
                    format!("{:.0}", r.carla_macs_per_cycle),
                    format!("{:.0}", r.sf_macs_per_cycle),
                    format!("x{:.2}", r.speedup),
                ]
            })
            .collect::<Vec<_>>(),
    );
    (
        format!(
            "TABLE II — operation efficiency vs CARLA [15]\n{table}\
             paper: 84/96/672 vs 9 cycles, speedup x2.67 at every pixel size\n"
        ),
        rows,
    )
}

/// Table III: the post-layout chip operating point on the U-net workload.
pub fn table3() -> (String, PpaReport) {
    let g = unet(UnetConfig::default());
    let cfg = AcceleratorConfig::default();
    let a = analyze_graph(&cfg, &g, DEFAULT_SPARSITY);
    let rep = CAL_40NM_LAYOUT.report(&a.totals, cfg.units as u64);
    let text = format!(
        "TABLE III — SF-MMCN chip operating point (post-layout model, U-net workload)\n\
         {}\n\
         paper: 40 nm, 200 MHz, 0.9 V, 16-bit, core 0.39 mm2, 116.7 mW total,\n\
         3.75 GOPs/mW, 3752.36 GOPs/mm2 (paper OP accounting)\n",
        render_table(
            &["metric", "measured (sim)"],
            &[
                vec!["technology".into(), rep.tech.into()],
                vec!["frequency".into(), format!("{:.0} MHz", rep.freq_hz / 1e6)],
                vec!["bit-width".into(), "16 bits".into()],
                vec!["core area".into(), format!("{:.2} mm2", rep.area_mm2)],
                vec![
                    "core power".into(),
                    format!("{:.1} mW", rep.core_power_w * 1e3)
                ],
                vec![
                    "total power (+DRAM)".into(),
                    format!("{:.1} mW", rep.total_power_w * 1e3)
                ],
                vec!["throughput".into(), format!("{:.1} GOPs", rep.gops)],
                vec![
                    "efficiency".into(),
                    format!("{:.3} GOPs/mW", rep.gops_per_w / 1e3)
                ],
                vec![
                    "area efficiency".into(),
                    format!("{:.1} GOPs/mm2", rep.gops_per_mm2)
                ],
            ]
        )
    );
    (text, rep)
}

/// §IV headline claims, measured under the consistent simulation model.
#[derive(Debug, Clone)]
pub struct Headlines {
    /// Power reduction vs the parallel PE array (paper: 92%).
    pub power_reduction_vs_parallel: f64,
    /// Area reduction vs the parallel PE array (paper: 70%).
    pub area_reduction_vs_parallel: f64,
    /// Energy-efficiency ratio vs CARLA-sim (paper quotes 81x against
    /// CARLA's published 0.31 kGOPs/W using the paper's OP accounting).
    pub eff_ratio_vs_carla_sim: f64,
    /// Area-efficiency ratio vs CARLA published (paper: 18.42x).
    pub area_eff_ratio_vs_carla_published: f64,
    /// nu ratio CARLA-sim / SF-sim (paper: 82.3 / 0.02).
    pub nu_ratio_vs_carla_sim: f64,
}

pub fn headline_ratios(img: usize) -> (String, Headlines) {
    let sim = table1_sim_rows(img);
    let sf = &sim[0].report;
    let carla_sim = &sim[2].report;
    let pa = &sim[3].report;
    let carla_pub_area_eff = published::table1_rows()[0].area_eff_gops_mm2.unwrap();
    let h = Headlines {
        power_reduction_vs_parallel: 1.0 - sf.core_power_w / pa.core_power_w,
        area_reduction_vs_parallel: 1.0 - sf.area_mm2 / pa.area_mm2,
        eff_ratio_vs_carla_sim: sf.gops_per_w / carla_sim.gops_per_w,
        area_eff_ratio_vs_carla_published: sf.gops_per_mm2 / carla_pub_area_eff,
        nu_ratio_vs_carla_sim: carla_sim.nu / sf.nu,
    };
    let text = format!(
        "HEADLINE RATIOS (consistent simulation accounting)\n\
         power reduction vs parallel PE array: {:.0}%   (paper: 92%)\n\
         area  reduction vs parallel PE array: {:.0}%   (paper: 70%)\n\
         energy-eff ratio vs CARLA-sim:        {:.1}x  (paper: 81x, using its OP accounting)\n\
         area-eff ratio vs CARLA published:    {:.1}x  (paper: 18.42x)\n\
         nu ratio CARLA-sim / SF-sim:          {:.0}x  (paper: 82.3/0.02 = 4115x)\n",
        h.power_reduction_vs_parallel * 100.0,
        h.area_reduction_vs_parallel * 100.0,
        h.eff_ratio_vs_carla_sim,
        h.area_eff_ratio_vs_carla_published,
        h.nu_ratio_vs_carla_sim,
    );
    (text, h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_numbers() {
        let rows = table2_rows();
        assert_eq!(rows[0].carla_cycles_per_conv, 84);
        assert_eq!(rows[1].carla_cycles_per_conv, 96);
        assert_eq!(rows[2].carla_cycles_per_conv, 672);
        for r in &rows {
            assert_eq!(r.sf_cycles_per_conv, 9);
            assert!((r.speedup - 8.0 / 3.0).abs() < 1e-9, "x2.67 exactly");
        }
    }

    #[test]
    fn table1_sim_sf_power_near_paper() {
        let sim = table1_sim_rows(32); // small img for test speed
        let sf = &sim[0].report;
        let mw = sf.core_power_w * 1e3;
        assert!((8.0..30.0).contains(&mw), "SF core power {mw} mW");
        assert!((1.7..2.1).contains(&sf.area_mm2), "area {}", sf.area_mm2);
    }

    #[test]
    fn table1_sf_wins_every_fom() {
        let sim = table1_sim_rows(32);
        let sf = &sim[0].report;
        for other in &sim[1..] {
            assert!(
                sf.gops_per_w > other.report.gops_per_w,
                "SF must win GOPs/W vs {}",
                other.name
            );
            // area efficiency: the paper's claim is vs CARLA (18.42x);
            // vs the parallel array the claim is raw area/power reduction
            // (covered by headline_shapes_hold).
            if other.name.starts_with("CARLA") {
                assert!(
                    sf.gops_per_mm2 > other.report.gops_per_mm2,
                    "SF must win GOPs/mm2 vs {}",
                    other.name
                );
            }
            // nu: SF beats the traditional arrays. MMCN-sim is exempt:
            // the published MMCN nu (0.11) reflects a measured ~3%
            // utilization our charitable model does not reproduce — see
            // EXPERIMENTS.md "MMCN nu" note.
            if other.name != "MMCN (sim)" {
                assert!(
                    sf.nu < other.report.nu,
                    "SF must have the smallest nu vs {}",
                    other.name
                );
            }
        }
    }

    #[test]
    fn headline_shapes_hold() {
        let (_, h) = headline_ratios(32);
        assert!(h.power_reduction_vs_parallel > 0.6, "{h:?}");
        assert!(h.area_reduction_vs_parallel > 0.55, "{h:?}");
        assert!(h.eff_ratio_vs_carla_sim > 3.0, "{h:?}");
        assert!(h.nu_ratio_vs_carla_sim > 40.0, "{h:?}");
    }

    #[test]
    fn table3_operating_point() {
        let (text, rep) = table3();
        assert!(text.contains("TABLE III"));
        assert!((0.3..0.6).contains(&rep.area_mm2), "core {}", rep.area_mm2);
        assert_eq!(rep.freq_hz, 200e6);
    }
}
