//! Regeneration of every table and figure in the paper's evaluation
//! (§IV): Tables I-III, Figures 20-25, and the §IV headline ratios.
//!
//! Each function returns a rendered text block *and* structured data so
//! the benches can assert the paper-shape properties (who wins, by what
//! factor) and EXPERIMENTS.md can record paper-vs-measured side by side.

pub mod figures;
pub mod tables;

pub mod ablations;

pub use ablations::ablation_suite;
pub use figures::{fig19, fig20, fig21, fig22, fig23, fig24, fig25};
pub use tables::{headline_ratios, table1, table2, table3};

/// Right-pad or truncate a cell to a fixed width.
pub(crate) fn cell(s: &str, w: usize) -> String {
    if s.len() >= w {
        s[..w].to_string()
    } else {
        format!("{s:<w$}")
    }
}

/// Render an aligned table from rows of cells.
pub(crate) fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let hdr: Vec<String> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| cell(h, widths[i]))
        .collect();
    out.push_str(&hdr.join("  "));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| cell(c, widths[i]))
            .collect();
        out.push_str(&line.join("  "));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let s = render_table(
            &["name", "v"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer".into(), "22".into()],
            ],
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
    }
}
