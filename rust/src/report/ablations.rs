//! Ablations of the SF-MMCN design choices (DESIGN.md §3 "design-choice
//! ablations"): each knob the paper motivates, toggled independently so
//! its contribution is measurable.
//!
//! 1. **Zero gating** (Fig 4's zero-gate unit): energy at activation
//!    sparsity 0 vs 0.45 vs 0.7.
//! 2. **Data-reuse registers** (Fig 17): buffer traffic and energy with
//!    reuse on/off.
//! 3. **Server flow itself** (Figs 5-6): SF fused residuals vs the
//!    serialized strategy on the same 72-PE budget.
//! 4. **Buffer sizing**: DRAM traffic as the input buffer shrinks.

use crate::compiler::analyze_graph;
use crate::models::{resnet18, unet, UnetConfig};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::CAL_40NM;

use super::render_table;

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub name: String,
    pub cycles: u64,
    pub core_mw: f64,
    pub dram_mj_per_inf: f64,
    pub buffer_reads: u64,
}

/// Run the full ablation suite on ResNet-18@64 + U-net16.
pub fn ablation_suite() -> (String, Vec<AblationRow>) {
    let rn = resnet18(64, 10);
    let un = unet(UnetConfig::default());
    let mut rows = Vec::new();
    let mut out = String::new();

    let run = |cfg: &AcceleratorConfig, sparsity: f64, name: &str| -> AblationRow {
        let mut totals = analyze_graph(cfg, &rn, sparsity).totals;
        totals.merge_run(&analyze_graph(cfg, &un, sparsity).totals);
        let rep = CAL_40NM.report(&totals, cfg.units as u64);
        AblationRow {
            name: name.to_string(),
            cycles: totals.cycles,
            core_mw: rep.core_power_w * 1e3,
            dram_mj_per_inf: rep.dram_energy_j * 1e3,
            buffer_reads: totals.unit.buffer_reads,
        }
    };

    // --- 1) zero gating ---------------------------------------------------
    let base = AcceleratorConfig::default();
    let r0 = run(&base, 0.0, "gating: dense input (0% zeros)");
    let r45 = run(&base, 0.45, "gating: ReLU sparsity 45%");
    let r70 = run(&base, 0.70, "gating: ReLU sparsity 70%");
    out.push_str("ABLATION 1 — zero-gate unit (energy vs activation sparsity)\n");
    out.push_str(&render_table(
        &["config", "cycles", "core mW"],
        &[&r0, &r45, &r70]
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.cycles.to_string(),
                    format!("{:.2}", r.core_mw),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    out.push_str("cycles identical (gating saves energy, not time)\n\n");
    rows.extend([r0.clone(), r45.clone(), r70.clone()]);

    // --- 2) data-reuse registers -----------------------------------------
    let no_reuse = AcceleratorConfig {
        data_reuse: false,
        ..base
    };
    let rr = run(&base, 0.45, "reuse registers ON");
    let rn_ = run(&no_reuse, 0.45, "reuse registers OFF");
    out.push_str("ABLATION 2 — data-reuse registers (Fig 17)\n");
    out.push_str(&render_table(
        &["config", "buffer reads", "core mW"],
        &[&rr, &rn_]
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.buffer_reads.to_string(),
                    format!("{:.2}", r.core_mw),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    out.push_str(&format!(
        "reuse cuts buffer reads by {:.0}%\n\n",
        100.0 * (1.0 - rr.buffer_reads as f64 / rn_.buffer_reads as f64)
    ));
    rows.extend([rr, rn_]);

    // --- 3) server flow vs serialized on equal PE budget --------------------
    let sf = analyze_graph(&base, &rn, 0.45).totals;
    let mm = crate::baselines::mmcn::analyze_graph(&rn, 0.45);
    out.push_str("ABLATION 3 — server flow vs serialized parallel structures\n");
    out.push_str(&format!(
        "SF fused: {} cycles | serialized (MMCN strategy, 32 PEs): {} cycles \
         -> x{:.2}\n\n",
        sf.cycles,
        mm.counts.cycles,
        mm.counts.cycles as f64 / sf.cycles as f64
    ));

    // --- 4) buffer sizing ---------------------------------------------------
    out.push_str("ABLATION 4 — input-buffer capacity vs DRAM traffic\n");
    let mut brows = Vec::new();
    for kelems in [4u64, 16, 64, 256] {
        let cfg = AcceleratorConfig {
            input_buf_elems: kelems * 1024,
            ..base
        };
        let r = run(&cfg, 0.45, &format!("{kelems} Kelem input buffer"));
        brows.push(vec![
            r.name.clone(),
            format!("{:.3}", r.dram_mj_per_inf),
        ]);
        rows.push(r);
    }
    out.push_str(&render_table(&["config", "DRAM mJ/inference-pair"], &brows));
    out.push_str("larger buffers eliminate re-streaming of big feature maps\n");

    (out, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gating_saves_energy_not_cycles() {
        let (_, rows) = ablation_suite();
        let dense = &rows[0];
        let sparse = &rows[2];
        assert_eq!(dense.cycles, sparse.cycles);
        assert!(
            sparse.core_mw < dense.core_mw * 0.85,
            "70% sparsity must cut core power meaningfully: {} vs {}",
            sparse.core_mw,
            dense.core_mw
        );
    }

    #[test]
    fn reuse_cuts_buffer_traffic() {
        let (_, rows) = ablation_suite();
        let on = &rows[3];
        let off = &rows[4];
        // conv layers save ~60% (30 of 72 reads per group); dense layers
        // share the broadcast on both sides, so the blended saving is ~45%
        assert!(on.buffer_reads < off.buffer_reads * 6 / 10);
        assert!(on.core_mw < off.core_mw);
    }

    #[test]
    fn bigger_buffers_less_dram() {
        let (_, rows) = ablation_suite();
        let n = rows.len();
        let small = &rows[n - 4];
        let large = &rows[n - 1];
        assert!(large.dram_mj_per_inf <= small.dram_mj_per_inf);
    }

    #[test]
    fn render_mentions_all_four() {
        let (text, _) = ablation_suite();
        for i in 1..=4 {
            assert!(text.contains(&format!("ABLATION {i}")));
        }
    }
}
