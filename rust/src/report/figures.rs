//! Figures 20-25: the paper's evaluation plots, regenerated as data
//! series (printed as aligned text + ASCII bars).

use crate::baselines::{carla, mmcn};
use crate::compiler::analyze_graph;
use crate::models::{resnet18, unet, vgg16, ModelGraph, UnetConfig};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::CAL_40NM;

use super::render_table;
use super::tables::DEFAULT_SPARSITY;

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    format!("{}{}", "#".repeat(n), ".".repeat(width - n))
}

/// Fig 19: dataflow comparison — traditional serialized schedule vs the
/// SF-MMCN schedule, as an ASCII waveform (the paper draws this as a
/// timing diagram). Workload: a residual block (Conv_0 -> Conv_1 with a
/// skip), 3x3 filters, one 8-output group per conv.
pub fn fig19() -> (String, (u64, u64)) {
    use crate::sim::trace::Trace;
    let taps = 9u64;
    // Traditional (series strategy): conv_0, conv_1, then the residual
    // add as its own pass (+ the memory round-trip it implies).
    let mut trad = Trace::new(512);
    for t in 0..taps {
        trad.push(t, "Conv_0", "M");
        trad.push(taps + 1 + t, "Conv_1", "M");
    }
    for t in 0..8 {
        trad.push(2 * (taps + 1) + t, "Residual_0", "A");
    }
    let trad_cycles = 2 * (taps + 1) + 8;

    // SF-MMCN: Conv_1 and the residual run in the same cycles — PE_9
    // serves while PE_1..8 MAC (Fig 6b).
    let mut sf = Trace::new(512);
    for t in 0..taps {
        sf.push(t, "Conv_0", "M");
        sf.push(taps + 1 + t, "Conv_1", "M");
        sf.push(taps + 1 + t, "PE_9 serve", "S");
    }
    let sf_cycles = 2 * (taps + 1);

    let text = format!(
        "FIG 19 — dataflow: traditional (serialized) vs SF-MMCN\n\
         traditional ({trad_cycles} cycles):\n{}\n\
         SF-MMCN ({sf_cycles} cycles — residual absorbed into Conv_1):\n{}\n\
         paper shape: the residual pass disappears from the schedule\n",
        trad.render(trad_cycles + 2),
        sf.render(sf_cycles + 2)
    );
    (text, (trad_cycles, sf_cycles))
}

/// Fig 20: number of SF-MMCN units vs efficiency factor nu.
///
/// nu here follows the paper's design-selection reading: power divided by
/// utilization *of the full design's hierarchy* — the memory system and
/// control are sized once, so a small MAC core leaves that hierarchy
/// under-used ("a small MAC core unbalances the distribution of each
/// hierarchy", §IV.A). Utilization is therefore normalized against the
/// shipped 8-unit (72-PE) reference; with it, 2/4 units price badly,
/// 8 sits near the asymptote and 16 is marginally best — the paper's
/// exact argument for shipping 8.
pub fn fig20() -> (String, Vec<(usize, f64)>) {
    let g = resnet18(224, 1000);
    const REF_PES: f64 = 72.0;
    let mut series = Vec::new();
    for units in [2usize, 4, 8, 16] {
        let cfg = AcceleratorConfig::with_units(units);
        let a = analyze_graph(&cfg, &g, DEFAULT_SPARSITY);
        let rep = CAL_40NM.report(&a.totals, units as u64);
        let u_ref = a.totals.pe.active_cycles as f64
            / (a.totals.cycles as f64 * REF_PES);
        let nu = rep.core_power_w / u_ref;
        series.push((units, nu));
    }
    let max_nu = series.iter().map(|(_, n)| *n).fold(0.0, f64::max);
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(u, nu)| {
            vec![
                u.to_string(),
                format!("{nu:.4}"),
                bar(nu / max_nu, 40),
            ]
        })
        .collect();
    let text = format!(
        "FIG 20 — number of SF-MMCN units vs efficiency factor nu (ResNet-18)\n{}\
         paper shape: 2 and 4 units unfavourable; 8 good; 16 best nu but\n\
         worst absolute power/PE count (the paper ships 8)\n",
        render_table(&["units", "nu (72-PE ref)", ""], &rows)
    );
    (text, series)
}

/// Fig 21: per-conv-layer PE utilization on VGG-16 (a) and ResNet-18 (b).
pub fn fig21() -> (String, (Vec<f64>, Vec<f64>)) {
    let cfg = AcceleratorConfig::default();
    let render = |g: &ModelGraph| -> (Vec<f64>, Vec<Vec<String>>) {
        let a = analyze_graph(&cfg, g, 0.0);
        let mut utils = Vec::new();
        let mut rows = Vec::new();
        for l in a.layers.iter().filter(|l| l.label.starts_with("conv")) {
            utils.push(l.u_pe);
            rows.push(vec![
                format!("L{}", l.node_idx),
                l.label.clone(),
                format!("{:.1}%", l.u_pe * 100.0),
                bar(l.u_pe, 30),
            ]);
        }
        (utils, rows)
    };
    let (vgg_u, vgg_rows) = render(&vgg16(224, 1000));
    let (rn_u, rn_rows) = render(&resnet18(224, 1000));
    let text = format!(
        "FIG 21a — PE utilization per conv layer, VGG-16 @224\n{}\n\
         FIG 21b — PE utilization per conv layer, ResNet-18 @224\n{}\
         paper shape: first layer lowest (3-channel input -> 6 of 8 units);\n\
         series layers ~89% (PE_9 idle); residual layers ~100% (PE_9 serving)\n",
        render_table(&["layer", "shape", "U_PE", ""], &vgg_rows),
        render_table(&["layer", "shape", "U_PE", ""], &rn_rows)
    );
    (text, (vgg_u, rn_u))
}

/// Fig 22: cycles to the first conv output vs input size N.
pub fn fig22() -> (String, Vec<(u64, u64, u64)>) {
    let mut series = Vec::new();
    for n in [4u64, 8, 16, 28, 32, 64, 112, 224] {
        let sf = 9u64; // SF: first outputs after the 9 MAC cycles
        let ca = carla::first_output_cycles(n, 3);
        series.push((n, sf, ca));
    }
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(n, sf, ca)| {
            vec![
                n.to_string(),
                sf.to_string(),
                ca.to_string(),
                format!("x{:.1}", *ca as f64 / *sf as f64),
            ]
        })
        .collect();
    let text = format!(
        "FIG 22 — cycles to first conv output vs input size (3x3 filter)\n{}\
         paper shape: SF flat at 9; CARLA 3N, diverging with input size\n",
        render_table(&["N", "SF-MMCN", "CARLA [15]", "ratio"], &rows)
    );
    (text, series)
}

/// Fig 23: cycles and outputs per filter shape Wh x Ww.
pub fn fig23() -> (String, Vec<(usize, u64, u64, u64, u64)>) {
    let mut series = Vec::new();
    for k in [1usize, 3, 5, 7] {
        let taps = (k * k) as u64;
        // SF: one group of 8 self-computed outputs per `taps` cycles
        let sf_cycles = taps;
        let sf_outputs = 8u64;
        // CARLA per the paper: "CARLA only provides one convolution
        // output in the same cycle [window]" — 1 output per Wh*Ww window
        let ca_cycles = taps;
        let ca_outputs = 1u64;
        series.push((k, sf_cycles, sf_outputs, ca_cycles, ca_outputs));
    }
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(k, sc, so, cc, co)| {
            vec![
                format!("{k}x{k}"),
                sc.to_string(),
                so.to_string(),
                cc.to_string(),
                co.to_string(),
                format!("x{:.1}", (*so as f64 / *sc as f64) / (*co as f64 / *cc as f64)),
            ]
        })
        .collect();
    let text = format!(
        "FIG 23 — efficiency vs weight shape (outputs delivered per cycle window)\n{}\
         paper shape: SF delivers a full 8-output group per Wh*Ww cycles at any\n\
         filter shape; CARLA's row dataflow delivers ~1 output per k cycles\n",
        render_table(
            &["WhxWw", "SF cyc", "SF outs", "CARLA cyc", "CARLA outs", "adv"],
            &rows
        )
    );
    (text, series)
}

/// Fig 24: latency, MMCN [24] vs SF-MMCN, on series and parallel models.
pub fn fig24() -> (String, Vec<(String, u64, u64, f64)>) {
    let cfg = AcceleratorConfig::default();
    let models: Vec<(&str, ModelGraph)> = vec![
        ("vgg16@32 (series)", vgg16(32, 10)),
        ("resnet18@32 (residual)", resnet18(32, 10)),
        ("unet16 (diffusion)", unet(UnetConfig::default())),
    ];
    let mut series = Vec::new();
    for (name, g) in &models {
        let sf = analyze_graph(&cfg, g, DEFAULT_SPARSITY).total_cycles();
        let mm = mmcn::analyze_graph(g, DEFAULT_SPARSITY).counts.cycles;
        series.push((name.to_string(), sf, mm, mm as f64 / sf as f64));
    }
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(n, sf, mm, r)| {
            vec![
                n.clone(),
                format!("{sf}"),
                format!("{mm}"),
                format!("x{r:.2}"),
            ]
        })
        .collect();
    let text = format!(
        "FIG 24 — latency (cycles): MMCN [24] vs SF-MMCN\n{}\
         paper shape: SF-MMCN latency strictly lower; the gap grows on\n\
         parallel-structure models (residual / U-net)\n",
        render_table(&["model", "SF-MMCN", "MMCN", "MMCN/SF"], &rows)
    );
    (text, series)
}

/// Fig 25: per-block throughput of the U-net on SF-MMCN.
pub fn fig25() -> (String, Vec<(String, f64)>, f64) {
    let g = unet(UnetConfig::default());
    let cfg = AcceleratorConfig::default();
    let a = analyze_graph(&cfg, &g, 0.0);
    // Block mapping per Fig 14: Block1 = time dense (rides on conv1),
    // Block2 = conv+act(+time), Block3 = conv(+skip), Block4 = final logic
    // (the fused skip add). We report per-layer GOPs grouped by kind.
    let mut series = Vec::new();
    let mut total_ops = 0.0;
    let mut total_cycles = 0.0;
    for l in &a.layers {
        if !l.label.starts_with("conv") {
            continue;
        }
        let ops = 2.0 * l.macs as f64;
        let secs = l.cycles as f64 / CAL_40NM.freq_hz;
        let gops = ops / secs / 1e9;
        let kind = if l.label.contains("+time") {
            "B1+B2 (conv+time)"
        } else if l.label.contains("+skip") {
            "B3+B4 (conv+skip)"
        } else {
            "stem/head"
        };
        series.push((format!("{kind} {}", l.label), gops));
        total_ops += ops;
        total_cycles += l.cycles as f64;
    }
    let combined = total_ops / (total_cycles / CAL_40NM.freq_hz) / 1e9;
    let max = series.iter().map(|(_, g)| *g).fold(0.0, f64::max);
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(n, g)| vec![n.clone(), format!("{g:.1}"), bar(g / max, 30)])
        .collect();
    let text = format!(
        "FIG 25 — U-net per-block throughput on SF-MMCN (GOPs, datapath accounting)\n{}\
         combined conv throughput: {combined:.1} GOPs (datapath)\n\
         paper: 437.976 GOPs under its OP accounting (see EXPERIMENTS.md on\n\
         the accounting difference); shape: B2/B3 conv blocks dominate,\n\
         B1/B4 are light\n",
        render_table(&["block / layer", "GOPs", ""], &rows)
    );
    (text, series, combined)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig20_eight_units_beats_2_and_4() {
        let (_, s) = fig20();
        let nu: std::collections::HashMap<usize, f64> = s.into_iter().collect();
        assert!(nu[&8] < nu[&4], "8 units: {} vs 4: {}", nu[&8], nu[&4]);
        assert!(nu[&8] < nu[&2]);
        // 16 has the best nu, matching the paper's observation...
        assert!(nu[&16] <= nu[&8]);
        // ...but only marginally: the knee is at 8 (why the paper ships 8)
        let gain_4_to_8 = nu[&4] - nu[&8];
        let gain_8_to_16 = nu[&8] - nu[&16];
        assert!(gain_4_to_8 > gain_8_to_16, "diminishing returns after 8");
    }

    #[test]
    fn fig21_shapes() {
        let (_, (vgg, rn)) = fig21();
        assert_eq!(vgg.len(), 13);
        assert_eq!(rn.len(), 17);
        // first layer lowest on both
        let vgg_min = vgg.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((vgg[0] - vgg_min).abs() < 1e-9, "VGG L1 lowest");
        let rn_min = rn.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((rn[0] - rn_min).abs() < 1e-9, "ResNet L1 lowest");
        // VGG series plateau near 8/9
        for u in &vgg[1..] {
            assert!((0.84..0.93).contains(u), "{u}");
        }
    }

    #[test]
    fn fig22_sf_flat_carla_linear() {
        let (_, s) = fig22();
        for (n, sf, ca) in s {
            assert_eq!(sf, 9);
            assert_eq!(ca, 3 * n);
        }
    }

    #[test]
    fn fig23_sf_advantage_constant() {
        let (_, s) = fig23();
        for (_, sc, so, cc, co) in s {
            let adv = (so as f64 / sc as f64) / (co as f64 / cc as f64);
            assert!(adv >= 8.0 - 1e-9, "SF delivers 8x outputs per window");
        }
    }

    #[test]
    fn fig24_gap_grows_with_parallelism() {
        let (_, s) = fig24();
        let vgg_ratio = s[0].3;
        let unet_ratio = s[2].3;
        assert!(s.iter().all(|r| r.3 > 1.0), "SF always faster");
        assert!(unet_ratio > vgg_ratio, "gap grows on the diffusion model");
    }

    #[test]
    fn fig25_conv_blocks_dominate() {
        let (_, series, combined) = fig25();
        assert!(combined > 10.0, "combined {combined} GOPs");
        let best = series
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(
            best.0.contains("B1+B2") || best.0.contains("B3+B4"),
            "a U-net block layer must dominate, got {}",
            best.0
        );
    }
}
