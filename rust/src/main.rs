//! `sf-mmcn` — the launcher.
//!
//! Subcommands:
//! * `run`       — map a model onto the accelerator (analytic) and print
//!                 per-layer cycles/utilization plus the PPA report.
//! * `simulate`  — run the cycle-accurate micro simulator (with real
//!                 fixed-point numerics) on a small model instance.
//! * `serve`     — diffusion de-noise serving demo over PJRT artifacts.
//! * `sweep`     — design-space sweep (units vs nu / power / latency).
//! * `report`    — regenerate a paper table/figure (table1..3, fig20..25).
//! * `artifacts` — list AOT artifacts.
//!
//! One hidden subcommand, `shard-worker`, is the child-process body of
//! multi-process cluster serving (`serve --cluster N`, ISSUE 10): it
//! wraps one serving session behind a Unix socket and is only ever
//! spawned by the cluster front door, never by hand.

use anyhow::{bail, Result};

use sf_mmcn::baselines::mmcn;
use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::config::{ModelChoice, RunConfig, ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    read_trace, workload, write_trace, AdmissionError, DiffusionServer, FaultSpec, ShardFleet,
    TraceRecord, TrafficProfile,
};
use sf_mmcn::models::{resnet18, unet, vgg16, ModelGraph, UnetConfig};
use sf_mmcn::report;
use sf_mmcn::runtime::ArtifactStore;
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::cli::Args;
use sf_mmcn::util::{Rng, Tensor};

const SUBCOMMANDS: &[&str] = &[
    "run",
    "simulate",
    "serve",
    "sweep",
    "report",
    "artifacts",
    "shard-worker",
];

const USAGE: &str = "\
sf-mmcn — Server-Flow Multi-Mode CNN / diffusion accelerator

USAGE: sf-mmcn <subcommand> [options]

  run       --model vgg16|resnet18|unet [--img 224] [--units 8]
            [--sparsity 0.45] [--config file.toml]
  simulate  --model unet [--img 16] [--units 8] [--seed 42]
  serve     [--steps 50] [--requests 8] [--workers 2] [--fused]
            [--backend pjrt|native] [--native] [--batched] [--no-batch]
            [--max-batch 4] [--chunk 0] [--no-pipeline] [--no-pool]
            [--resident] [--pin-lanes]
            [--queue-depth 64] [--deadline-ms 0] [--priorities 3]
            [--open-loop [--rate 8.0]] [--traffic \"ou:60:2:15\"]
            [--trace-out FILE] [--trace-in FILE] [--config file.toml]
            [--model-mix \"unet:2,resnet18:1,vgg16:1\"]
            [--shards 1] [--heartbeat-ms 25] [--heartbeat-misses 8]
            [--fault-spec \"kill:1:5;stall:0:3:40\"] [--fault-seed N]
            [--cluster 4] [--preempt-file FILE] [--monitor-pump-us 500]
  sweep     [--model resnet18] [--img 224]
  report    table1|table2|table3|fig20|fig21|fig22|fig23|fig24|fig25|
            headlines|all
  artifacts [--dir artifacts]
";

fn build_model(model: ModelChoice, img: usize) -> ModelGraph {
    match model {
        ModelChoice::Vgg16 => vgg16(img, 1000),
        ModelChoice::Resnet18 => resnet18(img, 1000),
        ModelChoice::Unet => unet(UnetConfig {
            img,
            ..UnetConfig::default()
        }),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = ModelChoice::parse(m)?;
    }
    cfg.img = args.get_usize("img", cfg.img)?;
    cfg.accel.units = args.get_usize("units", cfg.accel.units)?;
    cfg.sparsity = args.get_f64("sparsity", cfg.sparsity)?;

    let g = build_model(cfg.model, cfg.img);
    let a = analyze_graph(&cfg.accel, &g, cfg.sparsity);
    println!(
        "model {} @ {}  ({:.2} GMACs, {} nodes, {} parallel)",
        g.name,
        cfg.img,
        g.total_macs() as f64 / 1e9,
        g.nodes.len(),
        g.parallel_nodes()
    );
    println!("{:<6} {:<42} {:>12} {:>8}", "node", "layer", "cycles", "U_PE");
    for l in &a.layers {
        println!(
            "{:<6} {:<42} {:>12} {:>7.1}%",
            l.node_idx,
            l.label,
            l.cycles,
            l.u_pe * 100.0
        );
    }
    let rep = CAL_40NM.report(&a.totals, cfg.accel.units as u64);
    println!(
        "\ntotal: {} cycles  {:.3} ms @ {:.0} MHz",
        a.total_cycles(),
        rep.runtime_s * 1e3,
        rep.freq_hz / 1e6
    );
    println!(
        "PPA: {:.1} mW core ({:.1} mW with DRAM)  {:.1} GOPs  {:.2} kGOPs/W  \
         {:.2} mm2  {:.1} GOPs/mm2  U_PE {:.1}%  nu {:.4}",
        rep.core_power_w * 1e3,
        rep.total_power_w * 1e3,
        rep.gops,
        rep.gops_per_w / 1e3,
        rep.area_mm2,
        rep.gops_per_mm2,
        rep.u_pe * 100.0,
        rep.nu
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = ModelChoice::parse(args.get_or("model", "unet"))?;
    let img = args.get_usize("img", 16)?;
    let units = args.get_usize("units", 8)?;
    let seed = args.get_u64("seed", 42)?;
    if img > 64 {
        bail!("micro simulation is cycle-accurate; use --img <= 64 (or `run`)");
    }
    let g = build_model(model, img);
    let ws = WeightStore::random(&g, seed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let x = Tensor::from_fn(&[g.input.c, g.input.h, g.input.w], |_| rng.normal() * 0.5);
    let emb: Option<Vec<f32>> = if matches!(model, ModelChoice::Unet) {
        Some(
            (0..UnetConfig::default().time_dim)
                .map(|_| rng.normal() * 0.5)
                .collect(),
        )
    } else {
        None
    };
    let mut acc = Accelerator::new(AcceleratorConfig::with_units(units));
    let run = acc.run_graph(&g, &x, &ws, emb.as_deref())?;
    println!("micro-simulated {} @ {img} with {units} units", g.name);
    for l in &run.layers {
        println!(
            "{:<6} {:<42} {:>12} {:>7.1}%",
            l.node_idx,
            l.label,
            l.cycles,
            l.u_pe * 100.0
        );
    }
    let rep = CAL_40NM.report(&run.totals, units as u64);
    println!(
        "\ntotal {} cycles; output shape {:?}; output sparsity {:.2}; \
         {:.2} mW core",
        run.total_cycles(),
        run.output.shape(),
        run.output.sparsity(),
        rep.core_power_w * 1e3,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(std::path::Path::new(path))?,
        None => ServeConfig::default(),
    };
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.requests = args.get_usize("requests", cfg.requests)?;
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
    cfg.chunk = args.get_usize("chunk", cfg.chunk)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = ServeBackend::parse(b)?;
    }
    if args.flag("native") {
        cfg.backend = ServeBackend::Native;
    }
    if args.flag("fused") {
        cfg.fused = true;
    }
    if args.flag("batched") {
        cfg.batched = true;
    }
    if args.flag("no-batch") {
        cfg.batched = false;
    }
    if args.flag("no-pipeline") {
        cfg.pipeline = false;
    }
    if args.flag("no-pool") {
        // per-batch-allocating baseline (ISSUE 4 comparison mode)
        cfg.pooled = false;
    }
    if args.flag("resident") {
        // fused resident-x scan (ISSUE 9): whole timestep range in one
        // engine call, images hot in one slab; bit-identical to chunked
        cfg.resident = true;
    }
    if args.flag("pin-lanes") {
        // best-effort NUMA pinning of the worker lanes (ISSUE 9)
        cfg.pin_lanes = true;
    }
    cfg.queue_depth = args.get_usize("queue-depth", cfg.queue_depth)?;
    cfg.default_deadline_ms = args.get_u64("deadline-ms", cfg.default_deadline_ms)?;
    cfg.priorities = args.get_usize("priorities", cfg.priorities)?;
    if let Some(mix) = args.get("model-mix") {
        // multi-mode traffic (ISSUE 7): weighted U-net / ResNet-18 /
        // VGG-16 pattern, e.g. "unet:2,resnet18:1,vgg16:1"
        cfg.model_mix = mix.to_string();
    }
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    cfg.heartbeat_ms = args.get_u64("heartbeat-ms", cfg.heartbeat_ms)?;
    cfg.heartbeat_misses = args.get_u64("heartbeat-misses", cfg.heartbeat_misses)?;
    // multi-process cluster serving (ISSUE 10)
    cfg.cluster = args.get_usize("cluster", cfg.cluster)?;
    cfg.monitor_pump_us = args.get_u64("monitor-pump-us", cfg.monitor_pump_us)?;
    if let Some(path) = args.get("preempt-file") {
        // spot-interruption sentinel: when this file appears, drain the
        // shard/worker index it names (empty file = index 0)
        cfg.preempt_file = path.to_string();
    }
    if let Some(spec) = args.get("fault-spec") {
        cfg.fault_spec = spec.to_string();
    }
    let fault_seed = match args.get("fault-seed") {
        Some(_) => Some(args.get_u64("fault-seed", 0)?),
        None => None,
    };
    if let Some(spec) = args.get("traffic") {
        // arrival-process realism (ISSUE 8): OU / burst / ramp / sine
        // rate profiles, e.g. "ou:60:2:15"; implies --open-loop
        cfg.traffic = spec.to_string();
    }
    let trace_in = args.get("trace-in").map(std::path::PathBuf::from);
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);

    // The cluster front door (ISSUE 10): N worker *processes* behind
    // the wire protocol. Faults are injected by killing real processes
    // (see `tests/cluster_e2e.rs`), not by the in-process fault plane.
    if cfg.cluster > 0 {
        if !cfg.fault_spec.is_empty() || fault_seed.is_some() {
            bail!(
                "--fault-spec/--fault-seed drive the in-process fleet's fault plane; \
                 cluster workers fail by process death (kill the worker instead)"
            );
        }
        if args.flag("open-loop")
            || !cfg.traffic.is_empty()
            || trace_in.is_some()
            || trace_out.is_some()
        {
            bail!(
                "open-loop traffic (--open-loop/--traffic/--trace-in/--trace-out) serves a \
                 single session; drop it or use the cluster bench for open-loop cells"
            );
        }
        return cmd_serve_cluster(&cfg);
    }

    // The fleet front door (ISSUE 6): multiple shards, or any fault
    // injection, serve through ShardFleet so failures are survivable.
    if cfg.shards > 1 || !cfg.fault_spec.is_empty() || fault_seed.is_some() {
        if args.flag("open-loop")
            || !cfg.traffic.is_empty()
            || trace_in.is_some()
            || trace_out.is_some()
        {
            bail!(
                "open-loop traffic (--open-loop/--traffic/--trace-in/--trace-out) serves a \
                 single session; drop it or use the scale-sweep bench for fleet cells"
            );
        }
        return cmd_serve_fleet(&cfg, fault_seed);
    }

    if let Some(path) = trace_in {
        // Trace replay (ISSUE 8): the recorded file fixes both the
        // requests and their arrival offsets, so --traffic conflicts.
        if !cfg.traffic.is_empty() {
            bail!("--trace-in replays a recorded arrival schedule; drop --traffic");
        }
        return cmd_serve_replay(&cfg, &path, trace_out.as_deref());
    }

    if args.flag("open-loop") || !cfg.traffic.is_empty() || trace_out.is_some() {
        // Streaming session demo (ISSUE 5): requests arrive on a
        // synthetic schedule instead of being pre-staged; overload is
        // shed at the bounded admission queue instead of growing latency.
        let rate = args.get_f64("rate", 8.0)?;
        return cmd_serve_open_loop(&cfg, rate, trace_out.as_deref());
    }

    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;
    println!(
        "serving {} denoise requests ({} steps each) on {} workers, {} backend{}{} …",
        cfg.requests,
        cfg.steps,
        cfg.workers,
        cfg.backend.name(),
        if cfg.fused { " [fused scan]" } else { "" },
        if cfg.batched {
            " [batched + pipelined]"
        } else {
            ""
        }
    );
    if !cfg.model_mix.is_empty() {
        println!("model mix: {}", cfg.model_mix);
    }
    let reqs = workload(&cfg, cfg.seed, 0..cfg.requests);
    let (results, metrics) = server.serve(reqs)?;
    println!("{}", metrics.render());
    if let Some(rep) = metrics.sim_report(&CAL_40NM, 8) {
        println!(
            "co-simulated SF-MMCN: {} cycles  {:.3} ms @400 MHz  {:.1} mW core  \
             {:.1} GOPs  U_PE {:.1}%",
            rep.cycles,
            rep.runtime_s * 1e3,
            rep.core_power_w * 1e3,
            rep.gops,
            rep.u_pe * 100.0
        );
        // per-mode accelerator rows (ISSUE 7): the paper's area-efficiency
        // FoM (GOPs/mm²) for each mode's slice of the mixed traffic
        for row in metrics.per_model.iter().filter(|r| r.sim_counts.is_some()) {
            if let Some(mrep) = row.sim_report(&CAL_40NM, 8) {
                println!(
                    "  {}: {} cycles  {:.1} GOPs  {:.1} GOPs/mm2  U_PE {:.1}%",
                    row.model.name(),
                    mrep.cycles,
                    mrep.gops,
                    mrep.gops_per_mm2,
                    mrep.u_pe * 100.0
                );
            }
        }
    }
    if let Some(r) = results.first() {
        let mean: f32 = r.image.data.iter().sum::<f32>() / r.image.len() as f32;
        println!(
            "sample image: id {} shape {:?} mean {:.4}",
            r.id, r.image.shape, mean
        );
    }
    Ok(())
}

/// Open-loop streaming client (ISSUE 5, traffic profiles ISSUE 8):
/// submit `cfg.requests` requests on a synthetic arrival schedule —
/// `serve.traffic` / `--traffic` profile if set, else the legacy fixed
/// `--rate` interval (≡ `uniform:RATE`) — shedding overload at the
/// bounded admission queue, then drain gracefully and report the
/// live-session metrics (streaming latency percentiles included).
/// `--trace-out` records the exact `(arrival, request)` sequence to a
/// JSON-lines trace before serving starts.
fn cmd_serve_open_loop(
    cfg: &ServeConfig,
    rate: f64,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    use std::time::{Duration, Instant};

    if rate <= 0.0 || !rate.is_finite() {
        bail!("--rate must be a positive number of requests/s, got {rate}");
    }
    let profile = cfg
        .parsed_traffic()?
        .unwrap_or(TrafficProfile::Uniform { rate });
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;
    println!(
        "open-loop serving: {} requests arriving as `{}` (mean {:.1} req/s, {} steps each), \
         {} workers, queue depth {}, {} backend …",
        cfg.requests,
        profile.render(),
        profile.mean_rate(),
        cfg.steps,
        cfg.workers,
        cfg.queue_depth,
        cfg.backend.name(),
    );
    let reqs = workload(cfg, cfg.seed, 0..cfg.requests);
    // the synthetic arrival schedule: request i is due at arrivals[i] ns
    let arrivals = profile.schedule(cfg.seed, cfg.requests);
    if let Some(path) = trace_out {
        let records: Vec<TraceRecord> = arrivals
            .iter()
            .zip(&reqs)
            .map(|(&arrival_ns, r)| TraceRecord {
                arrival_ns,
                request: r.clone(),
            })
            .collect();
        write_trace(path, &records)?;
        println!("recorded {} arrivals to {}", records.len(), path.display());
    }
    let handle = server.start();
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let (mut shed, mut dead) = (0usize, 0usize);
    for (req, &due_ns) in reqs.into_iter().zip(&arrivals) {
        if let Some(sleep) = Duration::from_nanos(due_ns).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match handle.try_submit(req) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => shed += 1,
            Err(AdmissionError::Deadline) => dead += 1,
            // ShuttingDown / NoLiveShards: admission is over
            Err(_) => break,
        }
    }
    println!(
        "\nmid-session snapshot (arrivals done, queue draining):\n{}",
        handle.metrics_snapshot().render()
    );
    let (mut completed, mut failed) = (0usize, 0usize);
    for t in tickets {
        match t.wait() {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    let metrics = handle.shutdown()?;
    println!("final session metrics:\n{}", metrics.render());
    println!(
        "open-loop summary: {completed} completed, {failed} failed/expired, \
         {shed} shed at admission (QueueFull), {dead} rejected on deadline"
    );
    if let Some(rep) = metrics.sim_report(&CAL_40NM, 8) {
        println!(
            "co-simulated SF-MMCN: {} cycles  {:.3} ms @400 MHz  {:.1} mW core",
            rep.cycles,
            rep.runtime_s * 1e3,
            rep.core_power_w * 1e3,
        );
    }
    Ok(())
}

/// Trace replay (ISSUE 8): submit exactly the recorded requests at
/// their recorded arrival offsets through a single session. Request
/// execution is a pure function of `(model, seed, steps)`, so the
/// replayed results are bit-identical to the recording run's.
/// `--trace-out` re-emits the canonical rendering of the parsed trace
/// (useful for normalizing a hand-edited file).
fn cmd_serve_replay(
    cfg: &ServeConfig,
    path: &std::path::Path,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    use std::time::{Duration, Instant};

    let records = read_trace(path)?;
    if records.is_empty() {
        bail!("trace {} holds no records", path.display());
    }
    if let Some(out) = trace_out {
        write_trace(out, &records)?;
        println!("re-emitted {} records to {}", records.len(), out.display());
    }
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;
    println!(
        "replaying {} recorded requests from {} ({} workers, queue depth {}, {} backend) …",
        records.len(),
        path.display(),
        cfg.workers,
        cfg.queue_depth,
        cfg.backend.name(),
    );
    let handle = server.start();
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let (mut shed, mut dead) = (0usize, 0usize);
    for rec in records {
        if let Some(sleep) = Duration::from_nanos(rec.arrival_ns).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match handle.try_submit(rec.request) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => shed += 1,
            Err(AdmissionError::Deadline) => dead += 1,
            // ShuttingDown / NoLiveShards: admission is over
            Err(_) => break,
        }
    }
    let (mut completed, mut failed) = (0usize, 0usize);
    for t in tickets {
        match t.wait() {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    let metrics = handle.shutdown()?;
    println!("final session metrics:\n{}", metrics.render());
    println!(
        "replay summary: {completed} completed, {failed} failed/expired, \
         {shed} shed at admission (QueueFull), {dead} rejected on deadline"
    );
    Ok(())
}

/// Fleet serving demo (ISSUE 6): shard the session, inject the requested
/// faults, and let failover deliver the full workload anyway. The fault
/// schedule comes from `--fault-spec` (literal) or `--fault-seed`
/// (canonical seeded kill-one-shard scenario); either way the printed
/// spec replays the exact run.
fn cmd_serve_fleet(cfg: &ServeConfig, fault_seed: Option<u64>) -> Result<()> {
    let store = ArtifactStore::default_store();
    let spec = match fault_seed {
        Some(seed) => FaultSpec::seeded_kill(seed, cfg.shards, cfg.requests as u64),
        None => FaultSpec::parse(&cfg.fault_spec)?,
    };
    println!(
        "fleet serving: {} requests ({} steps each) over {} shards × {} workers, {} backend …",
        cfg.requests,
        cfg.steps,
        cfg.shards,
        cfg.workers,
        cfg.backend.name(),
    );
    if !spec.is_empty() {
        println!("fault plane: {}", spec.render());
    }
    let fleet = ShardFleet::start_with_spec(cfg.clone(), &store, spec)?;
    let mut tickets = Vec::new();
    for req in workload(cfg, cfg.seed, 0..cfg.requests) {
        match fleet.submit(req) {
            Ok(t) => tickets.push(t),
            Err(e) => println!("request rejected at the front door: {e}"),
        }
    }
    let (mut delivered, mut failed) = (0usize, 0usize);
    for t in tickets {
        match t.wait() {
            Ok(_) => delivered += 1,
            Err(e) => {
                failed += 1;
                eprintln!("{e}");
            }
        }
    }
    let metrics = fleet.shutdown()?;
    println!("{}", metrics.render());
    println!("fleet summary: {delivered} delivered, {failed} failed");
    Ok(())
}

/// Cluster serving demo (ISSUE 10): spawn `cfg.cluster` worker
/// *processes* of this binary (hidden `shard-worker` subcommand), route
/// the workload across them over the wire protocol, and report the
/// merged fleet metrics. Same determinism contract as the in-process
/// fleet: a worker process dying mid-run loses nothing.
#[cfg(unix)]
fn cmd_serve_cluster(cfg: &ServeConfig) -> Result<()> {
    use sf_mmcn::coordinator::ClusterFleet;

    let exe = std::env::current_exe()?;
    println!(
        "cluster serving: {} requests ({} steps each) over {} worker processes × {} lanes, \
         {} backend …",
        cfg.requests,
        cfg.steps,
        cfg.cluster,
        cfg.workers,
        cfg.backend.name(),
    );
    let cluster = ClusterFleet::start(cfg.clone(), &exe)?;
    let mut tickets = Vec::new();
    for req in workload(cfg, cfg.seed, 0..cfg.requests) {
        match cluster.submit(req) {
            Ok(t) => tickets.push(t),
            Err(e) => println!("request rejected at the front door: {e}"),
        }
    }
    let (mut delivered, mut failed) = (0usize, 0usize);
    for t in tickets {
        match t.wait() {
            Ok(_) => delivered += 1,
            Err(e) => {
                failed += 1;
                eprintln!("{e}");
            }
        }
    }
    let metrics = cluster.shutdown()?;
    println!("{}", metrics.render());
    println!("cluster summary: {delivered} delivered, {failed} failed");
    Ok(())
}

#[cfg(not(unix))]
fn cmd_serve_cluster(_cfg: &ServeConfig) -> Result<()> {
    bail!("--cluster needs Unix domain sockets; this platform has none")
}

/// Hidden subcommand: the body of one cluster worker process. Spawned
/// by the cluster front door with `--config <toml> --socket <path>
/// --worker <slot>`; never invoked by hand.
#[cfg(unix)]
fn cmd_shard_worker(args: &Args) -> Result<()> {
    use sf_mmcn::coordinator::proc::run_worker;

    let Some(config) = args.get("config") else {
        bail!("shard-worker needs --config <worker.toml>");
    };
    let Some(socket) = args.get("socket") else {
        bail!("shard-worker needs --socket <path>");
    };
    let worker = args.get_usize("worker", 0)?;
    let cfg = ServeConfig::from_file(std::path::Path::new(config))?;
    run_worker(&cfg, std::path::Path::new(socket), worker)
}

#[cfg(not(unix))]
fn cmd_shard_worker(_args: &Args) -> Result<()> {
    bail!("shard-worker needs Unix domain sockets; this platform has none")
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = ModelChoice::parse(args.get_or("model", "resnet18"))?;
    let img = args.get_usize("img", 224)?;
    let g = build_model(model, img);
    println!(
        "{:<6} {:>12} {:>10} {:>10} {:>8} {:>8}",
        "units", "cycles", "mW", "GOPs", "U_PE", "nu"
    );
    for units in [1usize, 2, 4, 8, 16, 32] {
        let cfg = AcceleratorConfig::with_units(units);
        let a = analyze_graph(&cfg, &g, 0.45);
        let rep = CAL_40NM.report(&a.totals, units as u64);
        println!(
            "{:<6} {:>12} {:>10.1} {:>10.1} {:>7.1}% {:>8.4}",
            units,
            a.total_cycles(),
            rep.core_power_w * 1e3,
            rep.gops,
            rep.u_pe * 100.0,
            rep.nu
        );
    }
    let mm = mmcn::analyze_graph(&g, 0.45);
    println!(
        "mmcn   {:>12}   (series strategy, no reuse)",
        mm.counts.cycles
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let img = args.get_usize("img", 224)?;
    let mut emitted = false;
    let want = |k: &str| what == k || what == "all";
    if want("table1") {
        println!("{}", report::table1(img).0);
        emitted = true;
    }
    if want("table2") {
        println!("{}", report::table2().0);
        emitted = true;
    }
    if want("table3") {
        println!("{}", report::table3().0);
        emitted = true;
    }
    if want("headlines") {
        println!("{}", report::headline_ratios(img).0);
        emitted = true;
    }
    if want("fig20") {
        println!("{}", report::fig20().0);
        emitted = true;
    }
    if want("fig21") {
        println!("{}", report::fig21().0);
        emitted = true;
    }
    if want("fig22") {
        println!("{}", report::fig22().0);
        emitted = true;
    }
    if want("fig23") {
        println!("{}", report::fig23().0);
        emitted = true;
    }
    if want("fig24") {
        println!("{}", report::fig24().0);
        emitted = true;
    }
    if want("fig25") {
        println!("{}", report::fig25().0);
        emitted = true;
    }
    if !emitted {
        bail!("unknown report `{what}` — see `sf-mmcn` usage");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_or("dir", "artifacts");
    let store = ArtifactStore::new(dir);
    let list = store.list()?;
    if list.is_empty() {
        println!("no artifacts in {dir} — run `make artifacts`");
        return Ok(());
    }
    for a in list {
        let size = std::fs::metadata(&a.path).map(|m| m.len()).unwrap_or(0);
        println!("{:<24} {:>10} bytes  {}", a.name, size, a.path.display());
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(SUBCOMMANDS)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("report") => cmd_report(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("shard-worker") => cmd_shard_worker(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
