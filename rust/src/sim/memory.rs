//! Memory-hierarchy traffic accounting: off-chip DRAM, on-chip input /
//! weight / output SRAM buffers (paper Fig 18).
//!
//! The paper (citing [19]) notes that "data transmission between core and
//! memories has the most power of a chip" — the SF data-reuse registers
//! exist precisely to cut buffer traffic, and the serialized-parallel
//! strategies differ mainly in DRAM refetches. So the simulator tracks
//! every element moved at each level; the energy model prices them.

/// Traffic counters in *elements* (one element = one 16-bit word).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// Elements read from off-chip DRAM.
    pub dram_reads: u64,
    /// Elements written to off-chip DRAM.
    pub dram_writes: u64,
    /// Elements read from the on-chip input buffer.
    pub input_buf_reads: u64,
    /// Elements written into the on-chip input buffer (fills from DRAM).
    pub input_buf_writes: u64,
    /// Elements read from the on-chip weight buffer.
    pub weight_buf_reads: u64,
    /// Elements written into the on-chip weight buffer.
    pub weight_buf_writes: u64,
    /// Elements written to the output buffer.
    pub output_buf_writes: u64,
    /// Elements read back from the output buffer (e.g. residual skip reads).
    pub output_buf_reads: u64,
}

impl MemoryStats {
    pub fn merge(&mut self, o: &MemoryStats) {
        self.dram_reads += o.dram_reads;
        self.dram_writes += o.dram_writes;
        self.input_buf_reads += o.input_buf_reads;
        self.input_buf_writes += o.input_buf_writes;
        self.weight_buf_reads += o.weight_buf_reads;
        self.weight_buf_writes += o.weight_buf_writes;
        self.output_buf_writes += o.output_buf_writes;
        self.output_buf_reads += o.output_buf_reads;
    }

    /// Traffic accumulated since the `before` snapshot — the per-layer
    /// delta the simulator folds into each [`super::energy::EventCounts`]
    /// (§Perf: one struct-level diff instead of eight call-site
    /// subtractions on the layer loop).
    pub fn since(&self, before: &MemoryStats) -> MemoryStats {
        MemoryStats {
            dram_reads: self.dram_reads - before.dram_reads,
            dram_writes: self.dram_writes - before.dram_writes,
            input_buf_reads: self.input_buf_reads - before.input_buf_reads,
            input_buf_writes: self.input_buf_writes - before.input_buf_writes,
            weight_buf_reads: self.weight_buf_reads - before.weight_buf_reads,
            weight_buf_writes: self.weight_buf_writes - before.weight_buf_writes,
            output_buf_writes: self.output_buf_writes - before.output_buf_writes,
            output_buf_reads: self.output_buf_reads - before.output_buf_reads,
        }
    }

    /// Total off-chip traffic in elements.
    pub fn dram_traffic(&self) -> u64 {
        self.dram_reads + self.dram_writes
    }

    /// Total on-chip buffer traffic in elements.
    pub fn buffer_traffic(&self) -> u64 {
        self.input_buf_reads
            + self.input_buf_writes
            + self.weight_buf_reads
            + self.weight_buf_writes
            + self.output_buf_writes
            + self.output_buf_reads
    }
}

/// Double-buffered on-chip memory system with capacity-driven refetch.
///
/// Layer inputs that fit in the input buffer are fetched from DRAM once and
/// re-read from SRAM on every output-channel iteration; inputs that do
/// not fit are re-streamed from DRAM each iteration (the behaviour that
/// makes reuse-less designs like MMCN expensive on big parallel layers).
#[derive(Debug, Clone)]
pub struct MemorySystem {
    /// Input buffer capacity in elements.
    pub input_buf_capacity: u64,
    /// Weight buffer capacity in elements.
    pub weight_buf_capacity: u64,
    pub stats: MemoryStats,
}

impl MemorySystem {
    pub fn new(input_buf_capacity: u64, weight_buf_capacity: u64) -> Self {
        Self {
            input_buf_capacity,
            weight_buf_capacity,
            stats: MemoryStats::default(),
        }
    }

    /// Account for streaming a layer's input feature map.
    ///
    /// * `ifm_elems` — input feature-map size.
    /// * `iterations` — output-channel iterations that each need the IFM.
    /// * `core_reads` — reads the compute core actually issued against the
    ///   input buffer (already reuse-reduced by the SF registers).
    pub fn stream_input(&mut self, ifm_elems: u64, iterations: u64, core_reads: u64) {
        if ifm_elems <= self.input_buf_capacity {
            // Fits: one DRAM fill, SRAM serves every iteration.
            self.stats.dram_reads += ifm_elems;
            self.stats.input_buf_writes += ifm_elems;
        } else {
            // Doesn't fit: re-stream from DRAM per iteration.
            self.stats.dram_reads += ifm_elems * iterations;
            self.stats.input_buf_writes += ifm_elems * iterations;
        }
        self.stats.input_buf_reads += core_reads;
    }

    /// Account for a layer's weights (always DRAM -> weight buffer once;
    /// weights are stationary per output-channel iteration).
    pub fn stream_weights(&mut self, w_elems: u64, core_reads: u64) {
        if w_elems <= self.weight_buf_capacity {
            self.stats.dram_reads += w_elems;
            self.stats.weight_buf_writes += w_elems;
        } else {
            // Spill: stream in two passes (ping-pong) — still one DRAM read
            // per element, but double the buffer writes.
            self.stats.dram_reads += w_elems;
            self.stats.weight_buf_writes += 2 * w_elems;
        }
        self.stats.weight_buf_reads += core_reads;
    }

    /// Account for writing a layer's outputs. `spill_to_dram` is true when
    /// the next consumer cannot keep them on-chip (e.g. final layer or a
    /// skip connection crossing many layers).
    pub fn write_output(&mut self, ofm_elems: u64, spill_to_dram: bool) {
        self.stats.output_buf_writes += ofm_elems;
        if spill_to_dram {
            self.stats.dram_writes += ofm_elems;
        }
    }

    /// Account for reading a residual skip branch from the output buffer
    /// (the SF path) — the traffic PE_9 serves.
    pub fn read_skip(&mut self, elems: u64) {
        self.stats.output_buf_reads += elems;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitting_input_fetched_once() {
        let mut m = MemorySystem::new(10_000, 10_000);
        m.stream_input(5_000, 4, 1_000);
        assert_eq!(m.stats.dram_reads, 5_000);
        assert_eq!(m.stats.input_buf_writes, 5_000);
        assert_eq!(m.stats.input_buf_reads, 1_000);
    }

    #[test]
    fn oversized_input_refetched_per_iteration() {
        let mut m = MemorySystem::new(1_000, 10_000);
        m.stream_input(5_000, 4, 2_000);
        assert_eq!(m.stats.dram_reads, 20_000);
    }

    #[test]
    fn weights_one_dram_pass_even_on_spill() {
        let mut m = MemorySystem::new(0, 100);
        m.stream_weights(1_000, 500);
        assert_eq!(m.stats.dram_reads, 1_000);
        assert_eq!(m.stats.weight_buf_writes, 2_000);
    }

    #[test]
    fn output_spill_hits_dram() {
        let mut m = MemorySystem::new(0, 0);
        m.write_output(100, false);
        assert_eq!(m.stats.dram_writes, 0);
        m.write_output(100, true);
        assert_eq!(m.stats.dram_writes, 100);
        assert_eq!(m.stats.output_buf_writes, 200);
    }

    #[test]
    fn since_diffs_every_field() {
        let mut m = MemorySystem::new(10_000, 10_000);
        m.stream_input(100, 1, 10);
        let before = m.stats;
        m.stream_weights(50, 5);
        m.write_output(20, true);
        m.read_skip(7);
        let d = m.stats.since(&before);
        assert_eq!(d.dram_reads, 50);
        assert_eq!(d.weight_buf_writes, 50);
        assert_eq!(d.weight_buf_reads, 5);
        assert_eq!(d.output_buf_writes, 20);
        assert_eq!(d.dram_writes, 20);
        assert_eq!(d.output_buf_reads, 7);
        assert_eq!(d.input_buf_reads, 0);
        assert_eq!(d.input_buf_writes, 0);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = MemoryStats {
            dram_reads: 1,
            dram_writes: 2,
            input_buf_reads: 3,
            input_buf_writes: 4,
            weight_buf_reads: 5,
            weight_buf_writes: 6,
            output_buf_writes: 7,
            output_buf_reads: 8,
        };
        a.merge(&a.clone());
        assert_eq!(a.dram_traffic(), 6);
        assert_eq!(a.buffer_traffic(), 66);
    }
}
