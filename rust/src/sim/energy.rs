//! Event-energy + area model, calibrated to the paper's TSMC 40 nm numbers.
//!
//! The paper evaluates silicon (Design Compiler synthesis + Innovus P&R).
//! We cannot tape out, so every architecture simulated in this crate is
//! priced by the *same* event-energy model below; the paper's headline
//! claims are ratios between architectures, and ratios survive this
//! substitution (see DESIGN.md §1).
//!
//! Calibration targets (Table I, "This work" column):
//! 40 nm, 400 MHz, 72 PEs, 16-bit — core power ~= 18 mW, area ~= 1.9 mm²,
//! nu ~= 0.02. Sanity: 18 mW / 400 MHz = 45 pJ per cycle for the whole
//! core; with 72 MACs/cycle that implies ~0.45 pJ/MAC + buffers + control,
//! which is squarely in the published range for 16-bit MACs at 40 nm.
//!
//! nu (eq. 4) is defined as `P_total [W] / U_PE [fraction]`: this is the
//! only reading consistent with every ratio in Table I (SF-MMCN:
//! 0.018 W / 0.90 = 0.02; CARLA: 0.247 W / 0.003 = 82.3).

use super::memory::MemoryStats;
use super::pe::PeStats;
use super::unit::UnitStats;

/// Per-event energies (picojoules) and per-block areas (mm²).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Technology label for reports.
    pub tech: &'static str,
    /// Clock frequency in Hz.
    pub freq_hz: f64,
    // --- event energies, pJ ---
    /// One 16x16-bit MAC (multiplier + accumulator update).
    pub e_mac: f64,
    /// A zero-gated MAC slot (clock + zero-detect only).
    pub e_gated_mac: f64,
    /// Residual adder firing.
    pub e_resadd: f64,
    /// PE output-register writeback.
    pub e_writeback: f64,
    /// 32-bit reuse-register write.
    pub e_reuse_reg: f64,
    /// One element (16-bit) read/written at an on-chip SRAM buffer.
    pub e_sram: f64,
    /// One element (16-bit) moved to/from off-chip DRAM.
    pub e_dram: f64,
    /// Per-unit control overhead per active cycle.
    pub e_unit_ctrl: f64,
    /// Top-controller overhead per cycle.
    pub e_top_ctrl: f64,
    /// Idle PE per cycle when fine-grained clock gating exists (the
    /// SF-MMCN zero-gate/mode-gate path).
    pub e_pe_idle: f64,
    /// Idle PE per cycle *without* fine-grained gating — the clock tree
    /// still toggles the PE's registers (traditional arrays like CARLA's
    /// row-stationary design or a dense PE array).
    pub e_pe_idle_ungated: f64,
    /// Static leakage for the whole core, per cycle.
    pub e_leak_cycle: f64,
    // --- areas, mm² ---
    /// One PE (MAC + pipeline counter + zero gate + residual adder + regs).
    pub a_pe: f64,
    /// Per-unit overhead (server bus, mode muxes, reuse registers).
    pub a_unit_overhead: f64,
    /// Buffers + pooling + activation + top control, per design.
    pub a_periphery: f64,
}

/// TSMC 40 nm @ 400 MHz calibration (Table I operating point).
pub const CAL_40NM: EnergyModel = EnergyModel {
    tech: "40nm",
    freq_hz: 400e6,
    e_mac: 0.38,
    e_gated_mac: 0.04,
    e_resadd: 0.08,
    e_writeback: 0.10,
    e_reuse_reg: 0.08,
    e_sram: 0.35,
    e_dram: 160.0,
    e_unit_ctrl: 0.30,
    e_top_ctrl: 1.00,
    e_pe_idle: 0.02,
    e_pe_idle_ungated: 0.25,
    e_leak_cycle: 1.20,
    a_pe: 0.0125,
    a_unit_overhead: 0.022,
    a_periphery: 0.82,
};

/// TSMC 40 nm @ 200 MHz, 0.9 V post-layout point (Table III). Same event
/// energies; lower frequency and post-layout density (the paper's Table
/// III reports a 0.39 mm² placed core vs Table I's 1.9 mm² synthesis
/// estimate — we carry both operating points).
pub const CAL_40NM_LAYOUT: EnergyModel = EnergyModel {
    freq_hz: 200e6,
    a_pe: 0.0042,
    a_unit_overhead: 0.008,
    a_periphery: 0.075,
    ..CAL_40NM
};

/// Aggregated event counts for one run (any simulated architecture).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventCounts {
    /// Wall-clock cycles of the run.
    pub cycles: u64,
    /// PEs instantiated in the design (for U_PE and idle pricing).
    pub total_pes: u64,
    /// True when the design lacks fine-grained clock gating of idle PEs
    /// (traditional arrays); idle PEs then cost `e_pe_idle_ungated`.
    pub coarse_idle: bool,
    pub pe: PeStats,
    pub unit: UnitStats,
    pub mem: MemoryStats,
}

impl EventCounts {
    pub fn merge_run(&mut self, o: &EventCounts) {
        // Sequential composition: cycles add, design size must match.
        assert_eq!(self.total_pes, o.total_pes, "merging different designs");
        self.cycles += o.cycles;
        self.pe.merge(&o.pe);
        self.unit.merge(&o.unit);
        self.mem.merge(&o.mem);
    }

    /// Accumulate one layer's counters into a graph total — same design
    /// by construction, so no size check (§Perf: one call per layer on
    /// the simulator hot path instead of four separate merges).
    pub fn accumulate(&mut self, o: &EventCounts) {
        self.cycles += o.cycles;
        self.pe.merge(&o.pe);
        self.unit.merge(&o.unit);
        self.mem.merge(&o.mem);
    }

    /// Utilization of PEs (paper eqs. 1-2) as a fraction in [0, 1]:
    /// active PE-cycles over total PE-cycles.
    pub fn u_pe(&self) -> f64 {
        if self.cycles == 0 || self.total_pes == 0 {
            return 0.0;
        }
        self.pe.active_cycles as f64 / (self.cycles as f64 * self.total_pes as f64)
    }

    /// MAC operations including zero-gated slots — the *model's* MACs
    /// (gating saves energy, not work).
    pub fn model_macs(&self) -> u64 {
        self.pe.mac_slots()
    }
}

/// Power/performance/area report for one run under one energy model.
#[derive(Debug, Clone)]
pub struct PpaReport {
    pub tech: &'static str,
    pub freq_hz: f64,
    pub cycles: u64,
    pub runtime_s: f64,
    /// Core energy (datapath + buffers + control + leakage), joules.
    pub core_energy_j: f64,
    /// Off-chip DRAM energy, joules (reported separately: the paper's
    /// "Power (mW)" rows are core power).
    pub dram_energy_j: f64,
    pub core_power_w: f64,
    pub total_power_w: f64,
    /// Giga-ops (1 MAC = 2 ops) per second, from model MACs over runtime.
    pub gops: f64,
    pub gops_per_w: f64,
    pub area_mm2: f64,
    pub gops_per_mm2: f64,
    /// PE utilization, fraction.
    pub u_pe: f64,
    /// Efficiency factor nu = P_total[W] / U_PE (paper eq. 4).
    pub nu: f64,
}

impl EnergyModel {
    /// Area of a design with `units` server-flow units of `pes_per_unit`
    /// PEs (baselines pass their own organisations through here too).
    pub fn area_mm2(&self, total_pes: u64, units: u64) -> f64 {
        self.a_pe * total_pes as f64
            + self.a_unit_overhead * units as f64
            + self.a_periphery
    }

    /// Core energy (pJ) for the given counts — everything but DRAM.
    pub fn core_energy_pj(&self, c: &EventCounts) -> f64 {
        let pe = &c.pe;
        let u = &c.unit;
        let m = &c.mem;
        let idle_pe_cycles = pe.idle_cycles as f64
            + (c.total_pes as f64 * c.cycles as f64 - pe.active_cycles as f64 - pe.idle_cycles as f64)
                .max(0.0); // PEs outside any group are also idle-clocked
        let e_idle = if c.coarse_idle {
            self.e_pe_idle_ungated
        } else {
            self.e_pe_idle
        };
        pe.macs as f64 * self.e_mac
            + pe.gated_macs as f64 * self.e_gated_mac
            + pe.residual_adds as f64 * self.e_resadd
            + pe.writebacks as f64 * self.e_writeback
            + u.reuse_reg_writes as f64 * self.e_reuse_reg
            // core-issued SRAM reads (input taps + weight broadcasts) plus
            // the memory system's fills/spills
            + (u.buffer_reads + u.weight_reads) as f64 * self.e_sram
            + (m.buffer_traffic() as f64) * self.e_sram
            + idle_pe_cycles * e_idle
            + u.cycles as f64 * self.e_unit_ctrl
            + c.cycles as f64 * (self.e_top_ctrl + self.e_leak_cycle)
    }

    /// DRAM energy (pJ).
    pub fn dram_energy_pj(&self, c: &EventCounts) -> f64 {
        c.mem.dram_traffic() as f64 * self.e_dram
    }

    /// Build the full PPA report for a run.
    pub fn report(&self, c: &EventCounts, units: u64) -> PpaReport {
        let runtime_s = c.cycles as f64 / self.freq_hz;
        let core_pj = self.core_energy_pj(c);
        let dram_pj = self.dram_energy_pj(c);
        let core_energy_j = core_pj * 1e-12;
        let dram_energy_j = dram_pj * 1e-12;
        let core_power_w = if runtime_s > 0.0 {
            core_energy_j / runtime_s
        } else {
            0.0
        };
        let total_power_w = if runtime_s > 0.0 {
            (core_energy_j + dram_energy_j) / runtime_s
        } else {
            0.0
        };
        let ops = 2.0 * c.model_macs() as f64;
        let gops = if runtime_s > 0.0 {
            ops / runtime_s / 1e9
        } else {
            0.0
        };
        let area = self.area_mm2(c.total_pes, units);
        let u_pe = c.u_pe();
        PpaReport {
            tech: self.tech,
            freq_hz: self.freq_hz,
            cycles: c.cycles,
            runtime_s,
            core_energy_j,
            dram_energy_j,
            core_power_w,
            total_power_w,
            gops,
            gops_per_w: if core_power_w > 0.0 { gops / core_power_w } else { 0.0 },
            area_mm2: area,
            gops_per_mm2: if area > 0.0 { gops / area } else { 0.0 },
            u_pe,
            nu: if u_pe > 0.0 { core_power_w / u_pe } else { f64::INFINITY },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic fully-busy run: 72 PEs MAC-ing every cycle.
    fn busy_counts(cycles: u64) -> EventCounts {
        let mut c = EventCounts {
            cycles,
            total_pes: 72,
            ..Default::default()
        };
        c.pe.active_cycles = 72 * cycles;
        c.pe.macs = 72 * cycles;
        c.pe.writebacks = 8 * cycles / 9 * 8;
        // reuse-reduced buffer traffic: ~3.33 reads/cycle/unit x 8 units
        c.mem.input_buf_reads = cycles * 27;
        c.mem.weight_buf_reads = cycles * 8;
        c.unit.cycles = 8 * cycles;
        c
    }

    #[test]
    fn calibrated_core_power_near_18mw() {
        let c = busy_counts(1_000_000);
        let r = CAL_40NM.report(&c, 8);
        let mw = r.core_power_w * 1e3;
        assert!(
            (14.0..=22.0).contains(&mw),
            "core power {mw} mW out of the Table-I band"
        );
    }

    #[test]
    fn calibrated_area_near_1_9mm2() {
        let a = CAL_40NM.area_mm2(72, 8);
        assert!((1.7..=2.1).contains(&a), "area {a} mm²");
    }

    #[test]
    fn u_pe_full_when_all_pes_always_active() {
        let c = busy_counts(1000);
        assert!((c.u_pe() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nu_matches_paper_magnitude() {
        let c = busy_counts(1_000_000);
        let r = CAL_40NM.report(&c, 8);
        // paper: nu = 0.02 at 18 mW / 0.9 utilization
        assert!(r.nu > 0.005 && r.nu < 0.05, "nu = {}", r.nu);
    }

    #[test]
    fn gops_counts_two_ops_per_mac() {
        let c = busy_counts(400_000_000); // one second at 400 MHz
        let r = CAL_40NM.report(&c, 8);
        // 72 MACs/cycle * 2 ops * 400 MHz = 57.6 GOPs
        assert!((r.gops - 57.6).abs() < 0.1, "gops = {}", r.gops);
    }

    #[test]
    fn dram_separated_from_core() {
        let mut c = busy_counts(1000);
        c.mem.dram_reads = 1_000_000;
        let r = CAL_40NM.report(&c, 8);
        assert!(r.total_power_w > r.core_power_w);
        assert!(r.dram_energy_j > 0.0);
    }

    #[test]
    fn zero_cycle_run_is_safe() {
        let c = EventCounts {
            total_pes: 72,
            ..Default::default()
        };
        let r = CAL_40NM.report(&c, 8);
        assert_eq!(r.gops, 0.0);
        assert_eq!(r.core_power_w, 0.0);
    }

    #[test]
    fn gating_saves_energy() {
        let dense = busy_counts(100_000);
        let mut sparse = busy_counts(100_000);
        // move half the MACs to gated slots
        sparse.pe.macs /= 2;
        sparse.pe.gated_macs = dense.pe.macs / 2;
        let ed = CAL_40NM.core_energy_pj(&dense);
        let es = CAL_40NM.core_energy_pj(&sparse);
        assert!(es < ed * 0.85, "gating should cut energy: {es} vs {ed}");
    }
}
