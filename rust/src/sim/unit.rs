//! One SF-MMCN unit: PE_1..PE_8 plus the PE_9 *server* (paper Figs 5-6).
//!
//! The unit's whole point is that a parallel branch (residual skip, 1x1
//! residual conv, or the U-net time-parameter dense layer) completes in the
//! *same cycles* as the main convolution, because PE_9 prepares/serves the
//! branch value while PE_1..PE_8 run their MAC pipelines:
//!
//! * **Series** (Fig 6a): PE_9 clock-gated; outputs bypass the residual
//!   adder. Plain conv: 8 outputs per `taps` cycles.
//! * **ResidualIdentity** (Fig 6b): PE_9 streams the previous conv outputs
//!   (the skip branch) from its registers to the adders of PE_1..PE_8.
//! * **ResidualConv** (Fig 6c): PE_9 *computes* the 1x1 residual conv with
//!   its own MAC and serves the result. A 1x1xC filter is at most C taps
//!   against the main conv's 9C, so PE_9 always finishes in time — the
//!   synchronization argument of §III.C.
//! * **DenseTime** (Figs 14-16): PE_9 runs time-embedding dense MACs while
//!   PE_1..PE_8 convolve — the U-net block-1/block-2 overlap.
//! * **Small-input split** (Figs 11-12): for tiny feature maps the PE array
//!   splits into two 4-PE channel groups; PE_9 serves channel N during the
//!   first half-taps and channel N+1 during the second.
//!
//! Data-reuse registers (Fig 17): 8 x 32-bit registers hold the input
//! values shared between the overlapping windows of the 8 PEs (upper
//! 16 bits are free to hold the residual value in residual mode). The unit
//! counts buffer reads with and without reuse so the memory/energy model
//! can price the saving.

use crate::quant::Fixed;

use super::pe::{dot_wide, Pe, PeMode, PeStats};

/// Number of worker PEs per unit (PE_1..PE_8).
pub const WORKERS: usize = 8;
/// Total PEs per unit including the PE_9 server.
pub const PES_PER_UNIT: usize = WORKERS + 1;

/// Server-flow operating mode for a convolution group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitMode {
    /// Plain series convolution; PE_9 idle.
    Series,
    /// Residual block without conv on the skip: PE_9 serves stored values.
    ResidualIdentity,
    /// Residual block with a 1x1 conv on the skip: PE_9 computes it.
    ResidualConv,
    /// U-net block: PE_9 computes time-parameter dense MACs concurrently.
    DenseTime,
}

/// Counters for one unit (beyond the per-PE stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UnitStats {
    /// Total cycles the unit spent executing groups.
    pub cycles: u64,
    /// Convolution outputs produced by PE_1..PE_8.
    pub conv_outputs: u64,
    /// Values served by PE_9 over the server bus.
    pub served_values: u64,
    /// Input-buffer reads actually issued (with reuse registers).
    pub buffer_reads: u64,
    /// Input-buffer reads a reuse-less design would have issued.
    pub buffer_reads_no_reuse: u64,
    /// Weight-buffer reads (weights broadcast once per tap to all PEs).
    pub weight_reads: u64,
    /// Writes of reused values into the 32-bit reuse registers.
    pub reuse_reg_writes: u64,
}

impl UnitStats {
    pub fn merge(&mut self, o: &UnitStats) {
        self.cycles += o.cycles;
        self.conv_outputs += o.conv_outputs;
        self.served_values += o.served_values;
        self.buffer_reads += o.buffer_reads;
        self.buffer_reads_no_reuse += o.buffer_reads_no_reuse;
        self.weight_reads += o.weight_reads;
        self.reuse_reg_writes += o.reuse_reg_writes;
    }

    /// Buffer reads avoided by the reuse registers.
    pub fn reads_saved(&self) -> u64 {
        self.buffer_reads_no_reuse - self.buffer_reads
    }
}

/// What PE_9 serves during a group.
#[derive(Debug, Clone)]
pub enum ServerTask<'a> {
    /// Nothing (series mode) — PE_9 clock-gated.
    Idle,
    /// Serve these skip-branch values (one per worker output).
    ServeIdentity(&'a [Fixed]),
    /// Compute a 1x1(xC) residual conv per worker output: for output `i`,
    /// `windows[i]` dot `weights` — then serve it.
    ServeConv {
        windows: &'a [Vec<Fixed>],
        weights: &'a [Fixed],
    },
    /// Run dense (time-embedding) MACs: `x` dot `w`, independent of the
    /// workers; the scalar result is latched for the caller.
    Dense { x: &'a [Fixed], w: &'a [Fixed] },
}

/// What PE_9 serves during a *flat* group (`run_group_flat`, §Perf hot
/// path): the same four modes as [`ServerTask`], but over flat slices
/// with precomputed zero counts so the hot loop never re-scans data.
#[derive(Debug, Clone)]
pub enum FlatServer<'a> {
    /// Nothing (series mode) — PE_9 clock-gated.
    Idle,
    /// Serve these skip-branch values (one per worker output).
    Identity(&'a [Fixed]),
    /// Compute a 1x1(xC) residual conv per worker output: `windows` is the
    /// `gw x rtaps` flat slab, `zeros[i]` the zero taps of row `i`.
    Conv {
        windows: &'a [Fixed],
        rtaps: usize,
        weights: &'a [Fixed],
        zeros: &'a [u64],
    },
    /// Run dense (time-embedding) MACs; `zeros` counts zero inputs in `x`.
    Dense {
        x: &'a [Fixed],
        w: &'a [Fixed],
        zeros: u64,
    },
}

/// One convolution group: up to 8 worker windows sharing one filter.
#[derive(Debug, Clone)]
pub struct ConvGroup<'a> {
    /// Per-worker input windows, each `weights.len()` taps. Fewer than 8
    /// windows leaves the remaining workers idle (edge tiles).
    pub windows: &'a [Vec<Fixed>],
    /// The shared filter taps (broadcast to all workers).
    pub weights: &'a [Fixed],
    /// PE_9's task for this group.
    pub server: ServerTask<'a>,
    /// How many of each window's values were already present in the reuse
    /// registers (overlap with the previous group / neighbouring windows).
    pub reused_inputs: u64,
}

/// Result of executing one group.
#[derive(Debug, Clone)]
pub struct GroupResult {
    /// One output per supplied window.
    pub outputs: Vec<Fixed>,
    /// Dense result if the server ran a `Dense` task.
    pub dense_out: Option<Fixed>,
    /// Cycles this group consumed.
    pub cycles: u64,
}

/// One SF-MMCN unit.
#[derive(Debug, Clone)]
pub struct SfMmcnUnit {
    workers: Vec<Pe>,
    server: Pe,
    pub stats: UnitStats,
    /// Steady-state pipelining: true once a group has run, so subsequent
    /// groups overlap their writeback with the next group's first MAC.
    pipeline_warm: bool,
}

impl Default for SfMmcnUnit {
    fn default() -> Self {
        Self::new()
    }
}

impl SfMmcnUnit {
    pub fn new() -> Self {
        Self {
            workers: (0..WORKERS).map(|_| Pe::new()).collect(),
            server: Pe::new(),
            stats: UnitStats::default(),
            pipeline_warm: false,
        }
    }

    /// Aggregate PE stats: (workers, server).
    pub fn pe_stats(&self) -> (PeStats, PeStats) {
        let mut w = PeStats::default();
        for pe in &self.workers {
            w.merge(&pe.stats);
        }
        (w, self.server.stats)
    }

    /// Reset pipeline state between layers (a new layer cannot overlap the
    /// previous layer's writeback — Fig 7 shows the +1 cycle on the first
    /// conv of a burst).
    pub fn flush_pipeline(&mut self) {
        self.pipeline_warm = false;
    }

    /// Execute one convolution group cycle-by-cycle.
    pub fn run_group(&mut self, g: &ConvGroup) -> GroupResult {
        let taps = g.weights.len();
        assert!(taps > 0, "empty filter");
        assert!(
            g.windows.len() <= WORKERS,
            "at most {WORKERS} windows per group"
        );
        for (i, win) in g.windows.iter().enumerate() {
            assert_eq!(
                win.len(),
                taps,
                "window {i} has {} taps, filter has {taps}",
                win.len()
            );
        }

        let mode = match &g.server {
            ServerTask::Idle => UnitMode::Series,
            ServerTask::ServeIdentity(_) => UnitMode::ResidualIdentity,
            ServerTask::ServeConv { .. } => UnitMode::ResidualConv,
            ServerTask::Dense { .. } => UnitMode::DenseTime,
        };

        // Configure PEs.
        let residual_mode = matches!(
            mode,
            UnitMode::ResidualIdentity | UnitMode::ResidualConv
        );
        for (i, pe) in self.workers.iter_mut().enumerate() {
            if i < g.windows.len() {
                pe.set_mode(if residual_mode {
                    PeMode::ResidualAdd
                } else {
                    PeMode::Normal
                });
                pe.begin_conv(taps as u32);
            } else {
                pe.set_mode(PeMode::Idle);
            }
        }

        // PE_9 server setup.
        let mut server_results: Vec<Fixed> = Vec::new();
        let mut dense_out = None;
        match &g.server {
            ServerTask::Idle => self.server.set_mode(PeMode::Idle),
            ServerTask::ServeIdentity(vals) => {
                assert_eq!(
                    vals.len(),
                    g.windows.len(),
                    "one residual value per worker output"
                );
                self.server.set_mode(PeMode::Normal);
            }
            ServerTask::ServeConv { windows, weights } => {
                assert_eq!(windows.len(), g.windows.len());
                let rtaps = weights.len();
                // Synchronization invariant from §III.C: PE_9 must finish
                // all residual convs within the main conv's taps.
                assert!(
                    rtaps * windows.len() <= taps * WORKERS,
                    "PE_9 cannot prepare residual conv in time: \
                     {rtaps} taps x {} outputs vs {taps} main-conv cycles",
                    windows.len()
                );
                self.server.set_mode(PeMode::Normal);
            }
            ServerTask::Dense { x, w } => {
                assert_eq!(x.len(), w.len(), "dense operands must match");
                self.server.set_mode(PeMode::Normal);
            }
        }

        // ---- execution ------------------------------------------------------
        // §Perf: worker-major execution. Within a group the PEs never
        // interact until writeback, so running each worker's whole tap
        // stream contiguously produces identical stats and numerics to the
        // cycle-major interleaving while being ~3x faster to simulate.
        for (i, pe) in self.workers.iter_mut().enumerate() {
            if i < g.windows.len() {
                pe.run_conv_taps(&g.windows[i], g.weights);
            } else {
                pe.stats.idle_cycles += taps as u64;
            }
        }

        // PE_9: batched form of the per-cycle schedule (one serve/MAC per
        // cycle, engaged-but-done cycles count as active in serving modes,
        // idle in series/after-dense — same totals as the cycle loop).
        let mut extra_cycles = 0u64;
        match &g.server {
            ServerTask::Idle => self.server.stats.idle_cycles += taps as u64,
            ServerTask::ServeIdentity(vals) => {
                // One value per cycle; PE_9 engaged for the whole group
                // (the paper counts the server's data transmission as
                // utilization — residual layers hit ~100%, §IV.B.1).
                server_results.extend_from_slice(vals);
                self.stats.served_values += vals.len() as u64;
                self.server.stats.active_cycles += taps as u64;
            }
            ServerTask::ServeConv { windows, weights } => {
                for win in windows.iter() {
                    self.server.run_conv_taps(win, weights);
                    server_results.push(self.server.take_output());
                    self.stats.served_values += 1;
                }
                // transmit/engaged fill for the rest of the window
                let work = (windows.len() * weights.len()) as u64;
                self.server.stats.active_cycles += (taps as u64).saturating_sub(work);
            }
            ServerTask::Dense { x, w } => {
                self.server.run_conv_taps(x, w);
                dense_out = Some(self.server.take_output());
                let work = x.len() as u64;
                // dense shorter than the window: PE_9 idles the remainder;
                // longer: the unit stalls the handoff (overhang cycles).
                self.server.stats.idle_cycles += (taps as u64).saturating_sub(work);
                extra_cycles = work.saturating_sub(taps as u64);
            }
        }

        // ---- writeback --------------------------------------------------
        let mut outputs = Vec::with_capacity(g.windows.len());
        for (i, pe) in self.workers.iter_mut().enumerate().take(g.windows.len()) {
            debug_assert!(pe.done(), "worker {i} did not finish");
            if residual_mode {
                pe.apply_residual(server_results[i]);
            }
            outputs.push(pe.take_output());
        }

        // Cycle accounting: taps cycles, +1 writeback when the pipeline is
        // cold (first group after a flush), + any dense overhang.
        let mut cycles = taps as u64 + extra_cycles;
        if !self.pipeline_warm {
            cycles += 1;
            self.pipeline_warm = true;
        }

        // Memory accounting: without reuse every window value is a buffer
        // read; with the reuse registers, `reused_inputs` of them are
        // register hits instead.
        let total_inputs: u64 = g.windows.iter().map(|w| w.len() as u64).sum();
        assert!(
            g.reused_inputs <= total_inputs,
            "cannot reuse more inputs than exist"
        );
        self.stats.buffer_reads_no_reuse += total_inputs;
        self.stats.buffer_reads += total_inputs - g.reused_inputs;
        self.stats.reuse_reg_writes += g.reused_inputs;
        // Weights broadcast: one buffer read per tap regardless of #PEs.
        self.stats.weight_reads += taps as u64;

        self.stats.cycles += cycles;
        self.stats.conv_outputs += outputs.len() as u64;

        GroupResult {
            outputs,
            dense_out,
            cycles,
        }
    }

    /// §Perf hot path: execute one convolution group from *flat* buffers
    /// with per-group aggregated stats — no per-window `Vec`s, no per-tap
    /// branches, no per-cycle counter updates.
    ///
    /// Semantics are identical to [`Self::run_group`] (the golden tests in
    /// `rust/tests/sim_golden.rs` pin this bit-exactly):
    ///
    /// * `windows` is the `gw x taps` window slab, row-major; `zeros[i]`
    ///   is the number of zero taps in window `i` (precomputed once per
    ///   layer by the array driver and reused across output channels).
    /// * Worker lane `i < gw` accumulates its window against the broadcast
    ///   `weights` in tap order — gated slots add a zero product, so the
    ///   accumulator needs no branch — and folds `taps` active cycles plus
    ///   the MAC/gated split into its [`PeStats`] once.
    /// * PE_9 runs the [`FlatServer`] task under the same schedule as
    ///   [`ServerTask`] in `run_group` (engaged-fill in serving modes,
    ///   idle-fill in series/dense, dense overhang extends the group).
    ///
    /// `outputs` is a caller-owned scratch vector (cleared, then one
    /// output per window). Returns `(cycles, dense_out)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_group_flat(
        &mut self,
        windows: &[Fixed],
        gw: usize,
        taps: usize,
        zeros: &[u64],
        weights: &[Fixed],
        server: FlatServer,
        reused_inputs: u64,
        outputs: &mut Vec<Fixed>,
    ) -> (u64, Option<Fixed>) {
        assert!(taps > 0, "empty filter");
        assert!(gw >= 1 && gw <= WORKERS, "1..=8 windows per group");
        debug_assert_eq!(windows.len(), gw * taps);
        debug_assert_eq!(zeros.len(), gw);
        debug_assert_eq!(weights.len(), taps);

        // ---- workers: one dot product per lane, stats folded per lane ----
        outputs.clear();
        for i in 0..gw {
            let win = &windows[i * taps..(i + 1) * taps];
            outputs.push(Fixed::from_acc(dot_wide(win, weights)));
            let st = &mut self.workers[i].stats;
            st.active_cycles += taps as u64;
            st.macs += taps as u64 - zeros[i];
            st.gated_macs += zeros[i];
            st.writebacks += 1;
        }
        for pe in &mut self.workers[gw..] {
            pe.stats.idle_cycles += taps as u64;
        }

        // ---- PE_9: batched form of run_group's server schedule ----------
        let mut dense_out = None;
        let mut extra_cycles = 0u64;
        match server {
            FlatServer::Idle => self.server.stats.idle_cycles += taps as u64,
            FlatServer::Identity(vals) => {
                assert_eq!(vals.len(), gw, "one residual value per worker output");
                for i in 0..gw {
                    outputs[i] = outputs[i].sat_add(vals[i]);
                    self.workers[i].stats.residual_adds += 1;
                }
                self.stats.served_values += gw as u64;
                self.server.stats.active_cycles += taps as u64;
            }
            FlatServer::Conv {
                windows: rwin,
                rtaps,
                weights: rw,
                zeros: rzeros,
            } => {
                assert_eq!(rwin.len(), gw * rtaps);
                debug_assert_eq!(rzeros.len(), gw);
                debug_assert_eq!(rw.len(), rtaps);
                // Synchronization invariant from §III.C: PE_9 must finish
                // all residual convs within the main conv's taps.
                assert!(
                    rtaps * gw <= taps * WORKERS,
                    "PE_9 cannot prepare residual conv in time: \
                     {rtaps} taps x {gw} outputs vs {taps} main-conv cycles"
                );
                let mut rgated = 0u64;
                for i in 0..gw {
                    let win = &rwin[i * rtaps..(i + 1) * rtaps];
                    let served = Fixed::from_acc(dot_wide(win, rw));
                    outputs[i] = outputs[i].sat_add(served);
                    self.workers[i].stats.residual_adds += 1;
                    rgated += rzeros[i];
                }
                let work = (gw * rtaps) as u64;
                let st = &mut self.server.stats;
                st.macs += work - rgated;
                st.gated_macs += rgated;
                st.writebacks += gw as u64;
                // compute cycles + transmit/engaged fill for the rest
                st.active_cycles += work + (taps as u64).saturating_sub(work);
                self.stats.served_values += gw as u64;
            }
            FlatServer::Dense { x, w, zeros: dz } => {
                debug_assert_eq!(x.len(), w.len(), "dense operands must match");
                dense_out = Some(Fixed::from_acc(dot_wide(x, w)));
                let work = x.len() as u64;
                let st = &mut self.server.stats;
                st.active_cycles += work;
                st.macs += work - dz;
                st.gated_macs += dz;
                st.writebacks += 1;
                // dense shorter than the window: PE_9 idles the remainder;
                // longer: the unit stalls the handoff (overhang cycles).
                st.idle_cycles += (taps as u64).saturating_sub(work);
                extra_cycles = work.saturating_sub(taps as u64);
            }
        }

        // ---- cycle + memory accounting (identical to run_group) ---------
        let mut cycles = taps as u64 + extra_cycles;
        if !self.pipeline_warm {
            cycles += 1;
            self.pipeline_warm = true;
        }
        let total_inputs = (gw * taps) as u64;
        assert!(
            reused_inputs <= total_inputs,
            "cannot reuse more inputs than exist"
        );
        self.stats.buffer_reads_no_reuse += total_inputs;
        self.stats.buffer_reads += total_inputs - reused_inputs;
        self.stats.reuse_reg_writes += reused_inputs;
        self.stats.weight_reads += taps as u64;
        self.stats.cycles += cycles;
        self.stats.conv_outputs += gw as u64;

        (cycles, dense_out)
    }

    /// Small-input split (Figs 11-12): two output channels run
    /// *concurrently* on disjoint worker halves — channel A on PE_1..PE_4,
    /// channel B on PE_5..PE_8 — each with its own filter broadcast. PE_9
    /// handles channel A's branch during the first part of the window and
    /// channel B's during the second (Fig 12), so the pair costs the same
    /// `taps` cycles as a single group: no redundant circuits, no
    /// redundant cycles.
    pub fn run_split_group(
        &mut self,
        ga: &ConvGroup,
        gb: &ConvGroup,
    ) -> (GroupResult, GroupResult) {
        let (na, nb) = (ga.windows.len(), gb.windows.len());
        assert!(na <= 4 && nb <= 4, "split halves are at most 4 lanes");
        let taps = ga.weights.len();
        assert_eq!(taps, gb.weights.len(), "split groups share tap count");
        assert!(taps > 0);
        for (i, w) in ga.windows.iter().enumerate() {
            assert_eq!(w.len(), taps, "A window {i}");
        }
        for (i, w) in gb.windows.iter().enumerate() {
            assert_eq!(w.len(), taps, "B window {i}");
        }
        let residual_a = !matches!(ga.server, ServerTask::Idle | ServerTask::Dense { .. });
        let residual_b = !matches!(gb.server, ServerTask::Idle | ServerTask::Dense { .. });

        // Configure the halves: A on workers 0..na, B on workers 4..4+nb.
        for (i, pe) in self.workers.iter_mut().enumerate() {
            let (active, res) = if i < na {
                (true, residual_a)
            } else if (4..4 + nb).contains(&i) {
                (true, residual_b)
            } else {
                (false, false)
            };
            if active {
                pe.set_mode(if res { PeMode::ResidualAdd } else { PeMode::Normal });
                pe.begin_conv(taps as u32);
            } else {
                pe.set_mode(PeMode::Idle);
            }
        }
        self.server.set_mode(
            if matches!(ga.server, ServerTask::Idle) && matches!(gb.server, ServerTask::Idle) {
                PeMode::Idle
            } else {
                PeMode::Normal
            },
        );

        // PE_9's sequential schedule: finish half A's task, then half B's.
        // Each task is the same state machine as in `run_group`.
        struct SrvState {
            results: Vec<Fixed>,
            out_idx: usize,
            cursor: usize,
            dense_out: Option<Fixed>,
        }
        let mut sa = SrvState {
            results: vec![],
            out_idx: 0,
            cursor: 0,
            dense_out: None,
        };
        let mut sb = SrvState {
            results: vec![],
            out_idx: 0,
            cursor: 0,
            dense_out: None,
        };

        // Advance one server cycle on `task`; returns true if it consumed
        // the cycle (false = task already complete).
        let step_server = |server: &mut Pe,
                               stats: &mut UnitStats,
                               task: &ServerTask,
                               st: &mut SrvState|
         -> bool {
            match task {
                ServerTask::Idle => false,
                ServerTask::ServeIdentity(vals) => {
                    if st.out_idx < vals.len() {
                        st.results.push(vals[st.out_idx]);
                        st.out_idx += 1;
                        stats.served_values += 1;
                        server.stats.active_cycles += 1;
                        true
                    } else {
                        false
                    }
                }
                ServerTask::ServeConv { windows, weights } => {
                    if st.out_idx < windows.len() {
                        if st.cursor == 0 {
                            server.begin_conv(weights.len() as u32);
                        }
                        server.mac_cycle(windows[st.out_idx][st.cursor], weights[st.cursor]);
                        st.cursor += 1;
                        if st.cursor == weights.len() {
                            st.results.push(server.take_output());
                            stats.served_values += 1;
                            st.cursor = 0;
                            st.out_idx += 1;
                        }
                        true
                    } else {
                        false
                    }
                }
                ServerTask::Dense { x, w } => {
                    if st.cursor < x.len() {
                        if st.cursor == 0 {
                            server.begin_conv(x.len() as u32);
                        }
                        server.mac_cycle(x[st.cursor], w[st.cursor]);
                        st.cursor += 1;
                        if st.cursor == x.len() {
                            st.dense_out = Some(server.take_output());
                        }
                        true
                    } else {
                        false
                    }
                }
            }
        };

        // ---- cycle loop ---------------------------------------------------
        for t in 0..taps {
            for (i, pe) in self.workers.iter_mut().enumerate() {
                if i < na {
                    pe.mac_cycle(ga.windows[i][t], ga.weights[t]);
                } else if (4..4 + nb).contains(&i) {
                    pe.mac_cycle(gb.windows[i - 4][t], gb.weights[t]);
                } else {
                    pe.idle_cycle();
                }
            }
            // PE_9: A first, then B; engaged (not idle) whenever either
            // half has a branch at all — same utilization rule as
            // run_group's serving modes.
            let consumed = step_server(&mut self.server, &mut self.stats, &ga.server, &mut sa)
                || step_server(&mut self.server, &mut self.stats, &gb.server, &mut sb);
            if !consumed {
                if residual_a || residual_b {
                    self.server.stats.active_cycles += 1; // engaged: holding
                } else {
                    self.server.idle_cycle();
                }
            }
        }

        // Overhang: any unfinished server work (long dense chains) extends
        // the window, stalling the handoff.
        let mut extra_cycles = 0u64;
        loop {
            let consumed = step_server(&mut self.server, &mut self.stats, &ga.server, &mut sa)
                || step_server(&mut self.server, &mut self.stats, &gb.server, &mut sb);
            if !consumed {
                break;
            }
            extra_cycles += 1;
        }

        // ---- writeback ------------------------------------------------------
        let mut out_a = Vec::with_capacity(na);
        for (i, pe) in self.workers.iter_mut().enumerate().take(na) {
            debug_assert!(pe.done(), "A worker {i}");
            if residual_a {
                pe.apply_residual(sa.results[i]);
            }
            out_a.push(pe.take_output());
        }
        let mut out_b = Vec::with_capacity(nb);
        for i in 0..nb {
            let pe = &mut self.workers[4 + i];
            debug_assert!(pe.done(), "B worker {i}");
            if residual_b {
                pe.apply_residual(sb.results[i]);
            }
            out_b.push(pe.take_output());
        }

        let mut cycles = taps as u64 + extra_cycles;
        if !self.pipeline_warm {
            cycles += 1;
            self.pipeline_warm = true;
        }

        // Memory accounting: both halves window the *same* input map, so
        // half B's taps are register hits when the reuse registers are on
        // (the caller encodes that through `gb.reused_inputs`).
        for g in [ga, gb] {
            let total: u64 = g.windows.iter().map(|w| w.len() as u64).sum();
            assert!(g.reused_inputs <= total);
            self.stats.buffer_reads_no_reuse += total;
            self.stats.buffer_reads += total - g.reused_inputs;
            self.stats.reuse_reg_writes += g.reused_inputs;
        }
        // Two filters broadcast, one per half.
        self.stats.weight_reads += 2 * taps as u64;
        self.stats.cycles += cycles;
        self.stats.conv_outputs += (na + nb) as u64;

        (
            GroupResult {
                outputs: out_a,
                dense_out: sa.dense_out,
                cycles,
            },
            GroupResult {
                outputs: out_b,
                dense_out: sb.dense_out,
                cycles,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fx(x: f32) -> Fixed {
        Fixed::from_f32(x)
    }

    fn windows(n: usize, taps: usize, v: f32) -> Vec<Vec<Fixed>> {
        (0..n).map(|_| vec![fx(v); taps]).collect()
    }

    #[test]
    fn series_mode_eight_outputs_in_taps_cycles() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(0.5); 9];
        let wins = windows(8, 9, 1.0);
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.outputs.len(), 8);
        assert_eq!(r.cycles, 10); // cold pipeline: 9 + 1
        for o in &r.outputs {
            assert!((o.to_f32() - 4.5).abs() < 1e-2);
        }
        // steady state: next group is 9 cycles
        let r2 = u.run_group(&g);
        assert_eq!(r2.cycles, 9);
        let (_, srv) = u.pe_stats();
        assert_eq!(srv.macs, 0, "PE_9 must be idle in series mode");
    }

    #[test]
    fn residual_identity_same_cycles_as_series() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 1.0);
        let skip: Vec<Fixed> = (0..8).map(|i| fx(i as f32)).collect();
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::ServeIdentity(&skip),
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.cycles, 10); // identical to series cold-start: SF adds 0 cycles
        for (i, o) in r.outputs.iter().enumerate() {
            assert!(
                (o.to_f32() - (9.0 + i as f32)).abs() < 1e-2,
                "output {i} = {}",
                o.to_f32()
            );
        }
        assert_eq!(u.stats.served_values, 8);
    }

    #[test]
    fn residual_conv_pe9_computes_in_time() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 1.0);
        // 1x1 residual conv over 4 input channels: 4 taps per output
        let rwins: Vec<Vec<Fixed>> = (0..8).map(|_| vec![fx(0.5); 4]).collect();
        let rw = vec![fx(1.0); 4];
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::ServeConv {
                windows: &rwins,
                weights: &rw,
            },
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.cycles, 10, "residual conv must not add cycles");
        // main conv = 9, residual conv = 4 * 0.5 = 2 -> 11
        for o in &r.outputs {
            assert!((o.to_f32() - 11.0).abs() < 5e-2, "{}", o.to_f32());
        }
        let (_, srv) = u.pe_stats();
        assert_eq!(srv.macs, 32, "PE_9 ran 8 x 4-tap convs");
    }

    #[test]
    #[should_panic(expected = "cannot prepare residual conv in time")]
    fn residual_conv_too_large_rejected() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 2]; // tiny main conv: 2 cycles only
        let wins = windows(8, 2, 1.0);
        let rwins: Vec<Vec<Fixed>> = (0..8).map(|_| vec![fx(0.5); 9]).collect();
        let rw = vec![fx(1.0); 9];
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::ServeConv {
                windows: &rwins,
                weights: &rw,
            },
            reused_inputs: 0,
        };
        let _ = u.run_group(&g);
    }

    #[test]
    fn dense_time_overlaps_with_conv() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 2.0);
        let x = vec![fx(1.0); 6];
        let dw = vec![fx(0.5); 6];
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Dense { x: &x, w: &dw },
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.cycles, 10, "6-tap dense hides under 9-tap conv");
        let d = r.dense_out.expect("dense result");
        assert!((d.to_f32() - 3.0).abs() < 1e-2);
    }

    #[test]
    fn dense_longer_than_conv_adds_overhang() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 4];
        let wins = windows(8, 4, 1.0);
        let x = vec![fx(1.0); 10];
        let dw = vec![fx(1.0); 10];
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Dense { x: &x, w: &dw },
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.cycles, 4 + 1 + 6, "4 conv + cold + 6 overhang");
        assert!((r.dense_out.unwrap().to_f32() - 10.0).abs() < 1e-2);
    }

    #[test]
    fn partial_group_leaves_workers_idle() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(3, 9, 1.0);
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.outputs.len(), 3);
        let (wstats, _) = u.pe_stats();
        assert_eq!(wstats.macs, 27);
        assert_eq!(wstats.idle_cycles, 5 * 9);
    }

    #[test]
    fn reuse_accounting() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 1.0);
        // Sliding 3x3 windows over a row: 8 windows x 9 taps = 72 values,
        // but only 30 are distinct (3 rows x 10 cols).
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 42,
        };
        u.run_group(&g);
        assert_eq!(u.stats.buffer_reads_no_reuse, 72);
        assert_eq!(u.stats.buffer_reads, 30);
        assert_eq!(u.stats.reads_saved(), 42);
    }

    #[test]
    fn split_group_costs_taps_once() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wa = windows(4, 9, 1.0);
        let wb = windows(4, 9, 2.0);
        let ga = ConvGroup {
            windows: &wa,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 0,
        };
        let gb = ConvGroup {
            windows: &wb,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 0,
        };
        let (ra, rb) = u.run_split_group(&ga, &gb);
        assert_eq!(ra.cycles, 10);
        assert_eq!(rb.cycles, 10);
        assert_eq!(u.stats.cycles, 10, "halves overlap in time");
        assert!((ra.outputs[0].to_f32() - 9.0).abs() < 1e-2);
        assert!((rb.outputs[0].to_f32() - 18.0).abs() < 1e-2);
    }

    /// Helper: run the same group through `run_group` and `run_group_flat`
    /// on two fresh units and assert outputs, cycles, and every stat agree.
    fn assert_flat_matches(
        wins: &[Vec<Fixed>],
        w: &[Fixed],
        server: ServerTask<'_>,
        reused: u64,
        rounds: usize,
    ) {
        use crate::sim::pe::count_zeros;
        let mut u_ref = SfMmcnUnit::new();
        let mut u_flat = SfMmcnUnit::new();
        let taps = w.len();
        let gw = wins.len();
        let flat: Vec<Fixed> = wins.iter().flatten().copied().collect();
        let zeros: Vec<u64> = wins.iter().map(|win| count_zeros(win)).collect();
        for _ in 0..rounds {
            let g = ConvGroup {
                windows: wins,
                weights: w,
                server: server.clone(),
                reused_inputs: reused,
            };
            let r = u_ref.run_group(&g);
            let fs = match &server {
                ServerTask::Idle => FlatServer::Idle,
                ServerTask::ServeIdentity(v) => FlatServer::Identity(v),
                ServerTask::ServeConv { windows, weights } => {
                    let weights: &[Fixed] = weights;
                    // flatten on the fly for the test
                    let rtaps = weights.len();
                    let rflat: Vec<Fixed> = windows.iter().flatten().copied().collect();
                    let rz: Vec<u64> = windows.iter().map(|x| count_zeros(x)).collect();
                    // run inline since the borrows are local
                    let mut outs = Vec::new();
                    let (cycles, dense_out) = u_flat.run_group_flat(
                        &flat,
                        gw,
                        taps,
                        &zeros,
                        w,
                        FlatServer::Conv {
                            windows: &rflat,
                            rtaps,
                            weights,
                            zeros: &rz,
                        },
                        reused,
                        &mut outs,
                    );
                    assert_eq!(r.outputs, outs, "conv-server outputs");
                    assert_eq!(r.cycles, cycles);
                    assert_eq!(r.dense_out, dense_out);
                    continue;
                }
                ServerTask::Dense { x, w: dw } => FlatServer::Dense {
                    x,
                    w: dw,
                    zeros: count_zeros(x),
                },
            };
            let mut outs = Vec::new();
            let (cycles, dense_out) =
                u_flat.run_group_flat(&flat, gw, taps, &zeros, w, fs, reused, &mut outs);
            assert_eq!(r.outputs, outs, "outputs");
            assert_eq!(r.cycles, cycles, "cycles");
            assert_eq!(r.dense_out, dense_out, "dense out");
        }
        // unit-level counters
        assert_eq!(u_ref.stats.cycles, u_flat.stats.cycles);
        assert_eq!(u_ref.stats.conv_outputs, u_flat.stats.conv_outputs);
        assert_eq!(u_ref.stats.served_values, u_flat.stats.served_values);
        assert_eq!(u_ref.stats.buffer_reads, u_flat.stats.buffer_reads);
        assert_eq!(
            u_ref.stats.buffer_reads_no_reuse,
            u_flat.stats.buffer_reads_no_reuse
        );
        assert_eq!(u_ref.stats.weight_reads, u_flat.stats.weight_reads);
        assert_eq!(u_ref.stats.reuse_reg_writes, u_flat.stats.reuse_reg_writes);
        // aggregated PE stats
        let (rw_, rs) = u_ref.pe_stats();
        let (fw_, fsrv) = u_flat.pe_stats();
        assert_eq!(rw_, fw_, "worker PE stats");
        assert_eq!(rs, fsrv, "server PE stats");
    }

    #[test]
    fn flat_group_matches_reference_series() {
        let w: Vec<Fixed> = (0..9).map(|i| fx(0.1 * i as f32 - 0.4)).collect();
        let wins: Vec<Vec<Fixed>> = (0..8)
            .map(|i| {
                (0..9)
                    .map(|j| if (i + j) % 4 == 0 { fx(0.0) } else { fx(0.3 * j as f32) })
                    .collect()
            })
            .collect();
        assert_flat_matches(&wins, &w, ServerTask::Idle, 42, 3);
    }

    #[test]
    fn flat_group_matches_reference_partial_group() {
        let w = vec![fx(0.5); 12];
        let wins = windows(3, 12, 1.0);
        assert_flat_matches(&wins, &w, ServerTask::Idle, 0, 2);
    }

    #[test]
    fn flat_group_matches_reference_identity() {
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 1.0);
        let skip: Vec<Fixed> = (0..8).map(|i| fx(i as f32 - 3.0)).collect();
        assert_flat_matches(&wins, &w, ServerTask::ServeIdentity(&skip), 30, 2);
    }

    #[test]
    fn flat_group_matches_reference_residual_conv() {
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 1.0);
        let rwins: Vec<Vec<Fixed>> = (0..8)
            .map(|i| vec![if i % 2 == 0 { fx(0.0) } else { fx(0.5) }; 4])
            .collect();
        let rw = vec![fx(1.0); 4];
        assert_flat_matches(
            &wins,
            &w,
            ServerTask::ServeConv {
                windows: &rwins,
                weights: &rw,
            },
            0,
            2,
        );
    }

    #[test]
    fn flat_group_matches_reference_dense_and_overhang() {
        let w = vec![fx(1.0); 4];
        let wins = windows(8, 4, 1.0);
        // longer than the conv window: overhang cycles must match too
        let x = vec![fx(1.0); 10];
        let dw = vec![fx(0.5); 10];
        assert_flat_matches(&wins, &w, ServerTask::Dense { x: &x, w: &dw }, 0, 2);
        // shorter than the window: idle fill must match
        let w2 = vec![fx(1.0); 9];
        let wins2 = windows(8, 9, 2.0);
        let x2 = vec![fx(1.0); 6];
        let dw2 = vec![fx(0.5); 6];
        assert_flat_matches(&wins2, &w2, ServerTask::Dense { x: &x2, w: &dw2 }, 0, 2);
    }

    #[test]
    fn zero_inputs_gate_but_keep_timing() {
        let mut u = SfMmcnUnit::new();
        let w = vec![fx(1.0); 9];
        let wins = windows(8, 9, 0.0);
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 0,
        };
        let r = u.run_group(&g);
        assert_eq!(r.cycles, 10);
        let (wstats, _) = u.pe_stats();
        assert_eq!(wstats.macs, 0);
        assert_eq!(wstats.gated_macs, 72);
    }
}
