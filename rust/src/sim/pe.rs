//! A single SF-MMCN processing element (paper Fig 4).
//!
//! The PE owns a 16x16-bit multiplier, a 32-bit accumulator, a pipeline
//! counter, a zero-gate unit on the activation input, an output register,
//! and — the SF-MMCN addition — a residual adder plus an output mux that
//! selects between the plain MAC result and `MAC + residual`.
//!
//! Thanks to the pipeline counter a PE *self-computes* a complete
//! convolution: it consumes one (activation, weight) pair per cycle and
//! raises `done` after `k` MAC cycles (k = filter taps). The writeback
//! cycle overlaps the first MAC of the next convolution, giving the
//! paper's steady-state "8 outputs per 9 cycles" per unit.

use crate::quant::Fixed;

/// Zero-valued taps in a window — what the zero-gate unit would suppress.
#[inline]
pub fn count_zeros(window: &[Fixed]) -> u64 {
    // Branchless: `is_zero` lowers to a compare, the sum vectorizes.
    window.iter().map(|x| u64::from(x.is_zero())).sum()
}

/// Widening dot product, exactly as the MAC pipeline accumulates it:
/// Q8.8 x Q8.8 products summed into the Q16.16 accumulator in tap order.
/// Zero activations contribute zero products, so the result is identical
/// with or without the zero-gate unit.
///
/// The default build runs the scalar accumulator; `--features simd`
/// dispatches the explicit 8-lane path (`util::simd::dot_wide_fixed`).
/// Integer addition is associative, so both are **bit-exact** — the
/// simulator's goldens never move (asserted by `tests/kernel_equiv.rs`).
#[inline]
pub fn dot_wide(window: &[Fixed], weights: &[Fixed]) -> i64 {
    debug_assert_eq!(window.len(), weights.len());
    #[cfg(feature = "simd")]
    {
        crate::util::simd::dot_wide_fixed(window, weights)
    }
    #[cfg(not(feature = "simd"))]
    {
        dot_wide_scalar(window, weights)
    }
}

/// The scalar reference accumulator behind [`dot_wide`], kept public so
/// the kernel-equivalence suite can pin the SIMD path against it.
#[inline]
pub fn dot_wide_scalar(window: &[Fixed], weights: &[Fixed]) -> i64 {
    let mut acc = 0i64;
    for (&x, &w) in window.iter().zip(weights) {
        acc += x.mul_wide(w) as i64;
    }
    acc
}

/// Operating mode of a PE, set by the unit's mode-select lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeMode {
    /// Plain convolution: output <- MAC result.
    Normal,
    /// Residual: output <- MAC result + residual input (from PE_9's bus).
    ResidualAdd,
    /// PE is clock-gated (e.g. PE_9 during series layers).
    Idle,
}

/// Event counters for one PE. Pure data — the energy model prices these.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeStats {
    /// Cycles in which the PE was enabled (clock running).
    pub active_cycles: u64,
    /// Cycles spent clock-gated / idle.
    pub idle_cycles: u64,
    /// MAC operations actually executed (multiplier fired).
    pub macs: u64,
    /// MAC slots where the zero-gate unit suppressed the multiplier.
    pub gated_macs: u64,
    /// Residual-adder firings.
    pub residual_adds: u64,
    /// Output-register writebacks.
    pub writebacks: u64,
}

impl PeStats {
    pub fn merge(&mut self, o: &PeStats) {
        self.active_cycles += o.active_cycles;
        self.idle_cycles += o.idle_cycles;
        self.macs += o.macs;
        self.gated_macs += o.gated_macs;
        self.residual_adds += o.residual_adds;
        self.writebacks += o.writebacks;
    }

    /// Total MAC slots (fired + gated).
    pub fn mac_slots(&self) -> u64 {
        self.macs + self.gated_macs
    }
}

/// One processing element.
#[derive(Debug, Clone)]
pub struct Pe {
    mode: PeMode,
    /// Q16.16 accumulator (32-bit in silicon; i64 here so tests can assert
    /// no silicon-width overflow occurs — see `acc_fits_hw`).
    acc: i64,
    /// Pipeline counter: MAC cycles completed for the in-flight conv.
    counter: u32,
    /// Number of taps for the in-flight convolution (e.g. 9 for 3x3).
    taps: u32,
    /// Latched output of the last completed convolution.
    out: Fixed,
    /// Whether `out` is fresh (set by writeback, cleared by take_output).
    done: bool,
    pub stats: PeStats,
}

impl Default for Pe {
    fn default() -> Self {
        Self::new()
    }
}

impl Pe {
    pub fn new() -> Self {
        Self {
            mode: PeMode::Normal,
            acc: 0,
            counter: 0,
            taps: 9,
            out: Fixed::ZERO,
            done: false,
            stats: PeStats::default(),
        }
    }

    pub fn set_mode(&mut self, mode: PeMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> PeMode {
        self.mode
    }

    /// Begin a convolution of `taps` MAC cycles (filter height x width,
    /// possibly x channels when accumulating across input channels).
    pub fn begin_conv(&mut self, taps: u32) {
        assert!(taps > 0, "convolution needs at least one tap");
        self.acc = 0;
        self.counter = 0;
        self.taps = taps;
        self.done = false;
    }

    /// Run a whole convolution worker-major: `begin_conv` + one
    /// [`Self::mac_cycle`] per tap, without per-call dispatch overhead.
    /// Identical stats/numerics to the cycle-major path — PEs are
    /// independent within a group (§Perf hot path).
    ///
    /// §Perf: the loop is branch-light. A gated slot contributes a zero
    /// product (`x == 0  =>  x * w == 0`), so the accumulator can take
    /// every product unconditionally; only the zero *count* is tracked,
    /// and the MAC/gated split is folded into [`PeStats`] once per call.
    pub fn run_conv_taps(&mut self, window: &[Fixed], weights: &[Fixed]) {
        let zeros = count_zeros(window);
        self.run_conv_taps_with_zeros(window, weights, zeros);
    }

    /// [`Self::run_conv_taps`] with the window's zero count precomputed by
    /// the caller — the flat hot path counts zeros once per *layer* and
    /// reuses the counts across every output channel (§Perf).
    pub fn run_conv_taps_with_zeros(
        &mut self,
        window: &[Fixed],
        weights: &[Fixed],
        zeros: u64,
    ) {
        debug_assert_eq!(window.len(), weights.len());
        debug_assert_eq!(zeros, count_zeros(window), "stale zero count");
        self.begin_conv(window.len() as u32);
        self.acc = dot_wide(window, weights);
        let n = window.len() as u64;
        self.stats.active_cycles += n;
        self.stats.macs += n - zeros;
        self.stats.gated_macs += zeros;
        self.counter = self.taps; // all taps consumed
        self.finish(Fixed::ZERO);
    }

    /// One MAC cycle: consume an (activation, weight) pair.
    ///
    /// The zero-gate unit checks the *activation* (paper: "if input image
    /// data is zero, the zero gate unit will turn off a multiplier").
    /// A gated slot still consumes the cycle — only the multiplier energy
    /// is saved — which is why gating shows up in power, not cycles.
    #[inline]
    pub fn mac_cycle(&mut self, x: Fixed, w: Fixed) {
        debug_assert!(
            self.mode != PeMode::Idle,
            "MAC issued to an idle PE — unit control bug"
        );
        self.stats.active_cycles += 1;
        if x.is_zero() {
            self.stats.gated_macs += 1;
        } else {
            self.acc += x.mul_wide(w) as i64;
            self.stats.macs += 1;
        }
        self.counter += 1;
        if self.counter == self.taps {
            // Pipeline writeback: overlaps the next conv's first MAC, so it
            // costs a register write, not an extra cycle (Fig 7: 10 cycles
            // for a lone conv, 9 per conv in steady state).
            self.finish(Fixed::ZERO);
        }
    }

    /// Complete the in-flight convolution, applying the residual input if
    /// the PE is in residual mode. `residual` is the value PE_9 serves on
    /// the shared bus; ignored in `Normal` mode.
    fn finish(&mut self, _server_residual: Fixed) {
        let mac_out = Fixed::from_acc(self.acc);
        self.out = mac_out;
        self.done = true;
        self.stats.writebacks += 1;
        self.acc = 0;
        self.counter = 0;
    }

    /// Apply the residual served by PE_9 (residual modes only). In silicon
    /// this is the adder stage between the MAC output and the output
    /// register (Fig 4); it fires in the writeback cycle.
    pub fn apply_residual(&mut self, residual: Fixed) {
        debug_assert_eq!(self.mode, PeMode::ResidualAdd);
        self.out = self.out.sat_add(residual);
        self.stats.residual_adds += 1;
    }

    /// One idle (clock-gated) cycle.
    pub fn idle_cycle(&mut self) {
        self.stats.idle_cycles += 1;
    }

    /// True when a finished convolution output is waiting.
    pub fn done(&self) -> bool {
        self.done
    }

    /// Take the completed output (clears `done`).
    pub fn take_output(&mut self) -> Fixed {
        debug_assert!(self.done, "take_output before conv finished");
        self.done = false;
        self.out
    }

    /// MAC cycles completed for the in-flight convolution.
    pub fn counter(&self) -> u32 {
        self.counter
    }

    /// Check the accumulator still fits the silicon's 32-bit register.
    /// (Q8.8 x Q8.8 products accumulated <= 1024 taps stay well inside.)
    pub fn acc_fits_hw(&self) -> bool {
        self.acc >= i32::MIN as i64 && self.acc <= i32::MAX as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fx(x: f32) -> Fixed {
        Fixed::from_f32(x)
    }

    #[test]
    fn conv3x3_numerics() {
        let mut pe = Pe::new();
        pe.begin_conv(9);
        // window = all 0.5, weights = all 0.25 -> 9 * 0.125 = 1.125
        for _ in 0..9 {
            pe.mac_cycle(fx(0.5), fx(0.25));
        }
        assert!(pe.done());
        let out = pe.take_output().to_f32();
        assert!((out - 1.125).abs() < 1e-2, "{out}");
        assert_eq!(pe.stats.macs, 9);
        assert_eq!(pe.stats.writebacks, 1);
    }

    #[test]
    fn zero_gate_skips_multiplier_not_cycle() {
        let mut pe = Pe::new();
        pe.begin_conv(9);
        for i in 0..9 {
            let x = if i % 3 == 0 { fx(0.0) } else { fx(1.0) };
            pe.mac_cycle(x, fx(1.0));
        }
        assert!(pe.done());
        assert_eq!(pe.stats.gated_macs, 3);
        assert_eq!(pe.stats.macs, 6);
        assert_eq!(pe.stats.active_cycles, 9); // gated slots still cost cycles
        assert!((pe.take_output().to_f32() - 6.0).abs() < 1e-2);
    }

    #[test]
    fn residual_mode_adds_served_value() {
        let mut pe = Pe::new();
        pe.set_mode(PeMode::ResidualAdd);
        pe.begin_conv(9);
        for _ in 0..9 {
            pe.mac_cycle(fx(1.0), fx(0.5));
        }
        assert!(pe.done());
        pe.apply_residual(fx(2.0));
        let out = pe.take_output().to_f32();
        assert!((out - (4.5 + 2.0)).abs() < 1e-2, "{out}");
        assert_eq!(pe.stats.residual_adds, 1);
    }

    #[test]
    fn pipeline_back_to_back_convs() {
        let mut pe = Pe::new();
        for conv in 0..5 {
            pe.begin_conv(9);
            for _ in 0..9 {
                pe.mac_cycle(fx(1.0), fx(1.0));
            }
            assert!(pe.done(), "conv {conv} not done");
            let out = pe.take_output().to_f32();
            assert!((out - 9.0).abs() < 1e-2);
        }
        // 5 convs x 9 cycles, no extra writeback cycles in steady state
        assert_eq!(pe.stats.active_cycles, 45);
        assert_eq!(pe.stats.writebacks, 5);
    }

    #[test]
    fn variable_tap_counts() {
        for taps in [1u32, 4, 9, 25, 49] {
            let mut pe = Pe::new();
            pe.begin_conv(taps);
            for _ in 0..taps {
                pe.mac_cycle(fx(1.0), fx(1.0));
            }
            assert!(pe.done());
            assert!((pe.take_output().to_f32() - taps as f32).abs() < taps as f32 * 1e-2);
        }
    }

    #[test]
    fn accumulator_fits_hw_for_deep_channel_convs() {
        let mut pe = Pe::new();
        // worst case: 512-channel 3x3 accumulation at max magnitude inputs
        pe.begin_conv(9 * 64);
        for _ in 0..9 * 64 {
            pe.mac_cycle(fx(1.0), fx(1.0));
            assert!(pe.acc_fits_hw());
        }
    }

    #[test]
    fn taps_path_matches_cycle_path_exactly() {
        // The batched tap loop must be bit- and stat-identical to the
        // cycle-by-cycle path, including zero gating.
        let window: Vec<Fixed> = [0.0, 0.5, -0.25, 0.0, 1.0, 2.0, -1.5, 0.0, 0.125]
            .iter()
            .map(|&v| fx(v))
            .collect();
        let weights: Vec<Fixed> = (0..9).map(|i| fx(0.1 * i as f32 - 0.3)).collect();
        let mut a = Pe::new();
        a.begin_conv(9);
        for (&x, &w) in window.iter().zip(&weights) {
            a.mac_cycle(x, w);
        }
        let mut b = Pe::new();
        b.run_conv_taps(&window, &weights);
        assert_eq!(a.take_output(), b.take_output());
        assert_eq!(a.stats, b.stats);
        assert_eq!(b.stats.gated_macs, 3);
    }

    #[test]
    fn zero_count_helpers() {
        let w: Vec<Fixed> = [0.0, 1.0, 0.0, 2.0].iter().map(|&v| fx(v)).collect();
        assert_eq!(count_zeros(&w), 2);
        let ones = vec![fx(1.0); 4];
        // 0*1 + 1*1 + 0*1 + 2*1 = 3.0 in Q16.16
        let acc = dot_wide(&w, &ones);
        assert!((Fixed::from_acc(acc).to_f32() - 3.0).abs() < 1e-2);
    }

    #[test]
    #[should_panic(expected = "take_output before conv finished")]
    fn take_before_done_panics_in_debug() {
        let mut pe = Pe::new();
        pe.begin_conv(9);
        pe.mac_cycle(fx(1.0), fx(1.0));
        let _ = pe.take_output();
    }
}
